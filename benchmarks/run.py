"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Set REPRO_BENCH_FAST=1 for a quick
pass; SKIP_SLOW=1 skips the end-to-end CL accuracy benches.
"""
from __future__ import annotations

import os
import sys
import time
import traceback


def main() -> None:
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    from benchmarks import (
        bench_dispatch,
        bench_fig3_flops,
        bench_fig9_accuracy,
        bench_fig11_temporal,
        bench_fig12_extreme,
        bench_fleet,
        bench_kernels,
        bench_manager,
        bench_reallocation,
        bench_replay,
        bench_table3_models,
    )
    from benchmarks.common import emit

    modules = [
        ("table3", bench_table3_models),
        ("fig3", bench_fig3_flops),
        ("kernels", bench_kernels),
    ]
    if not int(os.environ.get("SKIP_SLOW", "0")):
        modules += [
            ("fig9", bench_fig9_accuracy),
            ("fig11", bench_fig11_temporal),
            ("fig12", bench_fig12_extreme),
            # System benches (smoke sizes when run via the registry; the
            # standalone scripts expose the full sweeps + JSON artifacts).
            ("dispatch", bench_dispatch),
            ("reallocation", bench_reallocation),
            ("replay", bench_replay),
            ("fleet", bench_fleet),
            ("manager", bench_manager),
        ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        t0 = time.time()
        try:
            emit(mod.run())
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"# {name} FAILED", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
