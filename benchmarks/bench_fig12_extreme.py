"""Fig. 12: extreme data-drift scenarios (ES1/ES2, all four drift axes).

Paper: Ekya degrades most (-12.9% vs regular), EOMU tolerates better
(+7.8% over Ekya), DaCapo-ST best (+4.4% over EOMU, +13.0% over Ekya).
"""
from __future__ import annotations

import time

from benchmarks.common import run_system
from repro.configs.dacapo_pairs import PAIRS

SYSTEMS_12 = ("OrinHigh-Ekya", "OrinHigh-EOMU", "DaCapo-Spatiotemporal")


def run():
    rows = []
    accs = {}
    for scen in ("ES1", "ES2"):
        for name in SYSTEMS_12:
            t0 = time.time()
            res = run_system(name, PAIRS[0][0], PAIRS[0][1], scen)
            accs[(scen, name)] = res.avg_accuracy
            rows.append((
                f"fig12/{scen}/{name}", (time.time() - t0) * 1e6,
                f"avg_acc={res.avg_accuracy*100:.1f}% "
                f"drifts={res.drift_events}"))
    for scen in ("ES1", "ES2"):
        dc = accs[(scen, "DaCapo-Spatiotemporal")]
        ek = accs[(scen, "OrinHigh-Ekya")]
        eo = accs[(scen, "OrinHigh-EOMU")]
        rows.append((
            f"fig12/{scen}/ordering", 0.0,
            f"DaCapo-vs-Ekya={100*(dc-ek):+.1f}pp (paper +13.0) "
            f"DaCapo-vs-EOMU={100*(dc-eo):+.1f}pp (paper +4.4) "
            f"PASS={dc >= max(ek, eo) - 0.02}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
