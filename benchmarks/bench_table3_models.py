"""Table III: parameter counts and GFLOPs of the six evaluated models.

Validates our implementations against the paper's reported numbers
(paper GFLOPs are MACs; ours count 2*MACs, so we compare flops/2).
"""
from __future__ import annotations

import time

import jax

from repro.configs.dacapo_pairs import TABLE_III, VISION_MODELS
from repro.models.registry import make_vision_model


def run():
    rows = []
    for name, cfg in VISION_MODELS.items():
        m = make_vision_model(cfg)
        t0 = time.time()
        params = m.init(jax.random.PRNGKey(0))
        us = (time.time() - t0) * 1e6
        n = m.param_count(params)
        gmacs = m.flops() / 2 / 1e9
        ref_n, ref_g = TABLE_III[name]
        derived = (f"params={n/1e6:.1f}M(paper {ref_n/1e6:.1f}M) "
                   f"gmacs={gmacs:.2f}(paper {ref_g:.2f}) "
                   f"param_err={abs(n-ref_n)/ref_n*100:.1f}%")
        rows.append((f"table3/{name}", us, derived))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
