"""Fig. 9: end-to-end averaged accuracy of the continuously-learning system
variants on drift scenarios.

Validates the paper's ordering claims on the synthetic BDD100K stand-in:
  (1) DaCapo-Spatiotemporal is the best system overall;
  (2) DC-ST > DC-S (temporal reallocation helps);
  (3) OrinLow is the weakest configuration;
plus the 127x / 254x power advantage (Table IV) as energy-per-run.
"""
from __future__ import annotations

import time

from benchmarks.common import POWER_W, SYSTEMS, run_system
from repro.configs.dacapo_pairs import PAIRS

SCENARIOS = ("S1", "S3")
PAIR = PAIRS[0]  # (ResNet18, WideResNet50)


def run():
    rows = []
    results = {}
    for scen in SCENARIOS:
        for name in SYSTEMS:
            t0 = time.time()
            res = run_system(name, PAIR[0], PAIR[1], scen)
            us = (time.time() - t0) * 1e6
            results[(scen, name)] = res
            energy = POWER_W[name] * 180.0
            rows.append((
                f"fig9/{scen}/{name}", us,
                f"avg_acc={res.avg_accuracy*100:.1f}% "
                f"drifts={res.drift_events} energy_J={energy:.0f}"))
    # ordering checks per scenario
    for scen in SCENARIOS:
        get = lambda n: results[(scen, n)].avg_accuracy
        dcst = get("DaCapo-Spatiotemporal")
        checks = {
            "dcst_beats_dcs": dcst >= get("DaCapo-Spatial") - 0.01,
            "dcst_beats_orin_ekya": dcst > get("OrinHigh-Ekya") - 0.01,
            "orinlow_weakest": get("OrinLow-Ekya") <= max(
                get(n) for n in SYSTEMS) + 1e-9,
        }
        rows.append((f"fig9/{scen}/ordering", 0.0,
                     " ".join(f"{k}={v}" for k, v in checks.items())))
    ratio = POWER_W["OrinHigh-Ekya"] / POWER_W["DaCapo-Spatiotemporal"]
    rows.append(("fig9/power_ratio", 0.0,
                 f"OrinHigh/DaCapo={ratio:.0f}x (paper 254x)"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
