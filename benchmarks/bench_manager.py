"""Fleet-manager benchmark: fault recovery, migration, overlapped
stepping, and estimator-driven placement.

Runs the sharded fleet tier (:class:`~repro.core.manager.FleetManager`,
N shards = N independent FleetSessions on their own sub-accelerators)
through five experiments on identical pretrained weights and an identical
virtual-clock budget:

* **recovery** — the same fleet twice: a no-fault baseline vs a run where
  one shard's accelerator is lost mid-run
  (:class:`~repro.runtime.fault.FailureInjector`, probed per round with
  ``key=shard_index``). The dead shard's lanes restore from their last
  per-lane durable checkpoint and re-home onto the survivors; the bench
  reports the accuracy cost of the fault, the explicitly-charged recovery
  seconds, and the manager/shard **ledger conservation gap** (must be ~0:
  every phase's T-SA seconds are charged once per tier);
* **migration** — migration-off (``static`` placement, lanes pinned where
  admitted) vs migration-on (``headroom`` placement: a drifted lane on an
  oversubscribed shard re-homes to the shard with T-SA headroom) at equal
  budget, on the bench_fleet drifting-camera fleet packed asymmetrically
  so the drifting camera starts on the loaded shard;
* **parallel** — serial (``parallel_shards=0``) vs overlapped
  (``parallel_shards=n``) round stepping at 2 and 4 shards.
  **Methodology, honestly:** this container is a 1-core CPU host, so
  jitted jax compute cannot overlap — what DOES overlap in the modeled
  system is each shard *waiting on its own sub-accelerator*. The bench
  emulates that blocking with the manager's ``shard_pace`` knob
  (host-seconds slept per modeled phase-second, inside ``step()``,
  touching no state), pace-calibrated from a pace-free probe run so the
  emulated device time is a fixed fraction of real host compute. Serial
  stepping pays every shard's wait back-to-back; the worker pool hides
  all but the slowest — the exact win overlapping gives on real
  hardware. Bit-identity of the two arms (accuracy, ledgers, decisions,
  events) is ASSERTED before the JSON is written; the headline
  ``manager_parallel_speedup`` is the 4-shard wall ratio;
* **placement** — ``headroom`` (lane-count balance) vs ``estimator``
  (seconds-based :class:`~repro.core.estimator.PlacementCostModel`) on a
  skewed fleet: shard 0 = both drifting cameras + one stable, shard 1 =
  two stables. The lane-count gap (1) sits below headroom's ``min_gap``
  hysteresis so headroom never migrates; the estimator reasons in
  seconds — it finds the move that lowers the fleet's load max and fires
  when the horizon-amortized T-SA gain beats ``migration_cost_s`` (which
  is charged to the manager ledger). A late admission demonstrates
  admission control: the estimator rejects it when every warm shard is
  past ``oversub_limit`` (surfaced as a ``reject`` action/event),
  headroom admits unconditionally;
* **scenario_matrix** — ``drift-pack`` vs ``headroom`` crossed with
  *aligned* vs *scattered* two-camera drift: the same S1/S3 drifters
  flipping simultaneously (packing their retraining bursts onto one
  T-SA pays) or staggered by half a segment (the payoff dilutes). The
  per-layout ``drift_pack_gain`` headline is the accuracy delta.

Writes ``BENCH_manager.json`` with, per experiment arm: mean fleet
accuracy, per-lane accuracies, rounds, ledger (T-SA / recovery /
migration seconds), events (fail/recover/migrate/reject counts) and host
wall time, plus the top-level ``manager_parallel_speedup`` headline.

Acceptance (asserted after the JSON is written): both recovery arms keep
every camera; the ledger conservation gap is ~0 in every arm; the faulted
run recovers (>=1 recover event) and lands within an accuracy tolerance
of the no-fault baseline; serial and overlapped arms are bit-identical;
the estimator arm migrates where headroom does not.

Run:  PYTHONPATH=src python benchmarks/bench_manager.py [--smoke]
          [--out F] [--fail-shard K] [--shards N] [--parallel N]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

# Importable both via benchmarks/run.py (repo root on sys.path) and as a
# standalone CLI (only benchmarks/ on sys.path).
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.bench_fleet import _hp, _pretrain, build_streams  # noqa: E402

# The faulted arm must land within this of the no-fault baseline. The
# dominant cost is not checkpoint staleness but budget dilution: after
# the round-3 loss every camera shares the surviving shard's single
# T-SA for the rest of the run, so per-lane retrain budget roughly
# halves fleet-wide (~0.2 accuracy on the smoke fleet).
ACCURACY_TOLERANCE = 0.3

# parallel section: emulated per-shard device wait as a fraction of the
# probe run's host compute (see bench_parallel's methodology note).
PACE_FRACTION = 0.75

# placement section: estimator admission ceiling — T-SA seconds per phase
# over the phase wall a shard may reach with one more lane aboard.
# Calibrated between the skewed fleet's stable-shard (~low) and
# drift-shard (~high) utilizations so the late admission is rejected once
# both shards are busy retraining.
OVERSUB_LIMIT = 0.5


def _manager(hp, smoke, **kw):
    from repro.configs.dacapo_pairs import RESNET18, WIDERESNET50
    from repro.core.fleet import FleetSpec
    from repro.core.manager import FleetManager
    from repro.core.mx import PrecisionPolicy

    spec = FleetSpec(student=RESNET18, teacher=WIDERESNET50, hp=hp,
                     policy=PrecisionPolicy(inference="mx9"),
                     apply_mx=False, seed=0, eval_fps=1.0,
                     dispatch="concurrent", fleet_mode="drift-weighted",
                     fleet_kwargs={"label_floor": 1.0, "drift_bias": 3.0,
                                   "gap_eps": 0.01})
    return FleetManager(spec, **kw)


def _summary(res, wall):
    counts = {}
    for e in res.events:
        counts[e.kind] = counts.get(e.kind, 0) + 1
    return {
        "fleet_avg_accuracy": round(res.fleet_avg_accuracy, 6),
        "per_lane_accuracy": {str(k): round(v.avg_accuracy, 6)
                              for k, v in sorted(res.lane_results.items(),
                                                 key=lambda kv: str(kv[0]))},
        "lanes": len(res.lane_results),
        "rounds": res.rounds,
        "parallel_rounds": res.parallel_rounds,
        "dead_shards": sum(1 for r in res.shard_results if r is None),
        "t_tsa_s": round(res.ledger["t_tsa"], 6),
        "recovery_cost_s": round(res.ledger["recovery_cost"], 6),
        "migration_cost_s": round(res.ledger.get("migration_cost", 0.0), 6),
        "conservation_gap": res.conservation_gap(),
        "events": counts,
        "wall_s": round(wall, 3),
    }


def _run(mgr, streams, duration, admissions=()):
    t0 = time.perf_counter()
    res = mgr.run(streams, duration=duration, admissions=admissions)
    return res, _summary(res, time.perf_counter() - t0)


def _assert_bit_identical(serial, overlapped, label):
    """Serial vs overlapped stepping must be bit-identical — not close,
    EQUAL: the pool only changes host scheduling, never modeled state."""
    assert serial.fleet_avg_accuracy == overlapped.fleet_avg_accuracy, label
    assert serial.ledger == overlapped.ledger, label
    assert serial.shard_ledgers == overlapped.shard_ledgers, label
    assert serial.rounds == overlapped.rounds, label
    assert serial.decisions == overlapped.decisions, label
    assert serial.events == overlapped.events, label
    sa = {str(k): v.avg_accuracy for k, v in serial.lane_results.items()}
    oa = {str(k): v.avg_accuracy for k, v in overlapped.lane_results.items()}
    assert sa == oa, label


def bench_recovery(n_shards, fail_shard, smoke, ckpt_root,
                   parallel=0) -> dict:
    """No-fault baseline vs mid-run shard loss with checkpoint recovery."""
    from repro.runtime.fault import FailureInjector

    duration = 90.0 if smoke else 180.0
    hp = _hp(smoke)
    streams = build_streams(3, smoke)
    tp, sp = _pretrain(streams, smoke)

    base = _manager(hp, smoke, n_shards=n_shards, migration=False,
                    parallel_shards=parallel,
                    checkpoint_dir=os.path.join(ckpt_root, "no_fault"),
                    checkpoint_every=2)
    base.set_pretrained(tp, sp)
    _, no_fault = _run(base, build_streams(3, smoke), duration)

    injector = FailureInjector(fail_at_steps=[(3, fail_shard)])
    faulted = _manager(hp, smoke, n_shards=n_shards, migration=False,
                       parallel_shards=parallel,
                       checkpoint_dir=os.path.join(ckpt_root, "fault"),
                       checkpoint_every=2, failure_injector=injector,
                       recovery_cost_s=2.0)
    faulted.set_pretrained(tp, sp)
    _, fault = _run(faulted, build_streams(3, smoke), duration)

    return {
        "no_fault": no_fault,
        "fault": fault,
        "fail_shard": fail_shard,
        "accuracy_delta": round(no_fault["fleet_avg_accuracy"]
                                - fault["fleet_avg_accuracy"], 6),
        "recovery_overhead_s": fault["recovery_cost_s"],
    }


def bench_migration(n_shards, smoke, parallel=0) -> dict:
    """static (no migration) vs headroom (drifted lanes re-home) at equal
    budget. The drifting camera is admitted first so static round-robin
    and headroom both start it on shard 0 next to a stable camera — the
    loaded shard headroom migrates it away from."""
    duration = 90.0 if smoke else 180.0
    hp = _hp(smoke)
    streams = build_streams(3, smoke)
    tp, sp = _pretrain(streams, smoke)

    out = {}
    for arm, kw in (
            ("off", {"placement": "static", "migration": False}),
            ("on", {"placement": "headroom",
                    "placement_kwargs": {"min_gap": 1},
                    "migration": True, "migration_cooldown": 2})):
        mgr = _manager(hp, smoke, n_shards=n_shards,
                       parallel_shards=parallel, **kw)
        mgr.set_pretrained(tp, sp)
        _, out[arm] = _run(mgr, build_streams(3, smoke), duration)
    out["accuracy_delta"] = round(out["on"]["fleet_avg_accuracy"]
                                  - out["off"]["fleet_avg_accuracy"], 6)
    out["migrations"] = out["on"]["events"].get("migrate", 0)
    return out


def bench_parallel(smoke) -> dict:
    """Serial vs overlapped round stepping at 2 and 4 shards.

    A pace-free probe measures pure host compute for one serial sweep;
    ``shard_pace`` is then set so each shard's emulated sub-accelerator
    wait over the run is ``PACE_FRACTION`` of that compute. Serial
    stepping pays the waits back-to-back (wall ~ C + N*P); the worker
    pool overlaps them (wall ~ C + P). Bit-identity of every arm pair is
    asserted before anything is reported."""
    duration = 90.0 if smoke else 180.0
    hp = _hp(smoke)
    streams = build_streams(4, smoke)
    tp, sp = _pretrain(streams, smoke)

    def make(n_shards, workers, pace):
        mgr = _manager(hp, smoke, n_shards=n_shards, placement="static",
                       migration=False, parallel_shards=workers,
                       shard_pace=pace)
        mgr.set_pretrained(tp, sp)
        return mgr

    t0 = time.perf_counter()
    make(2, 0, 0.0).run(build_streams(4, smoke), duration=duration)
    compute_wall = time.perf_counter() - t0
    # Each shard's modeled busy time over the run is ~`duration` virtual
    # seconds, so this pace makes one shard's emulated device wait equal
    # PACE_FRACTION x the probe's host compute.
    pace = PACE_FRACTION * compute_wall / duration

    out = {
        "methodology": ("1-core host: shard_pace emulates per-shard "
                        "sub-accelerator blocking; overlap hides it. "
                        "Serial/overlapped arms asserted bit-identical."),
        "host_cores": os.cpu_count(),
        "compute_only_wall_s": round(compute_wall, 3),
        "pace_fraction": PACE_FRACTION,
        "shard_pace": round(pace, 6),
    }
    for n in (2, 4):
        res_s, serial = _run(make(n, 0, pace), build_streams(4, smoke),
                             duration)
        res_p, par = _run(make(n, n, pace), build_streams(4, smoke),
                          duration)
        _assert_bit_identical(res_s, res_p, f"parallel/{n}_shards")
        assert serial["parallel_rounds"] == 0
        assert par["parallel_rounds"] > 0, "pool never engaged"
        out[f"{n}_shards"] = {
            "serial": serial, "overlapped": par,
            "wall_speedup": round(serial["wall_s"] / par["wall_s"], 3),
        }
    out["manager_parallel_speedup"] = out["4_shards"]["wall_speedup"]
    return out


def bench_placement(n_shards, smoke) -> dict:
    """headroom (lane counts) vs estimator (seconds) on a skewed fleet.

    Shard 0 starts with BOTH drifting cameras plus one stable camera,
    shard 1 with two stables — a lane-count gap of 1, below headroom's
    min_gap=2 hysteresis, so headroom never moves anything; but shard 0's
    T-SA *seconds* dominate the fleet's round wall, and the cost model
    finds the move that lowers the load max (shipping a lane off the hot
    shard pays because its seconds are smaller than the inter-shard gap)
    and fires once the horizon-amortized gain beats ``migration_cost_s``.
    A late admission lands unconditionally under headroom and is rejected
    by the estimator when every warm shard is past ``oversub_limit``."""
    from benchmarks.bench_fleet import build_multi_drift_streams

    duration = 90.0 if smoke else 180.0
    hp = _hp(smoke)
    probe = build_multi_drift_streams(6, smoke)
    tp, sp = _pretrain(probe, smoke)

    def skewed():
        # build_multi_drift_streams order: [drift_S1, drift_S3, stable x4].
        # Interleave so the alternating initial placement lands shard 0 =
        # {drift, drift, stable} and shard 1 = {stable, stable}; the last
        # stable camera is the late admission.
        s = build_multi_drift_streams(6, smoke)
        return [s[0], s[3], s[1], s[4], s[2]], s[5]

    out = {}
    for arm, kw in (
            ("headroom", {"placement": "headroom",
                          "migration": True, "migration_cooldown": 2,
                          "migration_cost_s": 2.0}),
            ("estimator", {"placement": "estimator",
                           "placement_kwargs": {
                               "migration_cost_s": 2.0,
                               "horizon_rounds": 4,
                               "oversub_limit": OVERSUB_LIMIT},
                           "migration": True, "migration_cooldown": 2,
                           "migration_cost_s": 2.0})):
        cams, late = skewed()
        mgr = _manager(hp, smoke, n_shards=n_shards, **kw)
        mgr.set_pretrained(tp, sp)
        _, out[arm] = _run(mgr, cams, duration,
                           admissions=[(duration * 0.55, "late", late)])
    out["migration_divergence"] = (
        out["estimator"]["events"].get("migrate", 0)
        - out["headroom"]["events"].get("migrate", 0))
    out["estimator_rejects"] = out["estimator"]["events"].get("reject", 0)
    out["accuracy_delta"] = round(
        out["estimator"]["fleet_avg_accuracy"]
        - out["headroom"]["fleet_avg_accuracy"], 6)
    return out


def build_scattered_drift_streams(n_streams: int, smoke: bool):
    """The *scattered* twin of bench_fleet's aligned multi-drift fleet.

    Same cameras — S1 and S3 drifters plus stable fillers — but the S3
    camera's first segment is halved, so every subsequent label flip
    lands mid-way between the S1 camera's flips. Aligned drift
    concentrates the retraining load into shared instants (the regime
    drift-pack consolidates onto one T-SA); scattered drift spreads it
    across the round, where lane-count balancing has less to lose."""
    import dataclasses as _dc

    from repro.data.stream import DriftStream, Segment, scenario

    seg_s = 30.0 if smoke else 45.0
    n_seg = 3 if smoke else 4

    def compressed(name):
        return [_dc.replace(s, duration_s=seg_s)
                for s in scenario(name, n_seg)]

    staggered = compressed("S3")
    staggered[0] = _dc.replace(staggered[0], duration_s=seg_s / 2)
    streams = [DriftStream(compressed("S1"), seed=17, img=24),
               DriftStream(staggered, seed=17, img=24)]
    for _ in range(max(0, n_streams - 2)):
        streams.append(DriftStream([Segment(duration_s=seg_s)] * n_seg,
                                   seed=17, img=24))
    return streams[:n_streams]


def bench_scenario_matrix(n_shards, smoke) -> dict:
    """drift-pack vs headroom across aligned vs scattered two-camera
    drift, at equal budget on identical pretrained weights.

    Aligned (bench_fleet's ``build_multi_drift_streams``): both cameras
    flip at the same instants — packing both drifters onto one shard
    lets their N_ldd bursts share a T-SA while the other shard serves
    undisturbed. Scattered (``build_scattered_drift_streams``): the same
    flips staggered by half a segment, diluting the payoff of packing.
    The headline ``drift_pack_gain`` per layout is drift-pack's fleet
    accuracy minus headroom's."""
    from benchmarks.bench_fleet import build_multi_drift_streams

    duration = 90.0 if smoke else 180.0
    hp = _hp(smoke)
    tp, sp = _pretrain(build_multi_drift_streams(4, smoke), smoke)

    builders = {"aligned": build_multi_drift_streams,
                "scattered": build_scattered_drift_streams}
    out = {"layouts": {}}
    for layout, build in builders.items():
        arms = {}
        for arm, kw in (
                ("drift-pack", {"placement": "drift-pack"}),
                ("headroom", {"placement": "headroom",
                              "placement_kwargs": {"min_gap": 1}})):
            mgr = _manager(hp, smoke, n_shards=n_shards, migration=True,
                           migration_cooldown=2, **kw)
            mgr.set_pretrained(tp, sp)
            _, arms[arm] = _run(mgr, build(4, smoke), duration)
        out["layouts"][layout] = arms
    out["drift_pack_gain"] = {
        layout: round(arms["drift-pack"]["fleet_avg_accuracy"]
                      - arms["headroom"]["fleet_avg_accuracy"], 6)
        for layout, arms in out["layouts"].items()}
    return out


def main(argv=None):
    import tempfile

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI")
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--fail-shard", type=int, default=1,
                    help="shard index the injector kills (CI matrix leg)")
    ap.add_argument("--parallel", type=int, default=0,
                    help="parallel_shards for the recovery/migration "
                         "sections (CI matrix leg; 0 = serial)")
    ap.add_argument("--out", default="BENCH_manager.json")
    args = ap.parse_args(argv)
    if not 0 <= args.fail_shard < args.shards:
        ap.error(f"--fail-shard must be in [0, {args.shards})")

    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory(prefix="bench_manager_ckpt_") as d:
        recovery = bench_recovery(args.shards, args.fail_shard,
                                  args.smoke, d, args.parallel)
    migration = bench_migration(args.shards, args.smoke, args.parallel)
    parallel = bench_parallel(args.smoke)
    placement = bench_placement(args.shards, args.smoke)
    scenario_matrix = bench_scenario_matrix(args.shards, args.smoke)
    result = {
        "bench": "manager",
        "mode": "smoke" if args.smoke else "full",
        "backend": jax.default_backend(),
        "n_shards": args.shards,
        "parallel_shards": args.parallel,
        "manager_parallel_speedup": parallel["manager_parallel_speedup"],
        "recovery": recovery,
        "migration": migration,
        "parallel": parallel,
        "placement": placement,
        "scenario_matrix": scenario_matrix,
    }

    # Write BEFORE the acceptance asserts so a failing comparison still
    # leaves the per-arm numbers to diagnose (CI uploads the file).
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    print(f"wrote {args.out} in {time.perf_counter() - t0:.1f}s")

    for arm in ("no_fault", "fault"):
        assert recovery[arm]["lanes"] == 3, \
            f"recovery/{arm}: a camera was lost"
        assert recovery[arm]["conservation_gap"] < 1e-6, \
            f"recovery/{arm}: manager/shard ledgers diverged"
    for arm in ("off", "on"):
        assert migration[arm]["conservation_gap"] < 1e-6, \
            f"migration/{arm}: manager/shard ledgers diverged"
        assert migration[arm]["lanes"] == 3
    assert recovery["fault"]["events"].get("fail", 0) == 1
    assert recovery["fault"]["events"].get("recover", 0) >= 1, \
        "the faulted run never recovered a lane"
    assert recovery["fault"]["dead_shards"] == 1
    assert recovery["accuracy_delta"] <= ACCURACY_TOLERANCE, \
        (f"fault cost {recovery['accuracy_delta']} fleet accuracy "
         f"(tolerance {ACCURACY_TOLERANCE})")
    # Overlapped stepping: bit-identity is asserted inside bench_parallel
    # (before any number is reported); here, the wall win must be real.
    floor = 1.3 if not args.smoke else 1.0
    assert parallel["manager_parallel_speedup"] > floor, \
        (f"4-shard overlap speedup "
         f"{parallel['manager_parallel_speedup']} <= {floor}")
    # Placement: seconds-based estimator must act where lane-count
    # headroom cannot (balanced counts, skewed seconds), pay the charged
    # migration cost, and reject the late oversubscribed admission.
    assert placement["migration_divergence"] >= 1, \
        "estimator never out-migrated headroom on the skewed fleet"
    assert placement["headroom"]["events"].get("migrate", 0) == 0, \
        "headroom migrated on balanced lane counts — scenario broken"
    est = placement["estimator"]
    assert est["migration_cost_s"] == pytest_approx(
        2.0 * est["events"].get("migrate", 0)), \
        "migration cost not charged per move"
    assert placement["estimator_rejects"] >= 1, \
        "estimator admitted the late camera on an oversubscribed fleet"
    assert placement["headroom"]["lanes"] == 6  # late camera admitted
    assert est["lanes"] == 5  # late camera rejected
    # Scenario matrix: every arm keeps all four cameras with conserved
    # ledgers in both drift layouts (which placement wins per layout is
    # the measured result, not an invariant).
    for layout, arms in scenario_matrix["layouts"].items():
        for arm in ("drift-pack", "headroom"):
            assert arms[arm]["lanes"] == 4, \
                f"scenario_matrix/{layout}/{arm}: a camera was lost"
            assert arms[arm]["conservation_gap"] < 1e-6, \
                f"scenario_matrix/{layout}/{arm}: ledgers diverged"
    return result


def pytest_approx(x, eps=1e-9):
    """Tiny float-compare helper (no pytest dependency in the bench)."""
    class _A:
        def __eq__(self, other):
            return abs(other - x) < eps
    return _A()


def run():
    """Registry entry (benchmarks/run.py): smoke manager sweep as CSV
    rows. Writes to a distinct file so a full BENCH_manager.json
    survives."""
    result = main(["--smoke", "--out", "BENCH_manager_smoke.json"])
    rows = []
    for arm in ("no_fault", "fault"):
        r = result["recovery"][arm]
        rows.append((f"manager/recovery/{arm}", r["wall_s"] * 1e6,
                     f"acc={r['fleet_avg_accuracy']}"))
    for arm in ("off", "on"):
        r = result["migration"][arm]
        rows.append((f"manager/migration/{arm}", r["wall_s"] * 1e6,
                     f"acc={r['fleet_avg_accuracy']}"))
    for n in (2, 4):
        for arm in ("serial", "overlapped"):
            r = result["parallel"][f"{n}_shards"][arm]
            rows.append((f"manager/parallel/{n}shard/{arm}",
                         r["wall_s"] * 1e6,
                         f"acc={r['fleet_avg_accuracy']}"))
    for arm in ("headroom", "estimator"):
        r = result["placement"][arm]
        rows.append((f"manager/placement/{arm}", r["wall_s"] * 1e6,
                     f"acc={r['fleet_avg_accuracy']}"))
    for layout, arms in result["scenario_matrix"]["layouts"].items():
        for arm, r in arms.items():
            rows.append((f"manager/scenario/{layout}/{arm}",
                         r["wall_s"] * 1e6,
                         f"acc={r['fleet_avg_accuracy']}"))
    return rows


if __name__ == "__main__":
    main()
