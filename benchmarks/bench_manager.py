"""Fleet-manager benchmark: fault-recovery overhead and live migration.

Runs the sharded fleet tier (:class:`~repro.core.manager.FleetManager`,
N shards = N independent FleetSessions on their own sub-accelerators)
through two experiments on identical pretrained weights and an identical
virtual-clock budget:

* **recovery** — the same fleet twice: a no-fault baseline vs a run where
  one shard's accelerator is lost mid-run
  (:class:`~repro.runtime.fault.FailureInjector`, probed per round with
  ``key=shard_index``). The dead shard's lanes restore from their last
  per-lane durable checkpoint and re-home onto the survivors; the bench
  reports the accuracy cost of the fault, the explicitly-charged recovery
  seconds, and the manager/shard **ledger conservation gap** (must be ~0:
  every phase's T-SA seconds are charged once per tier);
* **migration** — migration-off (``static`` placement, lanes pinned where
  admitted) vs migration-on (``headroom`` placement: a drifted lane on an
  oversubscribed shard re-homes to the shard with T-SA headroom) at equal
  budget, on the bench_fleet drifting-camera fleet packed asymmetrically
  so the drifting camera starts on the loaded shard.

Writes ``BENCH_manager.json`` with, per experiment arm: mean fleet
accuracy, per-lane accuracies, rounds, ledger (T-SA / recovery seconds),
events (fail/recover/migrate counts) and host wall time.

Acceptance (asserted after the JSON is written): both recovery arms keep
every camera; the ledger conservation gap is ~0 in every arm; the faulted
run recovers (>=1 recover event) and lands within an accuracy tolerance
of the no-fault baseline.

Run:  PYTHONPATH=src python benchmarks/bench_manager.py [--smoke]
          [--out F] [--fail-shard K] [--shards N]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

# Importable both via benchmarks/run.py (repo root on sys.path) and as a
# standalone CLI (only benchmarks/ on sys.path).
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.bench_fleet import _hp, _pretrain, build_streams  # noqa: E402

# The faulted arm must land within this of the no-fault baseline. The
# dominant cost is not checkpoint staleness but budget dilution: after
# the round-3 loss every camera shares the surviving shard's single
# T-SA for the rest of the run, so per-lane retrain budget roughly
# halves fleet-wide (~0.2 accuracy on the smoke fleet).
ACCURACY_TOLERANCE = 0.3


def _manager(hp, smoke, **kw):
    from repro.configs.dacapo_pairs import RESNET18, WIDERESNET50
    from repro.core.fleet import FleetSpec
    from repro.core.manager import FleetManager
    from repro.core.mx import PrecisionPolicy

    spec = FleetSpec(student=RESNET18, teacher=WIDERESNET50, hp=hp,
                     policy=PrecisionPolicy(inference="mx9"),
                     apply_mx=False, seed=0, eval_fps=1.0,
                     dispatch="concurrent", fleet_mode="drift-weighted",
                     fleet_kwargs={"label_floor": 1.0, "drift_bias": 3.0,
                                   "gap_eps": 0.01})
    return FleetManager(spec, **kw)


def _summary(res, wall):
    counts = {}
    for e in res.events:
        counts[e.kind] = counts.get(e.kind, 0) + 1
    return {
        "fleet_avg_accuracy": round(res.fleet_avg_accuracy, 6),
        "per_lane_accuracy": {str(k): round(v.avg_accuracy, 6)
                              for k, v in sorted(res.lane_results.items(),
                                                 key=lambda kv: str(kv[0]))},
        "lanes": len(res.lane_results),
        "rounds": res.rounds,
        "dead_shards": sum(1 for r in res.shard_results if r is None),
        "t_tsa_s": round(res.ledger["t_tsa"], 6),
        "recovery_cost_s": round(res.ledger["recovery_cost"], 6),
        "conservation_gap": res.conservation_gap(),
        "events": counts,
        "wall_s": round(wall, 3),
    }


def _run(mgr, streams, duration):
    t0 = time.perf_counter()
    res = mgr.run(streams, duration=duration)
    return res, _summary(res, time.perf_counter() - t0)


def bench_recovery(n_shards, fail_shard, smoke, ckpt_root) -> dict:
    """No-fault baseline vs mid-run shard loss with checkpoint recovery."""
    from repro.runtime.fault import FailureInjector

    duration = 90.0 if smoke else 180.0
    hp = _hp(smoke)
    streams = build_streams(3, smoke)
    tp, sp = _pretrain(streams, smoke)

    base = _manager(hp, smoke, n_shards=n_shards, migration=False,
                    checkpoint_dir=os.path.join(ckpt_root, "no_fault"),
                    checkpoint_every=2)
    base.set_pretrained(tp, sp)
    _, no_fault = _run(base, build_streams(3, smoke), duration)

    injector = FailureInjector(fail_at_steps=[(3, fail_shard)])
    faulted = _manager(hp, smoke, n_shards=n_shards, migration=False,
                       checkpoint_dir=os.path.join(ckpt_root, "fault"),
                       checkpoint_every=2, failure_injector=injector,
                       recovery_cost_s=2.0)
    faulted.set_pretrained(tp, sp)
    _, fault = _run(faulted, build_streams(3, smoke), duration)

    return {
        "no_fault": no_fault,
        "fault": fault,
        "fail_shard": fail_shard,
        "accuracy_delta": round(no_fault["fleet_avg_accuracy"]
                                - fault["fleet_avg_accuracy"], 6),
        "recovery_overhead_s": fault["recovery_cost_s"],
    }


def bench_migration(n_shards, smoke) -> dict:
    """static (no migration) vs headroom (drifted lanes re-home) at equal
    budget. The drifting camera is admitted first so static round-robin
    and headroom both start it on shard 0 next to a stable camera — the
    loaded shard headroom migrates it away from."""
    duration = 90.0 if smoke else 180.0
    hp = _hp(smoke)
    streams = build_streams(3, smoke)
    tp, sp = _pretrain(streams, smoke)

    out = {}
    for arm, kw in (
            ("off", {"placement": "static", "migration": False}),
            ("on", {"placement": "headroom",
                    "placement_kwargs": {"min_gap": 1},
                    "migration": True, "migration_cooldown": 2})):
        mgr = _manager(hp, smoke, n_shards=n_shards, **kw)
        mgr.set_pretrained(tp, sp)
        _, out[arm] = _run(mgr, build_streams(3, smoke), duration)
    out["accuracy_delta"] = round(out["on"]["fleet_avg_accuracy"]
                                  - out["off"]["fleet_avg_accuracy"], 6)
    out["migrations"] = out["on"]["events"].get("migrate", 0)
    return out


def main(argv=None):
    import tempfile

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI")
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--fail-shard", type=int, default=1,
                    help="shard index the injector kills (CI matrix leg)")
    ap.add_argument("--out", default="BENCH_manager.json")
    args = ap.parse_args(argv)
    if not 0 <= args.fail_shard < args.shards:
        ap.error(f"--fail-shard must be in [0, {args.shards})")

    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory(prefix="bench_manager_ckpt_") as d:
        recovery = bench_recovery(args.shards, args.fail_shard,
                                  args.smoke, d)
    migration = bench_migration(args.shards, args.smoke)
    result = {
        "bench": "manager",
        "mode": "smoke" if args.smoke else "full",
        "backend": jax.default_backend(),
        "n_shards": args.shards,
        "recovery": recovery,
        "migration": migration,
    }

    # Write BEFORE the acceptance asserts so a failing comparison still
    # leaves the per-arm numbers to diagnose (CI uploads the file).
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    print(f"wrote {args.out} in {time.perf_counter() - t0:.1f}s")

    for arm in ("no_fault", "fault"):
        assert recovery[arm]["lanes"] == 3, \
            f"recovery/{arm}: a camera was lost"
        assert recovery[arm]["conservation_gap"] < 1e-6, \
            f"recovery/{arm}: manager/shard ledgers diverged"
    for arm in ("off", "on"):
        assert migration[arm]["conservation_gap"] < 1e-6, \
            f"migration/{arm}: manager/shard ledgers diverged"
        assert migration[arm]["lanes"] == 3
    assert recovery["fault"]["events"].get("fail", 0) == 1
    assert recovery["fault"]["events"].get("recover", 0) >= 1, \
        "the faulted run never recovered a lane"
    assert recovery["fault"]["dead_shards"] == 1
    assert recovery["accuracy_delta"] <= ACCURACY_TOLERANCE, \
        (f"fault cost {recovery['accuracy_delta']} fleet accuracy "
         f"(tolerance {ACCURACY_TOLERANCE})")
    return result


def run():
    """Registry entry (benchmarks/run.py): smoke manager sweep as CSV
    rows. Writes to a distinct file so a full BENCH_manager.json
    survives."""
    result = main(["--smoke", "--out", "BENCH_manager_smoke.json"])
    rows = []
    for arm in ("no_fault", "fault"):
        r = result["recovery"][arm]
        rows.append((f"manager/recovery/{arm}", r["wall_s"] * 1e6,
                     f"acc={r['fleet_avg_accuracy']}"))
    for arm in ("off", "on"):
        r = result["migration"][arm]
        rows.append((f"manager/migration/{arm}", r["wall_s"] * 1e6,
                     f"acc={r['fleet_avg_accuracy']}"))
    return rows


if __name__ == "__main__":
    main()
