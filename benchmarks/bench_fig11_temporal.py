"""Fig. 11: temporal resource-allocation decisions — retraining vs labeling
time breakdown for DC-S vs DC-ST, plus the accuracy delta.

Paper: on drift, DC-ST allocates ~12.7% more time to labeling and gains
~5.9% accuracy over the spatial-only baseline.

Per-phase metrics come through the CLSession observer hook (structured
``PhaseRecord``s) rather than scraping the legacy phase_log dicts.
"""
from __future__ import annotations

import time

from benchmarks.common import run_system
from repro.configs.dacapo_pairs import PAIRS


def run():
    rows = []
    for student, teacher in PAIRS[:2]:
        t0 = time.time()
        st_records = []
        st = run_system("DaCapo-Spatiotemporal", student, teacher, "S1",
                        observers=(st_records.append,))
        sp = run_system("DaCapo-Spatial", student, teacher, "S1")
        us = (time.time() - t0) * 1e6

        def frac(res):
            tot = res.retrain_time + res.label_time
            return res.label_time / max(tot, 1e-9)

        # Observer-fed decision audit: how many phases ran with the boosted
        # N_ldd labeling budget (Alg. 1 line 13)?
        boosted = sum(1 for r in st_records
                      if r.decision.extra_label_samples > 0)
        rows.append((
            f"fig11/{student.name}+{teacher.name}", us,
            f"DC-ST label_frac={frac(st)*100:.1f}% "
            f"DC-S label_frac={frac(sp)*100:.1f}% "
            f"delta={100*(frac(st)-frac(sp)):+.1f}pp (paper +12.7pp) "
            f"acc_delta={(st.avg_accuracy-sp.avg_accuracy)*100:+.1f}pp "
            f"(paper +5.9pp) drifts={st.drift_events} "
            f"boosted_phases={boosted}/{len(st_records)}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
