"""Dispatch-layer benchmark: sequential vs. concurrent execution + fused
score-window inference.

Two measurements, written machine-readable to ``BENCH_dispatch.json`` (the
first entry of the bench trajectory):

* **session** — one `CLSession` per dispatch mode on a forced 2-row mesh,
  identical pretrained weights and stream: host wall-clock, executed phases,
  mean per-phase virtual time (sequential charges the T-SA sum, concurrent
  charges ``max(t_TSA, t_BSA)`` — see core/dispatch.py), and the number of
  jitted apply dispatches issued by the inference+labeling kernels.
* **scoring_fusion** — the eval/labeling inference path: scoring W frame
  windows one-jitted-call-per-window (the seed pattern) vs. ONE fused
  ``predict_batched`` call, with frames produced through the prefetching
  window iterator (`DriftStream.windows`) so host-side frame synthesis
  overlaps device work. Acceptance: fused issues fewer jitted calls.
* **fused** (PR 7) — the MX hot path itself: ``ops.mx_matmul_fused`` (the
  whole quantize→matmul chain as ONE program) against the unfused
  ``ops.mx_quantize``→``ops.mx_matmul`` pipeline (three programs with MX
  tensors materialized between them), measured in the container's serving
  kernel mode at the repo's hot-path GEMM sizes, bit-identity asserted per
  shape; plus the version-keyed serving-copy cache on repeated teacher
  labeling bursts (cached vs ``maxsize=0``). Headlines:
  ``fused_wall_speedup`` (geomean), ``fused_op_reduction`` (jitted
  programs per GEMM: 3 → 1), ``label_cache_speedup``. Acceptance: the op
  reduction is >= 2x (deterministic) and fused is never slower.
* **bwd_pair** (PR 9) — the retraining backward: both gradient GEMMs of a
  dense layer as ONE program (``ops.mx_matmul_bwd_pair``) vs the two
  independent fused launches they replace, bit-identity asserted per
  shape. Headline ``bwd_pair_speedup`` is the PROGRAM reduction per
  backward (2 → 1, measured via kernel_stats and asserted >= 2x,
  deterministic) — the launch-count win the fusion exists for; the raw
  wall times of both arms are reported per shape, with the caveat that
  the CPU interpreter's per-step emulation cost scales with the number
  of kernel operands, so its wall ratio under-reports what a native
  single launch saves.
* **serve_prequant** (PR 9) — the weight-resident serving path: quantize
  the weight ONCE (``ops.mx_quantize_rhs``), serve every window through
  ``ops.mx_matmul_prequant``, vs the fused GEMM re-quantizing the weight
  in-program every window. Bit-identity asserted per window; kernel_stats
  proves per-window weight-quantization ops drop to ZERO after the fill
  (asserted). Headline ``serve_prequant_speedup``.

Both PR 9 sections also re-run the PR 7 no-silent-ref-fallback audit over
every hot-path op they dispatch.

Run:  PYTHONPATH=src python benchmarks/bench_dispatch.py [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np


def _session_stats(res, session, wall_s: float) -> dict:
    recs = res.records
    dts = [r.t - r.phase_start for r in recs]
    return {
        "wall_s": round(wall_s, 3),
        "phases": len(recs),
        "virtual_end_s": round(recs[-1].t, 4) if recs else 0.0,
        "mean_phase_dt_s": round(float(np.mean(dts)), 6) if dts else 0.0,
        "mean_t_tsa_s": round(float(np.mean([r.t_tsa for r in recs])), 6)
        if recs else 0.0,
        "mean_t_bsa_s": round(float(np.mean([r.t_bsa for r in recs])), 6)
        if recs else 0.0,
        "avg_accuracy": round(res.avg_accuracy, 6),
        "jit_calls": (session.inference.n_apply_calls
                      + session.labeling.n_apply_calls),
    }


def bench_session(smoke: bool) -> dict:
    from repro.configs.dacapo_pairs import RESNET18, WIDERESNET50
    from repro.core.allocation import CLHyperParams
    from repro.core.partition import forced_row_mesh
    from repro.core.session import CLSystemSpec, pretrain_model
    from repro.data.stream import DriftStream, scenario
    from repro.models.registry import make_vision_model

    duration = 20.0 if smoke else 60.0
    hp = CLHyperParams(n_t=32 if smoke else 48, n_l=16 if smoke else 24,
                       c_b=128 if smoke else 192, epochs=1)
    stream = DriftStream(scenario("S1", 2 if smoke else 3), seed=5, img=24)
    rng = np.random.default_rng(0)
    steps = (10, 8) if smoke else (25, 15)
    tp = pretrain_model(make_vision_model(WIDERESNET50.reduced()), stream,
                        steps[0], 32, rng)
    sp = pretrain_model(make_vision_model(RESNET18.reduced()), stream,
                        steps[1], 32, rng, segments=stream.segments[:1],
                        seed=8)

    # Forced 2-row mesh: T-SA and B-SA become disjoint sub-meshes so the
    # concurrent mode's overlap model matches the bound placement.
    mesh = forced_row_mesh(2)
    base = CLSystemSpec(student=RESNET18, teacher=WIDERESNET50, hp=hp,
                        allocator="dacapo-spatiotemporal", apply_mx=False,
                        seed=0, eval_fps=0.5, mesh=mesh)

    out = {"duration_s": duration}
    for mode in ("sequential", "concurrent"):
        session = dataclasses.replace(base, dispatch=mode).build()
        session.set_pretrained(tp, sp)
        t0 = time.perf_counter()
        res = session.run(stream, duration=duration)
        wall = time.perf_counter() - t0
        out[mode] = _session_stats(res, session, wall)
    seq_dt, con_dt = (out["sequential"]["mean_phase_dt_s"],
                      out["concurrent"]["mean_phase_dt_s"])
    out["virtual_phase_speedup"] = round(seq_dt / con_dt, 4) if con_dt else 0
    return out


def bench_scoring_fusion(smoke: bool) -> dict:
    from repro.configs.dacapo_pairs import RESNET18
    from repro.core.estimator import DaCapoEstimator
    from repro.core.kernel import InferenceKernel
    from repro.data.stream import DriftStream, scenario
    from repro.models.registry import make_vision_model

    n_windows = 6 if smoke else 16
    frames_per_window = 8 if smoke else 24
    stream = DriftStream(scenario("S1", 2), seed=5, img=24)
    model = make_vision_model(RESNET18.reduced())
    params = model.init(jax.random.PRNGKey(0))
    kernel = InferenceKernel(model, RESNET18, DaCapoEstimator(),
                             apply_mx=False)

    window_s = frames_per_window / stream.fps
    spans_end = n_windows * window_s

    def gather():
        it = stream.windows(0.0, spans_end, window_s,
                            max_frames=frames_per_window, prefetch=3)
        return [(x, y) for _, _, x, y in it]

    windows = gather()
    total = sum(len(x) for x, _ in windows)

    # Warm both jit paths (per-window shape and fused shape).
    np.asarray(kernel.predict_async(params, windows[0][0]))
    [np.asarray(p) for p in
     kernel.predict_batched(params, [x for x, _ in windows])]

    kernel.n_apply_calls = 0
    t0 = time.perf_counter()
    preds_pw = [kernel.predict_async(params, x) for x, _ in windows]
    preds_pw = [np.asarray(p) for p in preds_pw]
    wall_pw = time.perf_counter() - t0
    calls_pw = kernel.n_apply_calls

    kernel.n_apply_calls = 0
    t0 = time.perf_counter()
    preds_f = kernel.predict_batched(params, [x for x, _ in windows])
    preds_f = [np.asarray(p) for p in preds_f]
    wall_f = time.perf_counter() - t0
    calls_f = kernel.n_apply_calls

    assert all(np.array_equal(a, b) for a, b in zip(preds_pw, preds_f)), \
        "fused predictions diverge from per-window predictions"
    assert calls_f < calls_pw, \
        f"fusion must issue fewer jitted calls ({calls_f} !< {calls_pw})"

    return {
        "n_windows": n_windows,
        "frames_per_window": frames_per_window,
        "per_window": {"jit_calls": calls_pw, "wall_s": round(wall_pw, 4),
                       "frames_per_s": round(total / wall_pw, 1)},
        "fused": {"jit_calls": calls_f, "wall_s": round(wall_f, 4),
                  "frames_per_s": round(total / wall_f, 1)},
        "call_reduction": round(calls_pw / calls_f, 2),
    }


def _wall_us(fn, reps: int) -> float:
    fn()  # warm (jit compile / trace)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def bench_fused(smoke: bool) -> dict:
    from repro.kernels import ops

    # The repo's hot-path GEMM sizes (img=24 models: small M, modest K/N —
    # where per-program dispatch overhead is a real fraction of the GEMM).
    shapes = ([(16, 432, 64), (32, 128, 64)] if smoke
              else [(16, 432, 64), (32, 128, 64), (64, 256, 128)])
    reps = 5 if smoke else 30
    per_shape = {}
    speedups = []
    for m, k, n in shapes:
        a = jax.random.normal(jax.random.PRNGKey(0), (m, k))
        b = jax.random.normal(jax.random.PRNGKey(1), (k, n))
        # Bit-identity first: fused must equal the unfused chain exactly.
        fused0 = np.asarray(ops.mx_matmul_fused(a, b, "mx6", "mx6"))
        unfused0 = np.asarray(ops.mx_matmul(a, b, "mx6", "mx6"))
        assert np.array_equal(fused0, unfused0), \
            f"fused != unfused at {(m, k, n)}"
        ops.reset_kernel_stats()
        wall_u = _wall_us(lambda: jax.block_until_ready(
            ops.mx_matmul(a, b, "mx6", "mx6")), reps)
        stats = ops.kernel_stats()
        ops_unfused = sum(sum(p.values()) for op, p in stats.items()
                          if op != "mx_matmul_fused") / (reps + 1)
        ops.reset_kernel_stats()
        wall_f = _wall_us(lambda: jax.block_until_ready(
            ops.mx_matmul_fused(a, b, "mx6", "mx6")), reps)
        ops_fused = sum(
            ops.kernel_stats()["mx_matmul_fused"].values()) / (reps + 1)
        ops.reset_kernel_stats()
        speedup = wall_u / wall_f
        speedups.append(speedup)
        per_shape[f"{m}x{k}x{n}"] = {
            "unfused_us": round(wall_u, 1), "fused_us": round(wall_f, 1),
            "wall_speedup": round(speedup, 2),
            "ops_per_gemm_unfused": ops_unfused,
            "ops_per_gemm_fused": ops_fused,
        }
    op_reduction = (per_shape[next(iter(per_shape))]["ops_per_gemm_unfused"]
                    / per_shape[next(iter(per_shape))]["ops_per_gemm_fused"])
    assert op_reduction >= 2.0, \
        f"fused must at least halve the jitted-op count ({op_reduction})"
    return {
        "kernel_mode": ops.kernel_mode(),
        "shapes": per_shape,
        "fused_wall_speedup": round(
            float(np.exp(np.mean(np.log(speedups)))), 2),
        "fused_op_reduction": round(op_reduction, 2),
    }


def _assert_no_silent_ref(ops_mod, op_names) -> None:
    """The PR 7 audit, extended: in a non-ref serving mode every listed op
    must have been served by its kernel path — zero silent ref fallbacks."""
    stats = ops_mod.kernel_stats()
    mode = ops_mod.kernel_mode()
    for op in op_names:
        assert op in stats, (op, stats)
        if mode != "ref":
            assert "ref" not in stats[op], (op, stats)


def bench_bwd_pair(smoke: bool) -> dict:
    """The retraining backward: dX + dW as ONE program vs two fused GEMMs."""
    from repro.kernels import ops

    shapes = ([(16, 432, 64), (32, 128, 64)] if smoke
              else [(16, 432, 64), (32, 128, 64), (64, 256, 128)])
    reps = 5 if smoke else 30
    per_shape = {}
    speedups = []
    for m, k, n in shapes:
        g = jax.random.normal(jax.random.PRNGKey(2), (m, n))
        x = jax.random.normal(jax.random.PRNGKey(3), (m, k))
        w = jax.random.normal(jax.random.PRNGKey(4), (k, n))
        # Bit-identity first: the pair must equal the two-GEMM chain.
        dx, dw = ops.mx_matmul_bwd_pair(g, x, w, "mx9")
        assert np.array_equal(np.asarray(dx), np.asarray(
            ops.mx_matmul_fused(g, w.T, "mx9", "mx9"))), (m, k, n)
        assert np.array_equal(np.asarray(dw), np.asarray(
            ops.mx_matmul_fused(x.T, g, "mx9", "mx9"))), (m, k, n)

        def two_gemms():
            jax.block_until_ready(ops.mx_matmul_fused(g, w.T, "mx9", "mx9"))
            jax.block_until_ready(ops.mx_matmul_fused(x.T, g, "mx9", "mx9"))

        ops.reset_kernel_stats()
        wall_u = _wall_us(two_gemms, reps)
        progs_unfused = sum(
            ops.kernel_stats()["mx_matmul_fused"].values()) / (reps + 1)
        ops.reset_kernel_stats()
        wall_p = _wall_us(lambda: jax.block_until_ready(
            ops.mx_matmul_bwd_pair(g, x, w, "mx9")), reps)
        progs_pair = sum(
            ops.kernel_stats()["mx_matmul_bwd_pair"].values()) / (reps + 1)
        _assert_no_silent_ref(ops, ["mx_matmul_bwd_pair"])
        ops.reset_kernel_stats()
        speedup = wall_u / wall_p
        speedups.append(speedup)
        per_shape[f"{m}x{k}x{n}"] = {
            "two_gemms_us": round(wall_u, 1), "pair_us": round(wall_p, 1),
            "wall_speedup": round(speedup, 2),
            "programs_per_bwd_unfused": progs_unfused,
            "programs_per_bwd_pair": progs_pair,
        }
    first = per_shape[next(iter(per_shape))]
    program_reduction = (first["programs_per_bwd_unfused"]
                         / first["programs_per_bwd_pair"])
    assert program_reduction >= 2.0, \
        f"the pair must halve the backward program count ({program_reduction})"
    # The headline is the deterministic program reduction (2 GEMM launches
    # + duplicate g-quantization -> 1 launch); the wall geomean is reported
    # alongside but is an emulation artifact on CPU hosts (see module doc).
    return {
        "kernel_mode": ops.kernel_mode(),
        "shapes": per_shape,
        "bwd_pair_speedup": round(program_reduction, 2),
        "bwd_pair_program_reduction": round(program_reduction, 2),
        "bwd_pair_wall_speedup": round(
            float(np.exp(np.mean(np.log(speedups)))), 2),
    }


def bench_serve_prequant(smoke: bool) -> dict:
    """Weight-resident serving: quantize the weight once, then serve every
    window with zero weight-quantization work — vs the fused GEMM that
    re-quantizes the weight inside every program."""
    from repro.kernels import ops

    n_windows = 6 if smoke else 16
    reps = 5 if smoke else 20
    m, k, n = (32, 256, 64)
    xs = [jax.random.normal(jax.random.PRNGKey(100 + i), (m, k))
          for i in range(n_windows)]
    w = jax.random.normal(jax.random.PRNGKey(5), (k, n))
    qw = ops.mx_quantize_rhs(w, "mx6")  # the one-time fill

    # Bit-identity per window: resident serving == re-quantizing serving.
    for x in xs:
        assert np.array_equal(
            np.asarray(ops.mx_matmul_prequant(x, qw, "mx6")),
            np.asarray(ops.mx_matmul_fused(x, w, "mx6", "mx6")))

    # Op accounting over one serving sweep: after the fill (1 mx_quantize,
    # counted above at qw creation — redone here under reset for the
    # audit), the per-window weight-quantization op count is exactly zero.
    ops.reset_kernel_stats()
    qw2 = ops.mx_quantize_rhs(w, "mx6")
    for x in xs:
        jax.block_until_ready(ops.mx_matmul_prequant(x, qw2, "mx6"))
    stats = ops.kernel_stats()
    fill_quants = sum(stats.get("mx_quantize", {}).values())
    serve_calls = sum(stats["mx_matmul_prequant"].values())
    assert fill_quants == 1, stats
    assert serve_calls == n_windows, stats
    weight_quants_per_window = (fill_quants - 1) / n_windows
    assert weight_quants_per_window == 0.0, stats
    _assert_no_silent_ref(ops, ["mx_matmul_prequant"])
    ops.reset_kernel_stats()

    def serve_resident():
        for x in xs:
            jax.block_until_ready(ops.mx_matmul_prequant(x, qw, "mx6"))

    def serve_requant():
        for x in xs:
            jax.block_until_ready(ops.mx_matmul_fused(x, w, "mx6", "mx6"))

    wall_r = _wall_us(serve_resident, reps)
    wall_q = _wall_us(serve_requant, reps)
    ops.reset_kernel_stats()
    return {
        "kernel_mode": ops.kernel_mode(),
        "gemm": f"{m}x{k}x{n}",
        "n_windows": n_windows,
        "resident_us": round(wall_r, 1),
        "requant_us": round(wall_q, 1),
        "weight_quant_ops_per_window": weight_quants_per_window,
        "serve_prequant_speedup": round(wall_q / wall_r, 2),
    }


def bench_label_cache(smoke: bool) -> dict:
    """Repeated teacher labeling bursts, apply_mx=True: the version-keyed
    serving cache quantizes the teacher tree ONCE; the ``maxsize=0``
    baseline re-quantizes it every burst (the pre-PR behavior)."""
    from repro.configs.dacapo_pairs import WIDERESNET50
    from repro.core.estimator import DaCapoEstimator
    from repro.core.kernel import LabelingKernel, ServingParamsCache
    from repro.models.registry import make_vision_model

    burst, reps = 4, (5 if smoke else 20)
    model = make_vision_model(WIDERESNET50.reduced())
    params = model.init(jax.random.PRNGKey(0))
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1),
                                     (burst, 24, 24, 3)), np.float32)
    cached = LabelingKernel(model, WIDERESNET50, DaCapoEstimator(),
                            apply_mx=True)
    uncached = LabelingKernel(model, WIDERESNET50, DaCapoEstimator(),
                              apply_mx=True)
    uncached.serving_cache = ServingParamsCache(maxsize=0)
    y_c = cached.label(params, x, "mx6")  # warm both paths
    y_u = uncached.label(params, x, "mx6")
    assert np.array_equal(y_c, y_u), "cache changed the labels"
    wall_c = _wall_us(lambda: cached.label(params, x, "mx6"), reps)
    wall_u = _wall_us(lambda: uncached.label(params, x, "mx6"), reps)
    stats = cached.serving_cache.stats()
    assert stats["misses"] == 1 and stats["hits"] >= reps, stats
    return {
        "burst_frames": burst,
        "cached_us": round(wall_c, 1), "uncached_us": round(wall_u, 1),
        "label_cache_speedup": round(wall_u / wall_c, 2),
        "cache_stats": stats,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI")
    ap.add_argument("--out", default="BENCH_dispatch.json")
    args = ap.parse_args()

    fused = bench_fused(args.smoke)
    bwd_pair = bench_bwd_pair(args.smoke)
    serve_prequant = bench_serve_prequant(args.smoke)
    label_cache = bench_label_cache(args.smoke)
    result = {
        "bench": "dispatch",
        "mode": "smoke" if args.smoke else "full",
        "backend": jax.default_backend(),
        "scoring_fusion": bench_scoring_fusion(args.smoke),
        "fused": fused,
        "bwd_pair": bwd_pair,
        "serve_prequant": serve_prequant,
        "label_cache": label_cache,
        "fused_wall_speedup": fused["fused_wall_speedup"],
        "fused_op_reduction": fused["fused_op_reduction"],
        "bwd_pair_speedup": bwd_pair["bwd_pair_speedup"],
        "bwd_pair_program_reduction": bwd_pair["bwd_pair_program_reduction"],
        "serve_prequant_speedup": serve_prequant["serve_prequant_speedup"],
        "label_cache_speedup": label_cache["label_cache_speedup"],
        "session": bench_session(args.smoke),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    print(f"\nwrote {args.out}")


def run():
    """Registry entry (benchmarks/run.py): smoke measurements as CSV rows."""
    fusion = bench_scoring_fusion(True)
    fused = bench_fused(True)
    bwd_pair = bench_bwd_pair(True)
    serve_prequant = bench_serve_prequant(True)
    cache = bench_label_cache(True)
    session = bench_session(True)
    return [
        ("dispatch/scoring_fused", fusion["fused"]["wall_s"] * 1e6,
         f"call_reduction={fusion['call_reduction']}"),
        ("dispatch/mx_fused",
         next(iter(fused["shapes"].values()))["fused_us"],
         f"wall_speedup={fused['fused_wall_speedup']}"
         f";op_reduction={fused['fused_op_reduction']}"),
        ("dispatch/mx_bwd_pair",
         next(iter(bwd_pair["shapes"].values()))["pair_us"],
         f"wall_speedup={bwd_pair['bwd_pair_speedup']}"
         f";program_reduction={bwd_pair['bwd_pair_program_reduction']}"),
        ("dispatch/serve_prequant", serve_prequant["resident_us"],
         f"speedup={serve_prequant['serve_prequant_speedup']}"
         f";weight_quants_per_window="
         f"{serve_prequant['weight_quant_ops_per_window']}"),
        ("dispatch/label_cache", cache["cached_us"],
         f"speedup={cache['label_cache_speedup']}"),
        ("dispatch/session_sequential",
         session["sequential"]["wall_s"] * 1e6,
         f"phase_dt={session['sequential']['mean_phase_dt_s']}"),
        ("dispatch/session_concurrent",
         session["concurrent"]["wall_s"] * 1e6,
         f"phase_dt={session['concurrent']['mean_phase_dt_s']}"
         f";virtual_speedup={session['virtual_phase_speedup']}"),
    ]


if __name__ == "__main__":
    main()
