"""Shared benchmark machinery: system variants (paper §VII-A baselines),
cached pretraining, CSV emission."""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.configs.dacapo_pairs import PAIRS, VisionConfig
from repro.core.cl_system import ContinuousLearningSystem, pretrain_model
from repro.core.estimator import DaCapoEstimator, TPUEstimator
from repro.core.scheduler import CLHyperParams
from repro.data.stream import DriftStream, scenario

FAST = bool(int(os.environ.get("REPRO_BENCH_FAST", "0")))


@dataclasses.dataclass(frozen=True)
class OrinEstimator(TPUEstimator):
    """NVIDIA Jetson Orin model (paper Table IV): FP32 only — no MX
    bandwidth/compute benefit; high (60 W, default clocks) or low (30 W,
    624.8 MHz) power envelope."""

    total_rows: int = 16  # normalized resource units, same split API
    peak_flops: float = 5.3e12 * 0.45  # sustained fp32
    hbm_bw: float = 204.8e9
    mx_speedup = {"mx4": 1.0, "mx6": 1.0, "mx9": 1.0}  # FP32 everywhere

    def forward_time(self, cfg, rows, precision, batch=1):
        from repro.core.estimator import vision_gemms

        flops = sum(2 * m * n * k for m, n, k in vision_gemms(cfg, batch))
        bytes_moved = sum((m * k + k * n + m * n) * 4
                          for m, n, k in vision_gemms(cfg, batch))
        frac = rows / self.total_rows
        t_c = flops / (self.peak_flops * frac)
        t_m = bytes_moved / (self.hbm_bw * frac)
        return max(t_c, t_m)


def orin_estimator(power: str) -> OrinEstimator:
    scale = 1.0 if power == "high" else 0.45
    return OrinEstimator(peak_flops=5.3e12 * 0.45 * scale,
                         hbm_bw=204.8e9 * (1.0 if power == "high" else 0.7))


# (name, estimator factory, allocator, apply_mx)
SYSTEMS = {
    "OrinLow-Ekya": (lambda: orin_estimator("low"), "ekya", False),
    "OrinHigh-Ekya": (lambda: orin_estimator("high"), "ekya", False),
    "OrinHigh-EOMU": (lambda: orin_estimator("high"), "eomu", False),
    "DaCapo-Ekya": (DaCapoEstimator, "ekya", True),
    "DaCapo-Spatial": (DaCapoEstimator, "dacapo-spatial", True),
    "DaCapo-Spatiotemporal": (DaCapoEstimator, "dacapo-spatiotemporal", True),
}

POWER_W = {"OrinLow-Ekya": 30.0, "OrinHigh-Ekya": 60.0,
           "OrinHigh-EOMU": 60.0, "DaCapo-Ekya": 0.236,
           "DaCapo-Spatial": 0.236, "DaCapo-Spatiotemporal": 0.236}

_PRETRAIN_CACHE: Dict[Tuple, Tuple] = {}


def default_hp() -> CLHyperParams:
    if FAST:
        return CLHyperParams(n_t=48, n_l=24, c_b=192, epochs=1)
    return CLHyperParams(n_t=96, n_l=48, c_b=384, epochs=1)


def make_stream(scen: str, n_segments: Optional[int] = None) -> DriftStream:
    n = n_segments or (3 if FAST else 5)
    return DriftStream(scenario(scen, n), seed=17, img=24)


def pretrained(student: VisionConfig, teacher: VisionConfig,
               stream_key: str, stream: DriftStream):
    key = (student.name, teacher.name, stream_key)
    if key not in _PRETRAIN_CACHE:
        rng = np.random.default_rng(0)
        probe = ContinuousLearningSystem(student, teacher,
                                         apply_mx_numerics=False)
        t_steps, s_steps = (30, 20) if FAST else (120, 45)
        tp = pretrain_model(probe.teacher, stream, t_steps, 48, rng)
        sp = pretrain_model(probe.student, stream, s_steps, 48, rng,
                            segments=stream.segments[:1], seed=8)
        _PRETRAIN_CACHE[key] = (tp, sp)
    return _PRETRAIN_CACHE[key]


def run_system(name: str, student: VisionConfig, teacher: VisionConfig,
               scen: str, duration: Optional[float] = None,
               hp: Optional[CLHyperParams] = None):
    est_fn, allocator, apply_mx = SYSTEMS[name]
    stream = make_stream(scen)
    hp = hp or default_hp()
    sys_ = ContinuousLearningSystem(
        student, teacher, hp=hp, estimator=est_fn(), allocator=allocator,
        apply_mx_numerics=apply_mx, eval_fps=0.5)
    tp, sp = pretrained(student, teacher, scen, stream)
    sys_.set_pretrained(tp, sp)
    dur = duration or (90.0 if FAST else 180.0)
    return sys_.run(stream, duration=dur)


def emit(rows):
    """Print 'name,us_per_call,derived' CSV rows."""
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
