"""Shared benchmark machinery: system variants (paper §VII-A baselines) as
declarative ``CLSystemSpec`` entries, cached pretraining, CSV emission."""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.configs.dacapo_pairs import PAIRS, VisionConfig
from repro.core.allocation import CLHyperParams
from repro.core.estimator import DaCapoEstimator, TPUEstimator
from repro.core.session import CLSystemSpec, PhaseObserver, pretrain_model
from repro.data.stream import DriftStream, scenario
from repro.models.registry import make_vision_model

FAST = bool(int(os.environ.get("REPRO_BENCH_FAST", "0")))


@dataclasses.dataclass(frozen=True)
class OrinEstimator(TPUEstimator):
    """NVIDIA Jetson Orin model (paper Table IV): FP32 only — no MX
    bandwidth/compute benefit; high (60 W, default clocks) or low (30 W,
    624.8 MHz) power envelope. Reuses the TPU roofline in fractional-rows
    mode: rows are shares of one device, not whole chips."""

    total_rows: int = 16  # normalized resource units, same split API
    peak_flops: float = 5.3e12 * 0.45  # sustained fp32
    hbm_bw: float = 204.8e9
    fractional_rows: bool = True
    mx_speedup = {"mx4": 1.0, "mx6": 1.0, "mx9": 1.0}  # FP32 everywhere


def orin_estimator(power: str) -> OrinEstimator:
    scale = 1.0 if power == "high" else 0.45
    return OrinEstimator(peak_flops=5.3e12 * 0.45 * scale,
                         hbm_bw=204.8e9 * (1.0 if power == "high" else 0.7))


# Each system variant is a declarative spec; run_system fills in the model
# pair, hyper-parameters and scenario-specific bits via dataclasses.replace.
SYSTEMS: Dict[str, CLSystemSpec] = {
    "OrinLow-Ekya": CLSystemSpec(
        estimator=lambda: orin_estimator("low"), allocator="ekya",
        apply_mx=False),
    "OrinHigh-Ekya": CLSystemSpec(
        estimator=lambda: orin_estimator("high"), allocator="ekya",
        apply_mx=False),
    "OrinHigh-EOMU": CLSystemSpec(
        estimator=lambda: orin_estimator("high"), allocator="eomu",
        apply_mx=False),
    "DaCapo-Ekya": CLSystemSpec(
        estimator=DaCapoEstimator, allocator="ekya", apply_mx=True),
    "DaCapo-Spatial": CLSystemSpec(
        estimator=DaCapoEstimator, allocator="dacapo-spatial", apply_mx=True),
    "DaCapo-Spatiotemporal": CLSystemSpec(
        estimator=DaCapoEstimator, allocator="dacapo-spatiotemporal",
        apply_mx=True),
}

POWER_W = {"OrinLow-Ekya": 30.0, "OrinHigh-Ekya": 60.0,
           "OrinHigh-EOMU": 60.0, "DaCapo-Ekya": 0.236,
           "DaCapo-Spatial": 0.236, "DaCapo-Spatiotemporal": 0.236}

_PRETRAIN_CACHE: Dict[Tuple, Tuple] = {}


def default_hp() -> CLHyperParams:
    if FAST:
        return CLHyperParams(n_t=48, n_l=24, c_b=192, epochs=1)
    return CLHyperParams(n_t=96, n_l=48, c_b=384, epochs=1)


def make_stream(scen: str, n_segments: Optional[int] = None) -> DriftStream:
    n = n_segments or (3 if FAST else 5)
    return DriftStream(scenario(scen, n), seed=17, img=24)


def pretrained(student: VisionConfig, teacher: VisionConfig,
               stream_key: str, stream: DriftStream):
    key = (student.name, teacher.name, stream_key)
    if key not in _PRETRAIN_CACHE:
        rng = np.random.default_rng(0)
        teacher_model = make_vision_model(teacher.reduced())
        student_model = make_vision_model(student.reduced())
        t_steps, s_steps = (30, 20) if FAST else (120, 45)
        tp = pretrain_model(teacher_model, stream, t_steps, 48, rng)
        sp = pretrain_model(student_model, stream, s_steps, 48, rng,
                            segments=stream.segments[:1], seed=8)
        _PRETRAIN_CACHE[key] = (tp, sp)
    return _PRETRAIN_CACHE[key]


def run_system(name: str, student: VisionConfig, teacher: VisionConfig,
               scen: str, duration: Optional[float] = None,
               hp: Optional[CLHyperParams] = None,
               observers: Sequence[PhaseObserver] = ()):
    spec = dataclasses.replace(SYSTEMS[name], student=student,
                               teacher=teacher, hp=hp or default_hp(),
                               eval_fps=0.5)
    stream = make_stream(scen)
    session = spec.build()
    tp, sp = pretrained(student, teacher, scen, stream)
    session.set_pretrained(tp, sp)
    dur = duration or (90.0 if FAST else 180.0)
    return session.run(stream, duration=dur, observers=observers)


def emit(rows):
    """Print 'name,us_per_call,derived' CSV rows."""
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
