"""Trace-replay benchmark: prediction accuracy and the replay-scored policy.

Two sections, one JSON artifact (``BENCH_replay.json``):

* **prediction** — runs a traced DC-ST session under each dispatch
  semantics and replays every recorded phase through
  :class:`~repro.core.replay.TraceReplayer`:

  - ``exact_phases`` / ``replay_sequential_exact`` — phases whose end
    clock the replayer reconstructs *bitwise* (must be all of them, in
    both modes: replay walks the plan's own float-add sequence);
  - ``replay_phase_time_mape`` — mean absolute percentage error of the
    genuinely predictive path: ``predict(from_units=True)`` re-prices
    every program from trace-wide per-label cost histograms (what a
    candidate scorer uses for budgets the trace never ran) against the
    recorded concurrent phase times;
  - ``calibration`` — the per-kernel wall/virtual scale factors
    :meth:`~repro.core.replay.TraceReplayer.calibrate` fits for
    :class:`~repro.core.estimator.CalibratedEstimator`.

* **policy** — DC-ST vs the ``"dacapo-replay"`` allocator on identical
  pretrained weights over a concurrent session with real serving load
  (eval_fps high enough that the B-SA chain bounds the phase): replay
  scores K retrain-budget boosts per phase against the recorded last
  phase and only accepts boosts that fit the B-SA slack. The headline
  ``replay_policy_gain`` is the accuracy delta; the replay arm charges
  its measured scoring wall to ``profile_cost_s`` on the T-SA ledger
  (``charged_profile_s`` reports both arms' totals).

Run:  PYTHONPATH=src python benchmarks/bench_replay.py [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import json
import os
import time

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np


def _pretrained(smoke: bool):
    from repro.configs.dacapo_pairs import RESNET18, WIDERESNET50
    from repro.core.allocation import CLHyperParams
    from repro.core.session import pretrain_model
    from repro.data.stream import DriftStream, scenario
    from repro.models.registry import make_vision_model

    del smoke  # the policy arms need real pretraining to show the gain
    stream = DriftStream(scenario("S1", 3), seed=5, img=24)
    hp = CLHyperParams(n_t=48, n_l=24, c_b=192, epochs=1)
    rng = np.random.default_rng(0)
    tp = pretrain_model(make_vision_model(WIDERESNET50.reduced()), stream,
                        25, 32, rng)
    sp = pretrain_model(make_vision_model(RESNET18.reduced()), stream, 15,
                        32, rng, segments=stream.segments[:1], seed=8)
    return stream, hp, tp, sp


def _session(hp, allocator, dispatch, trace, eval_fps):
    from repro.configs.dacapo_pairs import RESNET18, WIDERESNET50
    from repro.core.session import CLSystemSpec

    return CLSystemSpec(student=RESNET18, teacher=WIDERESNET50,
                        allocator=allocator, hp=hp, apply_mx=False, seed=0,
                        eval_fps=eval_fps, dispatch=dispatch,
                        trace=trace).build()


def bench_prediction(setup, smoke: bool) -> dict:
    from repro.core.replay import TraceReplayer
    from repro.core.trace import SessionTrace

    stream, hp, tp, sp = setup
    duration = 45.0 if smoke else 90.0
    out = {}
    for mode in ("sequential", "concurrent"):
        session = _session(hp, "dacapo-spatiotemporal", mode, True, 0.5)
        session.set_pretrained(tp, sp)
        t0 = time.perf_counter()
        session.run(stream, duration=duration)
        wall = time.perf_counter() - t0
        trace = session.dispatcher.recorder.trace
        # Round-trip through JSON first: the offline-analysis path must be
        # as exact as the in-memory one.
        rep = TraceReplayer(SessionTrace.from_json(trace.to_json()), hp=hp)
        exact = sum(1 for i, ph in enumerate(trace.phases)
                    if rep.phase_time(i) == ph.end)
        errs = [abs(rep.predict(i, from_units=True) - ph.end) / ph.end
                for i, ph in enumerate(trace.phases) if ph.end > 0]
        cal = rep.calibrate()
        out[mode] = {
            "phases": len(trace.phases),
            "events": sum(len(ph.events) for ph in trace.phases),
            "exact_phases": exact,
            "bitwise_exact": exact == len(trace.phases),
            "from_units_mape_pct": round(
                100.0 * float(np.mean(errs)), 6) if errs else 0.0,
            "wall_s": round(wall, 3),
            "calibration": {
                "global_scale": round(cal.global_scale, 6),
                "scales": {k: round(v, 6) for k, v in cal.scales.items()},
            },
        }
    return out


def bench_policy(setup, smoke: bool) -> dict:
    stream, hp, tp, sp = setup
    duration = 60.0 if smoke else 90.0
    out = {}
    for allocator in ("dacapo-spatiotemporal", "dacapo-replay"):
        session = _session(hp, allocator, "concurrent", None, 2.0)
        session.set_pretrained(tp, sp)
        t0 = time.perf_counter()
        res = session.run(stream, duration=duration)
        wall = time.perf_counter() - t0
        charged = sum(r.decision.profile_cost_s for r in res.records)
        boosted = sum(
            1 for r in res.records
            if r.decision.retrain_samples > res.records[0]
            .decision.retrain_samples)
        out[allocator] = {
            "avg_accuracy": round(res.avg_accuracy, 6),
            "phases": len(res.records),
            "drift_events": res.drift_events,
            "retrain_time": round(res.retrain_time, 6),
            "boosted_phases": boosted,
            "charged_profile_s": round(charged, 6),
            "wall_s": round(wall, 3),
        }
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="shorter sessions for CI")
    ap.add_argument("--out", default="BENCH_replay.json")
    args = ap.parse_args(argv)

    setup = _pretrained(args.smoke)
    result = {
        "bench": "replay",
        "mode": "smoke" if args.smoke else "full",
        "backend": jax.default_backend(),
    }
    t0 = time.perf_counter()
    result["prediction"] = bench_prediction(setup, args.smoke)
    print(f"# prediction done in {time.perf_counter() - t0:.1f}s",
          flush=True)
    t0 = time.perf_counter()
    result["policy"] = bench_policy(setup, args.smoke)
    print(f"# policy done in {time.perf_counter() - t0:.1f}s", flush=True)

    # Headlines (check_artifacts.py requires both).
    result["replay_phase_time_mape"] = result["prediction"]["concurrent"][
        "from_units_mape_pct"]
    result["replay_policy_gain"] = round(
        result["policy"]["dacapo-replay"]["avg_accuracy"]
        - result["policy"]["dacapo-spatiotemporal"]["avg_accuracy"], 6)

    # Write BEFORE the acceptance asserts so a failing run still uploads
    # the numbers needed to diagnose it.
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    print(f"wrote {args.out}")

    # Acceptance: replay is exact on the virtual clock in BOTH dispatch
    # semantics, the histogram-priced concurrent predictions land within
    # 5% MAPE, and the replay-scored policy never loses accuracy to DC-ST
    # while paying for its own scoring on the ledger.
    for mode in ("sequential", "concurrent"):
        assert result["prediction"][mode]["bitwise_exact"], \
            f"{mode}: replay not bitwise exact"
    assert result["replay_phase_time_mape"] < 5.0, \
        f"concurrent MAPE {result['replay_phase_time_mape']}% >= 5%"
    assert result["replay_policy_gain"] >= 0.0, \
        f"dacapo-replay lost accuracy: {result['replay_policy_gain']}"
    assert result["policy"]["dacapo-replay"]["boosted_phases"] > 0, \
        "replay policy never accepted a boost"
    assert result["policy"]["dacapo-replay"]["charged_profile_s"] > 0, \
        "replay scoring wall never charged to profile_cost_s"
    return result


def run():
    """Registry entry (benchmarks/run.py): smoke pass as CSV rows. Writes
    to a distinct file so a full BENCH_replay.json survives."""
    result = main(["--smoke", "--out", "BENCH_replay_smoke.json"])
    rows = []
    for mode, stats in result["prediction"].items():
        rows.append((f"replay/predict/{mode}", stats["wall_s"] * 1e6,
                     f"exact={stats['exact_phases']}/{stats['phases']}"
                     f";mape={stats['from_units_mape_pct']}"))
    for allocator, stats in result["policy"].items():
        rows.append((f"replay/policy/{allocator}", stats["wall_s"] * 1e6,
                     f"acc={stats['avg_accuracy']}"
                     f";boosted={stats['boosted_phases']}"))
    rows.append(("replay/policy_gain", 0.0,
                 f"gain={result['replay_policy_gain']}"))
    return rows


if __name__ == "__main__":
    main()
