"""Multi-camera fleet benchmark: cross-stream T-SA allocation policies.

Runs an N-stream heterogeneous fleet — one camera drifting (label
distribution flips each compressed segment) next to stable cameras parked
in the student's pretraining context — through
:class:`~repro.core.fleet.FleetSession` under three cross-stream split
modes on identical pretrained weights and an identical virtual-clock
budget:

* ``drift-weighted`` — the :class:`~repro.core.allocation.FleetAllocator`
  routes the shared T-SA's labeling/retraining budget to the cameras whose
  accuracy-loss signal (and drift flags) say they need it;
* ``uniform`` — every camera gets ``1/N`` of the budget every phase;
* ``isolated`` — the no-fleet baseline: every camera keeps a full
  per-session budget, so the shared T-SA serializes ~N sessions' worth of
  work per phase (N isolated sessions time-sharing the accelerator) and
  each stream's update cadence is ~N× slower.

A second dimension sweeps the fleet's *spatial* plane: a multi-lane-drift
fleet (two cameras flipping their label distributions on aligned segment
boundaries next to one stable camera) runs under each
:class:`~repro.core.decision.FleetRowPolicy` — ``resolve-max`` (the static
baseline), ``drift-surge`` (grow the fleet T-SA under multi-lane drift,
with hysteresis) and ``weighted-vote`` (rows follow the drift-weighted
temporal shares) — at equal virtual-clock budget and identical weights.

Writes ``BENCH_fleet.json`` with, per mode: mean fleet accuracy,
per-stream accuracies/drifts, fleet phases executed, the per-phase shared
T-SA time (the equal-budget check: uniform and drift-weighted spend ~one
session's T-SA budget per phase, isolated ~N×), speculation counters, and
host wall time; and per row policy: mean fleet accuracy, fleet phases,
rows-over-time stats (mean/max T-SA rows, spatial re-allocations); plus
the batched B-SA serve microbench (PR 7: every lane's score windows in
ONE vmapped program per phase — headline
``fleet_batched_serve_speedup``, the per-phase program reduction).

Acceptance (asserted after the JSON is written): the drift-weighted fleet
beats BOTH uniform and isolated on mean fleet accuracy, and the best
adaptive row policy (drift-surge or weighted-vote) beats resolve-max on
mean fleet accuracy in the multi-lane-drift scenario.

Run:  PYTHONPATH=src python benchmarks/bench_fleet.py [--smoke] [--out F]
          [--streams N] [--row-policy P]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np

MODES = ("drift-weighted", "uniform", "isolated")
ROW_POLICIES = ("resolve-max", "drift-surge", "weighted-vote")


def build_streams(n_streams: int, smoke: bool):
    """One hard-drifting camera + (n-1) static-context cameras.

    All cameras share one stream seed — the same visual world (class
    patterns, textures), the paper's multi-camera deployment — and differ
    only in their segment timelines: camera 0 flips its label distribution
    every (compressed) segment (S1), while the static cameras sit in the
    student's pretraining context. Budget spent on the static cameras is
    mostly wasted; camera 0 is where labeling/retraining pays — the signal
    the drift-weighted allocator should find."""
    from repro.data.stream import DriftStream, Segment, scenario

    seg_s = 30.0 if smoke else 45.0
    n_seg = 3 if smoke else 4
    drifting = [dataclasses.replace(s, duration_s=seg_s)
                for s in scenario("S1", n_seg)]
    streams = [DriftStream(drifting, seed=17, img=24)]
    for _ in range(n_streams - 1):
        stable = [Segment(duration_s=seg_s)] * n_seg
        streams.append(DriftStream(stable, seed=17, img=24))
    return streams


def build_multi_drift_streams(n_streams: int, smoke: bool):
    """The multi-lane-drift scenario for the row-policy sweep.

    Two cameras drift on *aligned* segment boundaries — camera 0 through
    the compressed S1 timeline, camera 1 through S3 with identical segment
    lengths, so their label distributions flip at the same instants but to
    different contexts — next to (n-2) stable cameras. Simultaneous
    multi-lane drift is exactly the regime the adaptive row policies
    (drift-surge quorum, weighted-vote boost) react to and the static
    resolve-max baseline cannot."""
    import dataclasses as _dc

    from repro.data.stream import DriftStream, Segment, scenario

    seg_s = 30.0 if smoke else 45.0
    n_seg = 3 if smoke else 4

    def compressed(name):
        return [_dc.replace(s, duration_s=seg_s)
                for s in scenario(name, n_seg)]

    streams = [DriftStream(compressed("S1"), seed=17, img=24),
               DriftStream(compressed("S3"), seed=17, img=24)]
    for _ in range(max(0, n_streams - 2)):
        streams.append(DriftStream([Segment(duration_s=seg_s)] * n_seg,
                                   seed=17, img=24))
    return streams[:n_streams]


def _hp(smoke: bool):
    from repro.core.allocation import CLHyperParams

    # Retraining-heavy economics: labels (the teacher is the expensive
    # kernel) are detection infrastructure every camera keeps in full
    # (label_floor=1.0); the contended budget the modes split is
    # retraining + the N_ldd drift bursts. v_thr widened for n_l=16 label
    # counts (the default -0.10 was tuned for 32..48-label estimates).
    return (CLHyperParams(n_t=64, n_l=16, c_b=192, epochs=1, v_thr=-0.25)
            if smoke
            else CLHyperParams(n_t=96, n_l=24, c_b=256, epochs=1,
                               v_thr=-0.25))


def _pretrain(streams, smoke: bool):
    """Shared pretraining: teacher across the whole attribute space of the
    (first) drifting camera; student on the stable context only
    (segments[:1]) and to convergence, so stable cameras start at their
    ceiling and budget routed to them is genuinely wasted."""
    import numpy as np

    from repro.configs.dacapo_pairs import RESNET18, WIDERESNET50
    from repro.core.session import pretrain_model
    from repro.models.registry import make_vision_model

    rng = np.random.default_rng(0)
    steps = (30, 40) if smoke else (60, 60)
    tp = pretrain_model(make_vision_model(WIDERESNET50.reduced()),
                        streams[0], steps[0], 32, rng)
    sp = pretrain_model(make_vision_model(RESNET18.reduced()), streams[0],
                        steps[1], 32, rng,
                        segments=streams[0].segments[:1], seed=8)
    return tp, sp


def bench_fleet(n_streams: int, smoke: bool) -> dict:
    from repro.configs.dacapo_pairs import RESNET18, WIDERESNET50
    from repro.core.fleet import FleetSpec

    from repro.core.mx import PrecisionPolicy

    duration = 90.0 if smoke else 180.0
    hp = _hp(smoke)
    streams = build_streams(n_streams, smoke)
    # Deeper pretraining than the other smoke benches: the drift detector
    # compares teacher labels against student predictions, so both must be
    # real models for the drift signal — the thing this bench allocates on
    # — to carry information instead of noise.
    tp, sp = _pretrain(streams, smoke)

    # MX9 serving -> the balanced (8, 8) offline split (the mx6 default
    # would leave the B-SA 2 rows and crush every mode's keep_frac).
    # label_floor=1.0: every camera keeps its full n_l labels per phase so
    # every drift detector stays reliable — only retraining and the drift
    # bursts (extra_label_samples) are re-proportioned across the fleet.
    base = FleetSpec(student=RESNET18, teacher=WIDERESNET50, hp=hp,
                     policy=PrecisionPolicy(inference="mx9"),
                     apply_mx=False, seed=0, eval_fps=1.0,
                     dispatch="concurrent",
                     fleet_kwargs={"label_floor": 1.0, "drift_bias": 3.0,
                                   "gap_eps": 0.01})
    out = {}
    for mode in MODES:
        fleet = dataclasses.replace(base, fleet_mode=mode).build()
        fleet.set_pretrained(tp, sp)
        t0 = time.perf_counter()
        fres = fleet.run(streams, duration=duration)
        wall = time.perf_counter() - t0
        spec_hits = sum(r.spec_hits for lane in fres.streams
                        for r in lane.records)
        spec_misses = sum(r.spec_misses for lane in fres.streams
                          for r in lane.records)
        out[mode] = {
            "fleet_avg_accuracy": round(fres.fleet_avg_accuracy, 6),
            "per_stream_accuracy": [round(r.avg_accuracy, 6)
                                    for r in fres.streams],
            "per_stream_drifts": [r.drift_events for r in fres.streams],
            "fleet_phases": len(fres.fleet_phase_log),
            # Equal-budget check: per-phase shared-T-SA seconds.
            "mean_phase_t_tsa_s": round(float(np.mean(
                [e["t_tsa"] for e in fres.fleet_phase_log])), 6)
            if fres.fleet_phase_log else 0.0,
            "spec_hits": spec_hits,
            "spec_misses": spec_misses,
            "wall_s": round(wall, 3),
        }
    return out


def bench_row_policies(n_streams: int, smoke: bool,
                       only: str = None) -> dict:
    """The spatial-plane dimension: the multi-lane-drift fleet under each
    FleetRowPolicy at equal virtual-clock budget, identical weights, and
    the drift-weighted temporal split throughout — the only variable is
    who resolves the fleet's per-phase row split."""
    from repro.configs.dacapo_pairs import RESNET18, WIDERESNET50
    from repro.core.fleet import FleetSpec
    from repro.core.mx import PrecisionPolicy

    duration = 90.0 if smoke else 180.0
    hp = _hp(smoke)
    streams = build_multi_drift_streams(n_streams, smoke)
    tp, sp = _pretrain(streams, smoke)

    base = FleetSpec(student=RESNET18, teacher=WIDERESNET50, hp=hp,
                     policy=PrecisionPolicy(inference="mx9"),
                     apply_mx=False, seed=0, eval_fps=1.0,
                     dispatch="concurrent", fleet_mode="drift-weighted",
                     fleet_kwargs={"label_floor": 1.0, "drift_bias": 3.0,
                                   "gap_eps": 0.01})
    out = {}
    for rp in (ROW_POLICIES if only is None else (only,)):
        fleet = dataclasses.replace(base, row_policy=rp).build()
        fleet.set_pretrained(tp, sp)
        t0 = time.perf_counter()
        fres = fleet.run(streams, duration=duration)
        wall = time.perf_counter() - t0
        rows = [(e["rows_tsa"], e["rows_bsa"])
                for e in fres.fleet_phase_log]
        out[rp] = {
            "fleet_avg_accuracy": round(fres.fleet_avg_accuracy, 6),
            "per_stream_accuracy": [round(r.avg_accuracy, 6)
                                    for r in fres.streams],
            "per_stream_drifts": [r.drift_events for r in fres.streams],
            "fleet_phases": len(fres.fleet_phase_log),
            "mean_rows_tsa": round(float(np.mean([r for r, _ in rows])), 3)
            if rows else 0.0,
            "max_rows_tsa": max((r for r, _ in rows), default=0),
            "spatial_moves": sum(a != b for a, b in zip(rows, rows[1:])),
            "wall_s": round(wall, 3),
        }
    return out


def bench_batched_serve(smoke: bool) -> dict:
    """Batched fleet serving (PR 7): L lanes' score windows through ONE
    vmapped B-SA program (``InferenceKernel.predict_fleet_async``) vs one
    fused predict per lane. The headline ``fleet_batched_serve_speedup``
    is the per-phase B-SA *program* reduction (L programs → 1) — the
    device-dispatch metric the fused serve targets; host wall times for
    both paths are reported alongside (on a CPU host the vmapped stacked
    apply is not wall-faster — there is no second sub-accelerator to
    overlap with)."""
    from repro.configs.dacapo_pairs import RESNET18
    from repro.core.estimator import DaCapoEstimator
    from repro.core.kernel import InferenceKernel
    from repro.models.registry import make_vision_model

    n_lanes = 3 if smoke else 4
    frames = 16 if smoke else 24
    reps = 5 if smoke else 15
    model = make_vision_model(RESNET18.reduced())
    trees = [model.init(jax.random.PRNGKey(i)) for i in range(n_lanes)]
    rngs = [jax.random.PRNGKey(100 + i) for i in range(n_lanes)]
    wins = [np.asarray(jax.random.normal(r, (frames, 24, 24, 3)),
                       np.float32) for r in rngs]
    kernel = InferenceKernel(model, RESNET18, DaCapoEstimator(),
                             apply_mx=False)

    def per_lane():
        outs = [kernel.predict_async(t, w) for t, w in zip(trees, wins)]
        jax.block_until_ready(outs)
        return outs

    def batched():
        outs = kernel.predict_fleet_async(trees, wins)
        jax.block_until_ready(outs)
        return outs

    preds_pl = [np.asarray(p) for p in per_lane()]  # warm both jit paths
    preds_b = [np.asarray(p) for p in batched()]
    acc_gap = max(float((a != b).mean())
                  for a, b in zip(preds_pl, preds_b))

    kernel.n_apply_calls = 0
    t0 = time.perf_counter()
    for _ in range(reps):
        per_lane()
    wall_pl = (time.perf_counter() - t0) / reps
    calls_pl = kernel.n_apply_calls / reps

    kernel.n_apply_calls = 0
    t0 = time.perf_counter()
    for _ in range(reps):
        batched()
    wall_b = (time.perf_counter() - t0) / reps
    calls_b = kernel.n_apply_calls / reps

    assert calls_b < calls_pl, "batched serve must issue fewer programs"
    return {
        "n_lanes": n_lanes,
        "frames_per_lane": frames,
        "per_lane": {"programs": calls_pl, "wall_s": round(wall_pl, 4)},
        "batched": {"programs": calls_b, "wall_s": round(wall_b, 4)},
        "prediction_disagreement": acc_gap,  # vmapped apply ulp drift
        "fleet_batched_serve_speedup": round(calls_pl / calls_b, 2),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI")
    ap.add_argument("--streams", type=int, default=3)
    ap.add_argument("--out", default="BENCH_fleet.json")
    ap.add_argument("--row-policy", default=None, choices=ROW_POLICIES,
                    help="run the row-policy sweep for ONE policy only "
                         "(CI matrix entry; skips the cross-policy "
                         "acceptance assert)")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    # A single-policy run (CI matrix) skips the temporal-mode sweep: the
    # dimension under test is the spatial plane.
    modes = (bench_fleet(args.streams, args.smoke)
             if args.row_policy is None else {})
    row_policies = bench_row_policies(args.streams, args.smoke,
                                      only=args.row_policy)
    batched_serve = bench_batched_serve(args.smoke)
    result = {
        "bench": "fleet",
        "mode": "smoke" if args.smoke else "full",
        "backend": jax.default_backend(),
        "n_streams": args.streams,
        "modes": modes,
        "row_policies": row_policies,
        "batched_serve": batched_serve,
        "fleet_batched_serve_speedup":
            batched_serve["fleet_batched_serve_speedup"],
    }
    if modes:
        result["fleet_accuracy_gain_vs_uniform"] = round(
            modes["drift-weighted"]["fleet_avg_accuracy"]
            - modes["uniform"]["fleet_avg_accuracy"], 6)
        result["fleet_accuracy_gain_vs_isolated"] = round(
            modes["drift-weighted"]["fleet_avg_accuracy"]
            - modes["isolated"]["fleet_avg_accuracy"], 6)
    if len(row_policies) == len(ROW_POLICIES):
        result["row_policy_gain"] = round(
            max(row_policies["drift-surge"]["fleet_avg_accuracy"],
                row_policies["weighted-vote"]["fleet_avg_accuracy"])
            - row_policies["resolve-max"]["fleet_avg_accuracy"], 6)

    # Write BEFORE the acceptance asserts so a failing comparison still
    # leaves the per-mode numbers to diagnose (CI uploads the file).
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    print(f"wrote {args.out} in {time.perf_counter() - t0:.1f}s")

    if modes:
        dw = modes["drift-weighted"]["fleet_avg_accuracy"]
        assert dw > modes["uniform"]["fleet_avg_accuracy"], \
            "drift-weighted must beat the uniform split on fleet accuracy"
        assert dw > modes["isolated"]["fleet_avg_accuracy"], \
            "drift-weighted must beat isolated sessions on fleet accuracy"
    if "row_policy_gain" in result:
        assert result["row_policy_gain"] > 0, \
            ("an adaptive row policy (drift-surge or weighted-vote) must "
             "beat resolve-max on mean fleet accuracy under multi-lane "
             "drift")
    return result


def run():
    """Registry entry (benchmarks/run.py): smoke fleet sweep as CSV rows.
    Writes to a distinct file so a full-sweep BENCH_fleet.json survives."""
    result = main(["--smoke", "--out", "BENCH_fleet_smoke.json"])
    return ([(f"fleet/{mode}",
              result["modes"][mode]["wall_s"] * 1e6,
              f"acc={result['modes'][mode]['fleet_avg_accuracy']}")
             for mode in MODES]
            + [(f"fleet/rows/{rp}",
                result["row_policies"][rp]["wall_s"] * 1e6,
                f"acc={result['row_policies'][rp]['fleet_avg_accuracy']}")
               for rp in ROW_POLICIES])


if __name__ == "__main__":
    main()
