"""Fig. 3: MAC/FLOP breakdown of the three CL kernels over a 120 s run.

The paper shows retraining's share rising from 26% to 82% of total FLOPs as
the labeling sampling rate and retraining epochs increase, with inference
falling 57.8% -> 9.1% and labeling 27.1% -> 7.0%. We reproduce the sweep
analytically from the same estimator that drives Algorithm 1.
"""
from __future__ import annotations

import time

from repro.configs.dacapo_pairs import RESNET18, WIDERESNET50
from repro.models.registry import make_vision_model

WINDOW_S = 120.0
FPS = 30.0


def kernel_flops(sample_rate_hz: float, epochs: int):
    student = make_vision_model(RESNET18)
    teacher = make_vision_model(WIDERESNET50)
    n_frames = WINDOW_S * FPS
    n_samples = WINDOW_S * sample_rate_hz
    infer = n_frames * student.flops()
    label = n_samples * teacher.flops()
    retrain = n_samples * epochs * 3 * student.flops()
    total = infer + label + retrain
    return infer / total, retrain / total, label / total, total


def run():
    rows = []
    t0 = time.time()
    # sweep: (sampling rate, epochs) from light to heavy retraining configs
    for rate, epochs in [(0.5, 1), (1.0, 3), (2.0, 5), (4.0, 10), (6.0, 15)]:
        fi, fr, fl, total = kernel_flops(rate, epochs)
        rows.append((
            f"fig3/rate{rate}_ep{epochs}", (time.time() - t0) * 1e6,
            f"inference={fi*100:.1f}% retraining={fr*100:.1f}% "
            f"labeling={fl*100:.1f}% total_tflops={total/1e12:.1f}"))
    # assertions of the paper's qualitative claim
    fi0, fr0, _, _ = kernel_flops(0.5, 1)
    fi1, fr1, _, _ = kernel_flops(6.0, 15)
    ok = fr1 > fr0 and fi1 < fi0 and fr1 > 0.7 and fr0 < 0.4
    rows.append(("fig3/trend_check", 0.0,
                 f"retrain_share {fr0*100:.1f}%->{fr1*100:.1f}% "
                 f"(paper 26%->82%) PASS={ok}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
