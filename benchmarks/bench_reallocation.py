"""Online spatial re-allocation benchmark: DC-ST vs DC-ST-Online.

Runs both policies over every paper scenario (S1-S6 regular, ES1/ES2
extreme, Table II) on identical pretrained weights, through a concurrent
session consuming an explicit :class:`~repro.data.pipeline.FramePipeline`
handle, and writes ``BENCH_reallocation.json`` with, per scenario and
policy:

* ``avg_accuracy`` / ``drift_events`` / ``phases`` — learning outcome;
* ``rows_over_time`` — ``[t, rows_tsa, rows_bsa]`` per phase: the online
  policy's drift-time row boosts and hysteresis returns, flat for DC-ST;
* ``speculation`` — the pipeline's reconcile counters (hit rate must be
  > 0: concurrent dispatch is actually issuing programs against prefetched
  windows);
* ``wall_s`` / ``mean_phase_dt_s`` — host wall time and mean virtual phase
  time.

A third variant (``dacapo-spatiotemporal+nohints``) re-runs DC-ST with
decision-aware speculation disabled — the labeling burst replayed from the
last layout instead of pre-sized with the next decision's budget — and the
sweep reports ``decision_aware_hit_rate_delta``: how much hit rate the
decision-aware predictor recovers (drift phases change the burst size, so
pure replay always misses them).

Scenario segments are compressed (60 s -> 30 s, 15 s in smoke) so drift —
and with it the re-allocation path — fires inside bench timescales. The
serving precision is pinned to MX9 so the offline split is the balanced
(8, 8) where row moves change both sides' throughput materially, and the
forced 4-row mesh makes each boost re-fission the T-SA/B-SA sub-meshes.

Run:  PYTHONPATH=src python benchmarks/bench_reallocation.py [--smoke]
          [--out F] [--scenarios S1,ES1]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np

POLICIES = ("dacapo-spatiotemporal", "dacapo-spatiotemporal-online")
# (policy, decision_aware_spec) per measured variant.
VARIANTS = {
    "dacapo-spatiotemporal": ("dacapo-spatiotemporal", True),
    "dacapo-spatiotemporal-online": ("dacapo-spatiotemporal-online", True),
    "dacapo-spatiotemporal+nohints": ("dacapo-spatiotemporal", False),
}


def _stats(res, pipe, wall_s: float) -> dict:
    recs = res.records
    dts = [r.t - r.phase_start for r in recs]
    return {
        "avg_accuracy": round(res.avg_accuracy, 6),
        "drift_events": res.drift_events,
        "phases": len(recs),
        "wall_s": round(wall_s, 3),
        "mean_phase_dt_s": round(float(np.mean(dts)), 6) if dts else 0.0,
        "rows_over_time": [
            [round(r.t, 4), r.decision.rows_tsa, r.decision.rows_bsa]
            for r in recs],
        "rows_moved_phases": sum(
            1 for r in recs if r.decision.rows_tsa != recs[0].decision.rows_tsa),
        "speculation": {
            "hits": pipe.stats.hits,
            "misses": pipe.stats.misses,
            "hit_rate": round(pipe.stats.hit_rate, 4),
            "windows_speculated": pipe.stats.windows_speculated,
            "windows_wasted": pipe.stats.windows_wasted,
        },
    }


def bench_scenario(scen: str, smoke: bool) -> dict:
    from repro.configs.dacapo_pairs import RESNET18, WIDERESNET50
    from repro.core.allocation import CLHyperParams
    from repro.core.mx import PrecisionPolicy
    from repro.core.partition import forced_row_mesh
    from repro.core.session import CLSystemSpec, pretrain_model
    from repro.data.pipeline import FramePipeline
    from repro.data.stream import DriftStream, scenario
    from repro.models.registry import make_vision_model

    seg_s = 15.0 if smoke else 30.0
    n_seg = 4 if smoke else 5
    duration = 45.0 if smoke else 120.0
    segs = [dataclasses.replace(s, duration_s=seg_s)
            for s in scenario(scen, n_seg)]
    stream = DriftStream(segs, seed=17, img=24)
    hp = (CLHyperParams(n_t=32, n_l=16, c_b=128, epochs=1) if smoke
          else CLHyperParams(n_t=48, n_l=24, c_b=192, epochs=1))
    rng = np.random.default_rng(0)
    steps = (8, 6) if smoke else (25, 15)
    tp = pretrain_model(make_vision_model(WIDERESNET50.reduced()), stream,
                        steps[0], 32, rng)
    sp = pretrain_model(make_vision_model(RESNET18.reduced()), stream,
                        steps[1], 32, rng, segments=stream.segments[:1],
                        seed=8)

    # MX9 serving -> balanced (8, 8) offline split; 4-row mesh -> row
    # boosts re-fission the sub-meshes (8->6 B-SA rows: 2->1 mesh rows).
    mx9_serve = PrecisionPolicy(inference="mx9")
    base = CLSystemSpec(student=RESNET18, teacher=WIDERESNET50, hp=hp,
                        apply_mx=False, seed=0, eval_fps=0.5,
                        policy=mx9_serve, dispatch="concurrent",
                        mesh=forced_row_mesh(4))

    out = {}
    for variant, (policy, aware) in VARIANTS.items():
        session = dataclasses.replace(base, allocator=policy,
                                      decision_aware_spec=aware).build()
        session.set_pretrained(tp, sp)
        pipe = FramePipeline(stream, speculative=True)
        t0 = time.perf_counter()
        res = session.run(pipe, duration=duration)
        wall = time.perf_counter() - t0
        pipe.close()  # settles the wasted-window accounting
        out[variant] = _stats(res, pipe, wall)
    return out


def main(argv=None):
    from repro.data.stream import SCENARIOS

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + scenario subset for CI")
    ap.add_argument("--out", default="BENCH_reallocation.json")
    ap.add_argument("--scenarios", default=None,
                    help="comma-separated subset (default: all 8; "
                         "smoke default: S1,ES1)")
    args = ap.parse_args(argv)

    if args.scenarios:
        names = args.scenarios.split(",")
    else:
        names = ["S1", "ES1"] if args.smoke else sorted(SCENARIOS)
    unknown = [n for n in names if n not in SCENARIOS]
    if unknown:
        raise SystemExit(f"unknown scenarios: {unknown}")

    result = {
        "bench": "reallocation",
        "mode": "smoke" if args.smoke else "full",
        "backend": jax.default_backend(),
        "policies": list(POLICIES),
        "variants": list(VARIANTS),
        "scenarios": {},
    }
    for name in names:
        t0 = time.perf_counter()
        result["scenarios"][name] = bench_scenario(name, args.smoke)
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s",
              flush=True)

    for variant in VARIANTS:
        hits = sum(s[variant]["speculation"]["hits"]
                   for s in result["scenarios"].values())
        misses = sum(s[variant]["speculation"]["misses"]
                     for s in result["scenarios"].values())
        rate = hits / max(1, hits + misses)
        result.setdefault("speculation_hit_rate", {})[variant] = round(rate,
                                                                       4)
    # Satellite: what the decision-aware predictor recovers over pure
    # layout replay (same policy, hints off).
    result["decision_aware_hit_rate_delta"] = round(
        result["speculation_hit_rate"]["dacapo-spatiotemporal"]
        - result["speculation_hit_rate"]["dacapo-spatiotemporal+nohints"],
        4)
    # Phases the online policy spent away from the offline split
    # (drift-dependent, hence sweep-level).
    result["online_rows_moved_phases"] = sum(
        s[POLICIES[1]]["rows_moved_phases"]
        for s in result["scenarios"].values())

    # Write BEFORE the acceptance asserts: a failing sweep must still leave
    # the per-scenario counters needed to diagnose it (CI uploads the file).
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps({k: v for k, v in result.items() if k != "scenarios"},
                     indent=2))
    print(f"wrote {args.out} ({len(result['scenarios'])} scenarios)")

    # Acceptance: concurrent sessions actually speculate, for every
    # variant, across the sweep — and the decision-aware predictor never
    # costs hits (it only rewrites bursts to the budget actually coming).
    for variant, rate in result["speculation_hit_rate"].items():
        assert rate > 0, f"{variant}: speculation never hit"
    assert result["decision_aware_hit_rate_delta"] >= 0, \
        "decision-aware speculation lost hits vs pure replay"
    return result


def run():
    """Registry entry (benchmarks/run.py): smoke sweep as CSV rows. Writes
    to a distinct file so a full-sweep BENCH_reallocation.json survives."""
    result = main(["--smoke", "--out", "BENCH_reallocation_smoke.json"])
    rows = []
    for scen, variants in result["scenarios"].items():
        for variant, stats in variants.items():
            rows.append((f"reallocation/{scen}/{variant}",
                         stats["wall_s"] * 1e6,
                         f"acc={stats['avg_accuracy']}"
                         f";hit_rate={stats['speculation']['hit_rate']}"))
    rows.append(("reallocation/decision_aware_delta", 0.0,
                 f"hit_rate_delta="
                 f"{result['decision_aware_hit_rate_delta']}"))
    return rows


if __name__ == "__main__":
    main()
