"""Guard the BENCH_*.json schema the benchmarks (and CI consumers) depend
on: every artifact must parse as JSON and carry its headline accuracy keys.

Each system bench writes a JSON artifact CI uploads; downstream tooling
(and the acceptance asserts in the benches themselves) read the headline
keys below. A bench refactor that renames or drops one would silently ship
artifacts nobody can compare across runs — this script fails the build
instead.

Run:  python benchmarks/check_artifacts.py [PATTERN ...]
      (defaults to BENCH_*.json in the current directory; missing benches
      are fine — only artifacts that EXIST are validated.)
"""
from __future__ import annotations

import glob
import json
import sys

# Per-bench headline keys: path segments into the JSON document. A tuple
# entry like ("modes", "*", "fleet_avg_accuracy") requires the key in every
# member of that mapping; mappings verified non-empty unless the bench
# wrote them conditionally (see OPTIONAL_EMPTY).
HEADLINE_KEYS = {
    "dispatch": [("session", "sequential", "avg_accuracy"),
                 ("session", "concurrent", "avg_accuracy"),
                 ("fused_wall_speedup",),
                 ("fused_op_reduction",),
                 ("bwd_pair_speedup",),
                 ("bwd_pair_program_reduction",),
                 ("serve_prequant_speedup",),
                 ("serve_prequant", "weight_quant_ops_per_window"),
                 ("label_cache_speedup",)],
    "reallocation": [("scenarios", "*", "*", "avg_accuracy"),
                     ("speculation_hit_rate",)],
    "fleet": [("modes", "*", "fleet_avg_accuracy"),
              ("row_policies", "*", "fleet_avg_accuracy"),
              ("fleet_batched_serve_speedup",)],
    "replay": [("prediction", "sequential", "bitwise_exact"),
               ("prediction", "concurrent", "bitwise_exact"),
               ("policy", "*", "avg_accuracy"),
               ("replay_phase_time_mape",),
               ("replay_policy_gain",)],
    "manager": [("recovery", "no_fault", "fleet_avg_accuracy"),
                ("recovery", "fault", "fleet_avg_accuracy"),
                ("recovery", "fault", "conservation_gap"),
                ("recovery", "recovery_overhead_s"),
                ("migration", "off", "fleet_avg_accuracy"),
                ("migration", "on", "fleet_avg_accuracy"),
                ("manager_parallel_speedup",),
                ("parallel", "manager_parallel_speedup"),
                ("parallel", "4_shards", "wall_speedup"),
                ("placement", "headroom", "fleet_avg_accuracy"),
                ("placement", "estimator", "fleet_avg_accuracy"),
                ("placement", "migration_divergence"),
                ("scenario_matrix", "layouts", "*", "*",
                 "fleet_avg_accuracy"),
                ("scenario_matrix", "drift_pack_gain", "aligned"),
                ("scenario_matrix", "drift_pack_gain", "scattered")],
}
# Mappings a bench may legitimately leave empty (e.g. a --row-policy matrix
# run skips the temporal-mode sweep).
OPTIONAL_EMPTY = {("fleet", "modes")}


def _check_path(bench: str, doc: dict, path: tuple, errors: list,
                name: str, prefix: tuple = ()) -> None:
    node, walked = doc, list(prefix)
    for i, seg in enumerate(path):
        if seg == "*":
            label = "/".join(walked) or "<root>"
            if not isinstance(node, dict):
                errors.append(f"{name}: {label} is not a mapping")
                return
            if not node:
                # Only mappings explicitly allowed to be empty pass (the
                # walked prefix always carries the mapping's own key here).
                if walked and (bench, walked[-1]) in OPTIONAL_EMPTY:
                    return
                errors.append(f"{name}: {label} is empty")
                return
            rest = path[i + 1:]
            for key, sub in node.items():
                _check_path(bench, sub, rest, errors,
                            f"{name}:{label}[{key}]",
                            prefix=tuple(walked) + (key,))
            return
        if not isinstance(node, dict) or seg not in node:
            errors.append(f"{name}: missing headline key "
                          f"{'/'.join(walked + [seg])}")
            return
        walked.append(seg)
        node = node[seg]
    if node is None:
        errors.append(f"{name}: headline key {'/'.join(walked)} is null")


def main(argv=None) -> int:
    patterns = (argv if argv else sys.argv[1:]) or ["BENCH_*.json"]
    paths = sorted(p for pat in patterns for p in glob.glob(pat))
    if not paths:
        print(f"no artifacts matched {patterns} — nothing to check")
        return 0
    errors: list = []
    for path in paths:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            errors.append(f"{path}: does not parse: {e}")
            continue
        bench = doc.get("bench")
        if bench is None:
            errors.append(f"{path}: missing the 'bench' discriminator key")
            continue
        for key_path in HEADLINE_KEYS.get(bench, []):
            _check_path(bench, doc, key_path, errors, path)
        print(f"ok: {path} (bench={bench})")
    for err in errors:
        print(f"FAIL: {err}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
