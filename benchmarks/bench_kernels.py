"""Kernel micro-benchmarks: MX quantize / MX matmul / flash attention.

Wall-times measured on the jitted jnp reference path (CPU container; the
Pallas kernels target TPU and are validated in interpret mode by tests).
'derived' reports the kernel-level roofline on TPU v5e from the analytic
byte/FLOP counts (the number the DPE comparison in §Perf uses).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.estimator import TPU_HBM_BW, TPU_PEAK_FLOPS
from repro.kernels import ref
from repro.kernels.ref import MANTISSA_BITS


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def run():
    rows = []
    m, k, n = 512, 2048, 512
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n))

    for prec in ("mx4", "mx6", "mx9"):
        qfn = jax.jit(lambda x, p=prec: ref.mx_quant_dequant_ref(x, p))
        us = _time(qfn, a)
        mb = MANTISSA_BITS[prec]
        bits = mb + 1 + 16 / 16 + 8 / 16  # mantissa+sign+mx+shared/16
        rows.append((f"kernels/mx_quantize_{prec}", us,
                     f"bits_per_val={bits:.2f} compression={32/bits:.1f}x"))

    for prec in ("mx6", "mx9"):
        mfn = jax.jit(lambda a, b, p=prec: ref.mx_matmul_fp_ref(a, b, p, p))
        us = _time(mfn, a, b)
        flops = 2 * m * k * n
        # TPU-side: int8 mantissa traffic vs fp32
        bytes_mx = (m * k + k * n) * (MANTISSA_BITS[prec] + 1) / 8 + m * n * 4
        t_c = flops / TPU_PEAK_FLOPS
        t_m = bytes_mx / TPU_HBM_BW
        rows.append((f"kernels/mx_matmul_{prec}", us,
                     f"tpu_roofline_us={max(t_c, t_m)*1e6:.2f} "
                     f"bound={'compute' if t_c > t_m else 'memory'}"))

    # Fused quantize->matmul (PR 7) vs the 3-jit unfused chain, at the
    # repo's hot-path (small-M) GEMM sizes where the per-program dispatch
    # overhead the fusion removes is a real fraction of the GEMM.
    for fm, fk, fn in [(16, 432, 64), (32, 128, 64), (64, 256, 128)]:
        fa_ = jax.random.normal(jax.random.PRNGKey(4), (fm, fk))
        fb_ = jax.random.normal(jax.random.PRNGKey(5), (fk, fn))
        qfn = jax.jit(lambda x: ref.mx_quantize_ref(x, "mx6"))
        mmr = jax.jit(ref.mx_matmul_ref)
        ffn = jax.jit(
            lambda a, b: ref.mx_matmul_fused_ref(a, b, "mx6", "mx6"))

        def unfused_chain(a=fa_, b=fb_):
            return mmr(qfn(a), qfn(b.T))  # 3 programs, MX tensors between

        us_u = _time(unfused_chain, reps=20)
        us_f = _time(ffn, fa_, fb_, reps=20)
        rows.append((f"kernels/mx_fused_{fm}x{fk}x{fn}", us_f,
                     f"unfused_3jit_us={us_u:.1f} "
                     f"wall_speedup={us_u / us_f:.2f}x"))

    q = jax.random.normal(jax.random.PRNGKey(2), (1, 1024, 8, 64))
    kk = jax.random.normal(jax.random.PRNGKey(3), (1, 1024, 2, 64))
    from repro.models.attention import flash_attention as fa

    for window in (None, 256):
        ffn = jax.jit(lambda q, k, v, w=window: fa(q, k, v, causal=True,
                                                   window=w))
        us = _time(ffn, q, kk, kk)
        rows.append((f"kernels/flash_attn_w{window}", us,
                     "chunked-online-softmax (jnp ref path)"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
