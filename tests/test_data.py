"""Drift stream: determinism, scenario structure, drift effects."""
import numpy as np
import pytest

from repro.data.stream import DriftStream, SCENARIOS, Segment, scenario
from repro.data.tokens import TokenPipeline


def test_stream_deterministic():
    s1 = DriftStream(scenario("S1", 4), seed=3)
    s2 = DriftStream(scenario("S1", 4), seed=3)
    x1, y1 = s1.frames(10.0, 12.0)
    x2, y2 = s2.frames(10.0, 12.0)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)


def test_different_seeds_differ():
    x1, _ = DriftStream(scenario("S1", 2), seed=0).frames(0, 1)
    x2, _ = DriftStream(scenario("S1", 2), seed=1).frames(0, 1)
    assert not np.allclose(x1, x2)


def test_all_scenarios_build():
    for name in SCENARIOS:
        segs = scenario(name)
        assert len(segs) == 20
        stream = DriftStream(segs)
        assert stream.duration == pytest.approx(1200.0)  # 20 min (§VII-A)


def test_scenario_s1_flips_label_dist_only():
    segs = scenario("S1", 4)
    assert [s.label_dist for s in segs] == ["traffic", "all"] * 2
    assert len({s.time_of_day for s in segs}) == 1
    assert len({s.location for s in segs}) == 1


def test_extreme_scenario_flips_all_axes():
    segs = scenario("ES1", 16)
    assert len({s.label_dist for s in segs}) == 2
    assert len({s.time_of_day for s in segs}) == 2
    assert len({s.location for s in segs}) == 2
    assert len({s.weather for s in segs}) == 2


def test_traffic_segments_restrict_classes():
    stream = DriftStream([Segment(label_dist="traffic")], seed=0)
    _, y = stream.frames(0, 30)
    assert set(np.unique(y)) <= {0, 1, 2, 3, 4}
    stream2 = DriftStream([Segment(label_dist="all")], seed=0)
    _, y2 = stream2.frames(0, 30)
    assert len(np.unique(y2)) > 5


def test_night_darkens_frames():
    day = DriftStream([Segment(time_of_day="day")], seed=5)
    night = DriftStream([Segment(time_of_day="night")], seed=5)
    xd, _ = day.frames(0, 5)
    xn, _ = night.frames(0, 5)
    assert np.mean(np.abs(xn[..., :2])) < np.mean(np.abs(xd[..., :2]))


def test_max_frames_subsample():
    stream = DriftStream(scenario("S2", 2))
    x, y = stream.frames(0, 10, max_frames=7)
    assert len(x) == 7 and len(y) == 7


def test_token_pipeline_deterministic_and_learnable():
    pipe = TokenPipeline(vocab_size=64, seq_len=32, global_batch=4, seed=1)
    b1, b2 = pipe.batch(5), pipe.batch(5)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    assert b1["inputs"].shape == (4, 32)
    # bigram structure: every (tok -> next) pair is one of 4 successors
    succ = pipe._succ
    ok = succ[b1["inputs"].reshape(-1)] == b1["labels"].reshape(-1)[:, None]
    assert ok.any(axis=-1).all()


def test_token_pipeline_host_sharding():
    full = TokenPipeline(64, 16, 8, seed=2)
    h0 = TokenPipeline(64, 16, 8, seed=2, num_hosts=2, host_index=0)
    h1 = TokenPipeline(64, 16, 8, seed=2, num_hosts=2, host_index=1)
    assert h0.local_batch == 4 and h1.local_batch == 4
    assert not np.array_equal(h0.batch(0)["inputs"], h1.batch(0)["inputs"])
