"""FramePipeline + online re-allocation tests: speculative windows are
bit-identical to inline slicing for every scenario, reconcile hits/misses
are accounted per phase, the session consumes only pipeline handles, the
DC-ST-Online policy shifts rows on drift under hysteresis, and the golden
guard pins DC-ST-Online (re-allocation disabled) to DC-ST's exact timeline
on the refactored data path."""
import numpy as np
import pytest

from repro.configs.dacapo_pairs import RESNET18, WIDERESNET50
from repro.core.allocation import (
    CLHyperParams,
    OnlineSpatiotemporalAllocator,
    PhaseFeedback,
)
from repro.core.dispatch import SEQUENTIAL, PhasePlan
from repro.core.estimator import DaCapoEstimator
from repro.core.mx import PrecisionPolicy
from repro.core.partition import forced_row_mesh
from repro.core.session import CLSystemSpec, pretrain_model
from repro.data.pipeline import FramePipeline
from repro.data.stream import DriftStream, SCENARIOS, scenario

# Per-phase request layout replayed below: (dt0, dt1, max_frames) offsets
# from the phase start — a score window, a labeling burst, a tail window.
_PHASE_LAYOUT = ((0.0, 2.05, 4), (2.05, 2.9, 24), (2.9, 5.17, 3))
# Starts straddle the 60 s segment boundary so speculated windows cross a
# drift edge (segment_index changes mid-window).
_PHASE_STARTS = (50.0, 54.31, 58.62, 62.93)


# ----------------------------------------------------------- determinism --
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_speculative_windows_bit_identical_all_scenarios(name):
    """Satellite: prefetched/speculative windows yield bit-identical frames
    to inline slicing for every scenario (S1-S6, ES1, ES2)."""
    stream = DriftStream(scenario(name, 2), seed=9, img=16)
    inline = DriftStream(scenario(name, 2), seed=9, img=16)
    pipe = FramePipeline(stream, speculative=True)
    try:
        for s in _PHASE_STARTS:
            pipe.begin_phase(s)
            for dt0, dt1, mf in _PHASE_LAYOUT:
                x, y = pipe.frames(s + dt0, s + dt1, max_frames=mf)
                xi, yi = inline.frames(s + dt0, s + dt1, max_frames=mf)
                np.testing.assert_array_equal(x, xi)
                np.testing.assert_array_equal(y, yi)
        # Phase 1 had no trace to speculate from: all three windows miss.
        # Phases 2-4 replay the same layout and reconcile as hits — except
        # when a replayed timestamp lands exactly on a 1e-4 rounding
        # boundary, which the matcher deliberately rejects (a miss, never a
        # wrong frame); allow a couple of those per scenario.
        speculated = (len(_PHASE_STARTS) - 1) * len(_PHASE_LAYOUT)
        assert pipe.hits >= speculated - 2
        assert pipe.misses <= len(_PHASE_LAYOUT) + 2
        assert pipe.hit_rate > 0
    finally:
        pipe.close()


def test_mispredicted_window_synthesizes_inline_exactly():
    """A request outside the speculated layout is a miss — and still returns
    exactly what inline slicing would."""
    stream = DriftStream(scenario("S1", 2), seed=7, img=16)
    inline = DriftStream(scenario("S1", 2), seed=7, img=16)
    pipe = FramePipeline(stream, speculative=True)
    try:
        pipe.begin_phase(0.0)
        pipe.frames(0.0, 1.0, max_frames=4)
        pipe.begin_phase(3.0)
        h0, m0 = pipe.hits, pipe.misses
        # The drift case: the phase asks for a bigger labeling burst than
        # the speculation predicted.
        x, y = pipe.frames(3.0, 5.0, max_frames=16)
        xi, yi = inline.frames(3.0, 5.0, max_frames=16)
        np.testing.assert_array_equal(x, xi)
        np.testing.assert_array_equal(y, yi)
        assert (pipe.hits, pipe.misses) == (h0, m0 + 1)
        assert pipe.stats.windows_speculated == 1
    finally:
        pipe.close()


def test_ulp_perturbed_replay_still_hits():
    """The reconcile matcher tolerates the float-accumulation jitter of
    replaying offsets from a different phase start: a request perturbed by
    an ulp-scale delta still hits, and the frames are exactly what inline
    slicing at the perturbed time yields."""
    stream = DriftStream(scenario("S3", 2), seed=11, img=16)
    inline = DriftStream(scenario("S3", 2), seed=11, img=16)
    pipe = FramePipeline(stream, speculative=True)
    try:
        pipe.begin_phase(10.0)
        pipe.frames(10.0, 12.33, max_frames=6)
        pipe.begin_phase(17.31)
        t0, t1 = 17.31 + 1e-10, 17.31 + 2.33 + 1e-10
        x, y = pipe.frames(t0, t1, max_frames=6)
        xi, yi = inline.frames(t0, t1, max_frames=6)
        np.testing.assert_array_equal(x, xi)
        np.testing.assert_array_equal(y, yi)
        assert pipe.hits == 1
    finally:
        pipe.close()


def test_pipeline_close_and_transparent_modes():
    stream = DriftStream(scenario("S1", 2), seed=7, img=16)
    pipe = FramePipeline(stream, speculative=True)
    pipe.begin_phase(0.0)
    pipe.frames(0.0, 1.0, max_frames=2)
    pipe.begin_phase(2.0)
    assert pipe._worker is not None
    assert pipe.stats.windows_speculated == 1
    pipe.close()
    assert pipe._worker is None and not pipe.speculative
    # The unconsumed in-flight speculation is accounted as wasted:
    # speculated == hits + wasted always balances at close.
    assert pipe.stats.windows_wasted == 1
    assert (pipe.stats.windows_speculated
            == pipe.stats.hits + pipe.stats.windows_wasted)
    h, m = pipe.hits, pipe.misses
    x, y = pipe.frames(2.0, 3.0, max_frames=2)  # still serves, inline
    assert len(x) == len(y) == 2
    assert (pipe.hits, pipe.misses) == (h, m)
    # speculative=False never spawns a worker nor counts.
    flat = FramePipeline(stream, speculative=False)
    flat.begin_phase(0.0)
    flat.frames(0.0, 1.0, max_frames=2)
    flat.begin_phase(2.0)
    flat.frames(2.0, 3.0, max_frames=2)
    assert flat._worker is None
    assert flat.hits == flat.misses == 0 and flat.stats.phases == 0


def test_plan_fetch_requires_pipeline():
    plan = PhasePlan(SEQUENTIAL, start=0.0)
    with pytest.raises(ValueError):
        plan.fetch(0.0, 1.0, max_frames=2)


# -------------------------------------------------------- online policy --
_MX9_SERVE = PrecisionPolicy(inference="mx9")  # balanced (8, 8) split


def _online(hp=None, **kw) -> OnlineSpatiotemporalAllocator:
    pol = OnlineSpatiotemporalAllocator(hp or CLHyperParams(), _MX9_SERVE,
                                        **kw)
    return pol.bind(DaCapoEstimator(), RESNET18)


def test_online_policy_shifts_rows_on_drift_with_hysteresis():
    pol = _online(boost_rows=2, hysteresis_phases=2, recover_margin=0.05)
    r_tsa0, r_bsa0 = pol.rows
    assert pol.boost_rows == 2
    d = pol.initial_decision()
    assert (d.rows_tsa, d.rows_bsa) == (r_tsa0, r_bsa0)
    d = pol.next_decision(PhaseFeedback(0.8, 0.82, 1.0))  # healthy
    assert d.rows_tsa == r_tsa0
    d = pol.next_decision(PhaseFeedback(0.9, 0.3, 2.0))  # drift cliff
    assert d.reset_buffer
    assert (d.rows_tsa, d.rows_bsa) == (r_tsa0 + 2, r_bsa0 - 2)
    assert d.rows_tsa + d.rows_bsa == r_tsa0 + r_bsa0
    # Hysteresis: acc_valid already recovered, but the window holds rows.
    d = pol.next_decision(PhaseFeedback(0.85, 0.84, 3.0))
    assert d.rows_tsa == r_tsa0 + 2
    # Window expired + acc_valid at the pre-drift EMA: rows return.
    d = pol.next_decision(PhaseFeedback(0.85, 0.84, 4.0))
    assert (d.rows_tsa, d.rows_bsa) == (r_tsa0, r_bsa0)


def test_online_policy_redrift_rearms_and_low_acc_defers_return():
    pol = _online(boost_rows=2, hysteresis_phases=1, recover_margin=0.02)
    pol.next_decision(PhaseFeedback(0.8, 0.8, 0.0))  # EMA -> 0.8
    pol.next_decision(PhaseFeedback(0.9, 0.3, 1.0))  # drift -> boost
    d = pol.next_decision(PhaseFeedback(0.9, 0.2, 2.0))  # re-drift re-arms
    assert d.reset_buffer and d.rows_tsa == pol.rows[0] + 2
    # Window expired but acc_valid still below the EMA: rows stay boosted.
    d = pol.next_decision(PhaseFeedback(0.4, 0.42, 3.0))
    assert d.rows_tsa == pol.rows[0] + 2
    d = pol.next_decision(PhaseFeedback(0.79, 0.8, 4.0))  # recovered
    assert d.rows_tsa == pol.rows[0]


def test_online_policy_boost_clamped_and_disabled():
    # Default mx6 serving split leaves B-SA 2 rows: boost clamps to 1.
    hp = CLHyperParams()
    pol = OnlineSpatiotemporalAllocator(hp, boost_rows=5).bind(
        DaCapoEstimator(), RESNET18)
    assert pol.rows[1] - pol.boost_rows >= 1
    # boost_rows=0 disables re-allocation: drift never moves rows.
    off = _online(boost_rows=0)
    d = off.next_decision(PhaseFeedback(0.9, 0.3, 1.0))
    assert d.reset_buffer and (d.rows_tsa, d.rows_bsa) == off.rows
    # R=0 fallback split (a 0-row side means "time-share the whole
    # array"): boosting would shrink it to an exclusive slice — disabled.
    degen = OnlineSpatiotemporalAllocator(hp, boost_rows=4).bind(
        DaCapoEstimator(total_rows=1), RESNET18)
    assert degen.rows[0] == 0 and degen.boost_rows == 0
    d = degen.next_decision(PhaseFeedback(0.9, 0.3, 1.0))
    assert (d.rows_tsa, d.rows_bsa) == degen.rows


# ------------------------------------------------------------- sessions --
@pytest.fixture(scope="module")
def small_setup():
    stream = DriftStream(scenario("S1", 2), seed=5, img=24)
    hp = CLHyperParams(n_t=32, n_l=16, c_b=128, epochs=1)
    rng = np.random.default_rng(0)
    from repro.models.registry import make_vision_model
    tp = pretrain_model(make_vision_model(WIDERESNET50.reduced()), stream,
                        10, 32, rng)
    sp = pretrain_model(make_vision_model(RESNET18.reduced()), stream,
                        8, 32, rng, segments=stream.segments[:1], seed=8)
    return stream, hp, tp, sp


def _spec(hp, **kw) -> CLSystemSpec:
    kw.setdefault("allocator", "dacapo-spatiotemporal")
    return CLSystemSpec(student=RESNET18, teacher=WIDERESNET50, hp=hp,
                        apply_mx=False, seed=0, eval_fps=0.5, **kw)


def test_concurrent_session_speculates_and_golden_guard(small_setup):
    """One fixture, three concurrent runs: DC-ST on the session-owned
    pipeline (speculation hits recorded per phase), DC-ST on an explicit
    pipeline handle (identical timeline), and DC-ST-Online with
    re-allocation disabled (the golden guard: exact DC-ST behaviour on the
    refactored data path)."""
    stream, hp, tp, sp = small_setup

    session = _spec(hp, dispatch="concurrent").build()
    assert session.speculative_frames
    session.set_pretrained(tp, sp)
    res = session.run(stream, duration=20.0)
    assert res.records[0].spec_hits == 0  # nothing to speculate from yet
    assert sum(r.spec_hits for r in res.records) > 0
    for rec in res.records:
        entry = rec.as_log_entry()
        assert entry["spec_hits"] == rec.spec_hits
        assert entry["t_tsa"] == rec.t_tsa  # satellite: timing fields kept

    # Same run through an explicit FramePipeline handle.
    handle = FramePipeline(stream, speculative=True)
    session2 = _spec(hp, dispatch="concurrent").build()
    session2.set_pretrained(tp, sp)
    res2 = session2.run(handle, duration=20.0)
    assert handle.hits > 0  # the session fed our pipeline, not its own
    handle.close()
    assert res2.accuracy_timeline == res.accuracy_timeline

    # Golden guard: online policy with re-allocation disabled == DC-ST.
    guard = OnlineSpatiotemporalAllocator(hp, boost_rows=0)
    session3 = _spec(hp, dispatch="concurrent", allocator=guard).build()
    session3.set_pretrained(tp, sp)
    res3 = session3.run(stream, duration=20.0)
    assert res3.accuracy_timeline == res.accuracy_timeline
    assert res3.retrain_time == res.retrain_time
    assert len(res3.records) == len(res.records)


class _FireOnce:
    """Scripted drift detector: exactly one drift once t passes 5 s."""

    def __init__(self):
        self.fired = False

    def check(self, acc_label, acc_valid, t):
        if not self.fired and t > 5.0:
            self.fired = True
            return True
        return False


def test_online_session_moves_rows_and_repartitions(small_setup):
    """DC-ST-Online in a concurrent session on a 4-row mesh: the drift
    boost re-fissions the mesh (B-SA 2 mesh rows -> 1) and the hysteresis
    return restores it — the per-phase re-partitioning path driven by a
    real policy."""
    stream, hp, tp, sp = small_setup
    policy = OnlineSpatiotemporalAllocator(
        hp, _MX9_SERVE, boost_rows=4, hysteresis_phases=1,
        recover_margin=1.0)  # margin 1.0: return as soon as window expires
    policy.detector = _FireOnce()
    session = _spec(hp, dispatch="concurrent", allocator=policy,
                    policy=_MX9_SERVE, mesh=forced_row_mesh(4)).build()
    session.set_pretrained(tp, sp)
    seen = []
    session.add_observer(lambda rec: seen.append(
        (rec.decision.rows_bsa, session.partition.b_sa.devices.shape[0])))
    res = session.run(stream, duration=30.0)
    assert res.drift_events == 1
    rows = [r for r, _ in seen]
    r_tsa0, r_bsa0 = policy.rows
    assert rows[0] == r_bsa0  # offline split first
    assert r_bsa0 - 4 in rows  # boosted phases ran
    assert rows[-1] == r_bsa0  # rows returned after recovery
    # Mesh split follows the decision rows: 8/16 -> 2 mesh rows boosted->1.
    mesh_rows = {r: m for r, m in seen}
    assert mesh_rows[r_bsa0] == 2 and mesh_rows[r_bsa0 - 4] == 1


def test_sequential_session_defaults_to_transparent_pipeline(small_setup):
    stream, hp, tp, sp = small_setup
    session = _spec(hp).build()
    assert not session.speculative_frames
    session.set_pretrained(tp, sp)
    res = session.run(stream, duration=10.0)
    assert all(r.spec_hits == 0 and r.spec_misses == 0 for r in res.records)
