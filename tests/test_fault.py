"""Direct unit tests for repro.runtime.fault: FailureInjector semantics
(bare-step and (step, key)-targeted entries, fire-exactly-once),
StragglerDetector.observe, and the resilient_loop restart/resume and
checkpoint-cadence contracts. The fleet-manager tier builds on exactly
these semantics (it probes ``maybe_fail(round, key=shard_index)`` each
round), so they are pinned here independently of the manager tests."""
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.runtime.fault import (
    FailureInjector,
    Heartbeat,
    StragglerDetector,
    resilient_loop,
)


# ------------------------------------------------------- FailureInjector
def test_injector_bare_step_fires_once():
    inj = FailureInjector(fail_at_steps=(3,))
    inj.maybe_fail(0)
    inj.maybe_fail(2)
    with pytest.raises(RuntimeError, match="step 3"):
        inj.maybe_fail(3)
    inj.maybe_fail(3)  # each entry fires exactly once
    assert inj.failed == {3}


def test_injector_keyed_entry_targets_one_probe_site():
    """(step, key) kills only the matching key's probe at that step —
    the manager's per-shard probe contract."""
    inj = FailureInjector(fail_at_steps=[(3, 1)])
    for step in range(3):
        inj.maybe_fail(step, key=0)
        inj.maybe_fail(step, key=1)
    inj.maybe_fail(3, key=0)  # other shard unharmed
    with pytest.raises(RuntimeError, match=r"step 3 \(key=1\)"):
        inj.maybe_fail(3, key=1)
    inj.maybe_fail(3, key=1)  # fired once, never again
    inj.maybe_fail(4, key=1)
    assert inj.failed == {(3, 1)}


def test_injector_bare_entry_hits_any_keyed_probe():
    """A bare step entry fails whichever probe reaches that step first,
    keyed or not (the resilient_loop contract is a special case)."""
    inj = FailureInjector(fail_at_steps=(5,))
    with pytest.raises(RuntimeError, match=r"step 5 \(key=2\)"):
        inj.maybe_fail(5, key=2)
    inj.maybe_fail(5, key=0)  # consumed by the first prober
    assert inj.failed == {5}


def test_injector_mixed_entries():
    inj = FailureInjector(fail_at_steps=[2, (2, "a")])
    with pytest.raises(RuntimeError):
        inj.maybe_fail(2)  # consumes the bare entry
    with pytest.raises(RuntimeError):
        inj.maybe_fail(2, key="a")  # keyed entry still pending
    inj.maybe_fail(2, key="a")
    assert inj.failed == {2, (2, "a")}


# ----------------------------------------------------- StragglerDetector
def test_straggler_observe_needs_positive_median():
    sd = StragglerDetector(factor=2.0)
    assert not sd.observe(0, 10.0, 0.0)  # no median yet -> never flags
    assert not sd.observe(1, 0.19, 0.1)  # under factor x median
    assert sd.observe(2, 0.21, 0.1)
    assert sd.events == [{"step": 2, "duration": 0.21, "median": 0.1}]


def test_heartbeat_feeds_detector_rolling_median():
    hb = Heartbeat(window=4)
    for d in (1.0, 2.0, 3.0, 4.0, 5.0):
        hb.durations.append(d)
    assert len(hb.durations) == 5  # window enforced by beat(), not append
    hb2 = Heartbeat(window=4)
    hb2.beat()
    for _ in range(6):
        hb2.beat()
    assert len(hb2.durations) <= 4


# -------------------------------------------------------- resilient_loop
def _counting_step():
    calls = []

    def step_fn(state, step):
        calls.append(step)
        return {"w": state["w"] + 1.0}

    return step_fn, calls


def test_resilient_loop_restores_and_replays(tmp_path):
    """Failure at step 7 with checkpoint_every=5: restore at step 5,
    replay 5 and 6 — final state counts exactly num_steps effective
    steps."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    step_fn, calls = _counting_step()
    inj = FailureInjector(fail_at_steps=(7,))
    final, report = resilient_loop(
        step_fn, {"w": jnp.zeros(())}, num_steps=10,
        checkpoint_manager=mgr, checkpoint_every=5, failure_injector=inj)
    assert report.final_step == 10
    assert report.restarts == 1
    assert float(final["w"]) == 10.0
    assert calls == [0, 1, 2, 3, 4, 5, 6, 5, 6, 7, 8, 9]  # replay from 5
    assert report.checkpointed_steps == [5, 10]


def test_resilient_loop_failure_before_first_checkpoint(tmp_path):
    """A failure before any checkpoint restarts from step 0 (nothing to
    restore), still converging to num_steps effective steps."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    step_fn, calls = _counting_step()
    inj = FailureInjector(fail_at_steps=(2,))
    final, report = resilient_loop(
        step_fn, {"w": jnp.zeros(())}, num_steps=6,
        checkpoint_manager=mgr, checkpoint_every=4, failure_injector=inj)
    assert report.restarts == 1
    assert calls[:2] == [0, 1] and calls[2] == 0  # restarted from scratch
    # NOTE the loop restarts with the *current* in-memory state when no
    # checkpoint exists, so the counter keeps the pre-failure increments:
    # 2 lost-step increments + 6 effective steps.
    assert float(final["w"]) == 8.0
    assert report.final_step == 6


def test_resilient_loop_resumes_from_existing_checkpoint(tmp_path):
    """A fresh loop over a directory holding step-4's checkpoint resumes
    at step 4 instead of recomputing from scratch."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    step_fn, _ = _counting_step()
    resilient_loop(step_fn, {"w": jnp.zeros(())}, num_steps=4,
                   checkpoint_manager=mgr, checkpoint_every=4)
    step_fn2, calls2 = _counting_step()
    final, report = resilient_loop(
        step_fn2, {"w": jnp.zeros(())}, num_steps=8,
        checkpoint_manager=mgr, checkpoint_every=4)
    assert calls2 == [4, 5, 6, 7]  # steps 0-3 never re-run
    assert float(final["w"]) == 8.0
    assert report.final_step == 8
