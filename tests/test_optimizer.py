"""Optimizer + grad machinery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.grad import compress_int8, microbatched_grads
from repro.training.optimizer import (
    OptimizerConfig,
    apply_updates,
    init_opt_state,
    schedule,
)


@pytest.mark.parametrize("name", ["sgd", "adamw"])
def test_optimizer_converges_quadratic(name):
    cfg = OptimizerConfig(name=name, lr=0.1, warmup_steps=0,
                          total_steps=200, grad_clip=0.0, min_lr_ratio=1.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(params, cfg)
    for step in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw w^2
        params, state, _ = apply_updates(params, grads, state, step, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_schedule_warmup_and_decay():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    assert float(schedule(0, cfg)) < 0.2
    assert float(schedule(10, cfg)) == pytest.approx(1.0, abs=0.05)
    assert float(schedule(99, cfg)) < 0.2


def test_grad_clip():
    cfg = OptimizerConfig(name="sgd", lr=1.0, grad_clip=1.0,
                          warmup_steps=0, min_lr_ratio=1.0)
    params = {"w": jnp.zeros((3,))}
    state = init_opt_state(params, cfg)
    grads = {"w": jnp.asarray([100.0, 0.0, 0.0])}
    new_params, _, metrics = apply_updates(params, grads, state, 0, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(100.0)
    assert float(jnp.abs(new_params["w"]).max()) <= 1.0 + 1e-5


def test_microbatched_grads_match_full_batch():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (8, 4))
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    y = jax.random.normal(jax.random.PRNGKey(2), (16, 4))

    def loss_fn(params, batch):
        pred = batch["x"] @ params
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, {"loss": loss}

    batch = {"x": x, "y": y}
    l1, m1, g1 = microbatched_grads(loss_fn, w, batch, 1)
    l4, m4, g4 = microbatched_grads(loss_fn, w, batch, 4)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l4), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g4), rtol=1e-5)


def test_int8_compression_error_feedback():
    g = jnp.asarray([1.0, -0.503, 0.2501, 0.001])
    err = jnp.zeros_like(g)
    q, scale, err1 = compress_int8(g, err)
    deq = q.astype(jnp.float32) * scale
    # bounded quantization error
    assert float(jnp.abs(deq - g).max()) <= float(scale) * 0.5 + 1e-9
    # error feedback: next round re-injects the residual
    q2, scale2, err2 = compress_int8(g, err1)
    deq2 = q2.astype(jnp.float32) * scale2
    total = deq + deq2
    np.testing.assert_allclose(np.asarray(total), np.asarray(2 * g - err2),
                               rtol=1e-5, atol=1e-6)


def test_bf16_params_fp32_master_updates():
    cfg = OptimizerConfig(name="adamw", lr=0.01, warmup_steps=0,
                          min_lr_ratio=1.0)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = init_opt_state(params, cfg)
    assert state["mu"]["w"].dtype == jnp.float32
    grads = {"w": jnp.full((4,), 0.1, jnp.bfloat16)}
    new_params, state, _ = apply_updates(params, grads, state, 0, cfg)
    assert new_params["w"].dtype == jnp.bfloat16
