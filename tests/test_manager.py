"""FleetManager tier tests: the 1-shard degeneracy golden (a 1-shard
manager — checkpointing on — is bit-identical to a bare FleetSession in
both dispatch modes), fault-injected shard loss with checkpoint recovery
and manager/shard ledger conservation, mid-run lane admission, live lane
migration (bit-identical resume from a LaneSnapshot), the durable
snapshot encode/decode round-trip, and the PlacementPolicy registry."""
import jax
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs.dacapo_pairs import RESNET18, WIDERESNET50
from repro.core.allocation import CLHyperParams
from repro.core.decision import ManagerDecision
from repro.core.fleet import FleetSpec
from repro.core.manager import (
    PLACEMENT_POLICIES,
    DriftPackPlacementPolicy,
    EstimatorPlacementPolicy,
    FleetManager,
    HeadroomPlacementPolicy,
    LaneView,
    ManagerSpec,
    PlacementPolicy,
    ShardView,
    StaticPlacementPolicy,
    make_placement_policy,
    snapshot_to_state,
    state_to_snapshot,
)
from repro.core.session import pretrain_model
from repro.data.stream import DriftStream, scenario
from repro.models.registry import make_vision_model
from repro.runtime.fault import FailureInjector

_RECORD_FIELDS = ("index", "t", "acc_valid", "acc_label", "drift",
                  "retrain_time", "label_time", "phase_start", "t_tsa",
                  "t_bsa", "spec_hits", "spec_misses", "stream")


def _assert_records_identical(recs_a, recs_b):
    assert len(recs_a) == len(recs_b)
    for a, b in zip(recs_a, recs_b):
        for field in _RECORD_FIELDS:
            assert getattr(a, field) == getattr(b, field), field
        assert a.decision == b.decision
        assert a.next_decision == b.next_decision


@pytest.fixture(scope="module")
def pretrained():
    stream = DriftStream(scenario("S1", 2), seed=5, img=24)
    hp = CLHyperParams(n_t=32, n_l=16, c_b=128, epochs=1)
    rng = np.random.default_rng(0)
    tp = pretrain_model(make_vision_model(WIDERESNET50.reduced()), stream,
                        10, 32, rng)
    sp = pretrain_model(make_vision_model(RESNET18.reduced()), stream, 8,
                        32, rng, segments=stream.segments[:1], seed=8)
    return hp, tp, sp


def _streams(n):
    return [DriftStream(scenario(name, 2), seed=seed, img=24)
            for name, seed in [("S1", 5), ("S3", 6), ("ES1", 7)][:n]]


def _fleet_spec(hp, dispatch="sequential"):
    return FleetSpec(student=RESNET18, teacher=WIDERESNET50, hp=hp,
                     fleet_mode="drift-weighted", apply_mx=False, seed=0,
                     eval_fps=0.5, dispatch=dispatch)


# ------------------------------------------------------ degeneracy golden
@pytest.mark.parametrize("dispatch", ["sequential", "concurrent"])
def test_one_shard_manager_is_bit_identical_to_fleet_session(
        pretrained, dispatch, tmp_path):
    """A 1-shard FleetManager — per-lane checkpointing ON — reproduces a
    bare FleetSession bit-for-bit: phase log, per-lane records and
    accuracy timelines; and the manager ledger equals the shard ledger
    equals the fleet_phase_log sum exactly."""
    hp, tp, sp = pretrained
    bare = _fleet_spec(hp, dispatch).build()
    bare.set_pretrained(tp, sp)
    ref = bare.run(_streams(2), duration=40.0)

    mgr = FleetManager(_fleet_spec(hp, dispatch), n_shards=1,
                       checkpoint_dir=str(tmp_path / dispatch),
                       checkpoint_every=1)
    mgr.set_pretrained(tp, sp)
    res = mgr.run(_streams(2), duration=40.0)

    assert res.n_shards == 1
    got = res.shard_results[0]
    assert got.fleet_phase_log == ref.fleet_phase_log
    assert got.fleet_avg_accuracy == ref.fleet_avg_accuracy
    for lane, lane_ref in zip(got.streams, ref.streams):
        assert lane.accuracy_timeline == lane_ref.accuracy_timeline
        _assert_records_identical(lane.records, lane_ref.records)
    exact = sum(e["t_tsa"] for e in ref.fleet_phase_log)
    assert res.ledger["t_tsa"] == exact  # same accumulation order
    assert res.shard_ledgers[0]["t_tsa"] == exact
    assert res.conservation_gap() == 0.0
    assert res.ledger["recovery_cost"] == 0.0
    assert all(isinstance(d, ManagerDecision) for d in res.decisions)


# ------------------------------------------------- fault-injected recovery
def test_shard_loss_recovers_from_checkpoints(pretrained, tmp_path):
    """Kill shard 1 mid-run: its lanes restore from their last per-lane
    checkpoint and re-home onto the survivor; the manager ledger stays
    conserved (sum of shard ledgers + explicit recovery cost) and the
    fleet finishes with every lane scored to the duration, at accuracy
    within tolerance of the no-fault run."""
    hp, tp, sp = pretrained
    inj = FailureInjector(fail_at_steps=[(3, 1)])
    mgr = FleetManager(_fleet_spec(hp), n_shards=2,
                       checkpoint_dir=str(tmp_path),
                       checkpoint_every=2, failure_injector=inj,
                       recovery_cost_s=2.0, migration=False)
    mgr.set_pretrained(tp, sp)
    res = mgr.run(_streams(3), duration=40.0)

    kinds = [e.kind for e in res.events]
    assert "fail" in kinds and "recover" in kinds
    assert res.shard_results[1] is None  # the dead shard
    assert res.shard_results[0] is not None
    # Every camera still reaches the finish line on the survivor.
    assert set(res.lane_results) == {"cam0", "cam1", "cam2"}
    for lane_res in res.lane_results.values():
        assert lane_res.records, "lane lost by recovery"
    # Recovery placements are first-class ManagerDecision actions.
    recoveries = [p for d in res.decisions for p in d.placements
                  if p.kind == "recover"]
    assert recoveries and all(p.from_shard == 1 and p.to_shard == 0
                              for p in recoveries)
    # Ledger conservation: manager T-SA == sum of shard T-SA (the dead
    # shard keeps what it accrued), recovery charged explicitly on top.
    assert res.ledger["t_tsa"] == pytest.approx(
        sum(s["t_tsa"] for s in res.shard_ledgers), rel=1e-9)
    assert res.ledger["recovery_cost"] == 2.0 * len(recoveries)
    assert res.ledger["total"] == pytest.approx(
        res.ledger["t_tsa"] + res.ledger["recovery_cost"], rel=1e-12)

    nofault = FleetManager(_fleet_spec(hp), n_shards=2, migration=False)
    nofault.set_pretrained(tp, sp)
    ref = nofault.run(_streams(3), duration=40.0)
    assert res.fleet_avg_accuracy == pytest.approx(
        ref.fleet_avg_accuracy, abs=0.15)


# -------------------------------------------------------------- admission
def test_lane_admission_mid_run(pretrained):
    """A camera joining at t=10 lands on the headroom shard at the first
    phase boundary past its due time and is scored from the join point,
    not from t=0."""
    hp, tp, sp = pretrained
    mgr = FleetManager(_fleet_spec(hp), n_shards=2, migration=False)
    mgr.set_pretrained(tp, sp)
    late = DriftStream(scenario("ES1", 2), seed=9, img=24)
    res = mgr.run(_streams(2), duration=40.0,
                  admissions=[(10.0, "late", late)])
    assert "late" in res.lane_results
    admits = [e for e in res.events if e.kind == "admit"]
    assert len(admits) == 1 and admits[0].key == "late"
    assert admits[0].t >= 10.0
    lane = res.lane_results["late"]
    assert lane.records
    assert lane.records[0].phase_start >= 10.0  # no phases before joining
    assert all(t >= 10.0 for t, _ in lane.accuracy_timeline)
    assert any(p.kind == "admit" and p.key == "late"
               for d in res.decisions for p in d.placements)


# -------------------------------------------------------------- migration
def test_detach_attach_resumes_bit_identically(pretrained):
    """The migration primitive: detach a lane into a LaneSnapshot at a
    phase boundary and re-attach it (weights, optimizer, buffer, RNG,
    policy state, pipeline) — the remaining run is bit-identical to one
    that was never interrupted."""
    hp, tp, sp = pretrained
    sess_a = _fleet_spec(hp).build()
    sess_a.set_pretrained(tp, sp)
    ref = sess_a.run(_streams(1), duration=40.0)

    sess_b = _fleet_spec(hp).build()
    sess_b.set_pretrained(tp, sp)
    run = sess_b.open_run(_streams(1), duration=40.0)
    for _ in range(3):
        assert run.step()
    snap, pipe = run.detach_lane(0)
    assert run.n_lanes == 0
    run.attach_lane(pipe, snapshot=snap, own=True)
    while run.step():
        pass
    got = run.finalize()
    run.close()
    assert got.fleet_phase_log == ref.fleet_phase_log
    for lane, lane_ref in zip(got.streams, ref.streams):
        assert lane.accuracy_timeline == lane_ref.accuracy_timeline
        _assert_records_identical(lane.records, lane_ref.records)


class _MigrateOnce(PlacementPolicy):
    """Test policy: fewest-lanes placement, exactly one forced migration."""

    name = "migrate-once"

    def __init__(self, spec=None):
        super().__init__(spec)
        self.fired = False

    def place(self, views):
        order = sorted((v for v in views if v.placeable),
                       key=lambda v: (v.n_lanes, v.index))
        return order[0].index

    def migrate(self, views, lanes):
        if self.fired or not lanes:
            return None
        lane = lanes[0]
        targets = [v for v in views
                   if v.placeable and v.index != lane.shard]
        if not targets:
            return None
        self.fired = True
        return lane, targets[0].index


def test_manager_migration_via_custom_policy(pretrained):
    """A pluggable policy that forces one migration: the lane moves
    between shards mid-run (a 'migrate' event and PlacementAction), keeps
    its record history, and the ledger stays conserved."""
    hp, tp, sp = pretrained
    policy = _MigrateOnce()
    mgr = FleetManager(_fleet_spec(hp), n_shards=2, placement=policy,
                       migration=True, migration_cooldown=0)
    mgr.set_pretrained(tp, sp)
    res = mgr.run(_streams(2), duration=40.0)
    migs = [e for e in res.events if e.kind == "migrate"]
    assert len(migs) == 1
    moved = res.lane_results[migs[0].key]
    # History crosses the move: phases from before AND after the event.
    assert moved.records[0].phase_start < migs[0].t
    assert moved.records[-1].phase_start >= migs[0].t - 1e-6
    assert any(p.kind == "migrate" for d in res.decisions
               for p in d.placements)
    assert res.conservation_gap() == pytest.approx(0.0, abs=1e-9)
    assert set(res.lane_results) == {"cam0", "cam1"}


# --------------------------------------------- durable snapshot round-trip
def test_snapshot_state_roundtrip_through_checkpoint(pretrained, tmp_path):
    """snapshot_to_state/state_to_snapshot invert each other through a
    real CheckpointManager save/restore — including the empty-buffer
    sentinel and the pickled aux blob."""
    hp, tp, sp = pretrained
    sess = _fleet_spec(hp).build()
    sess.set_pretrained(tp, sp)
    run = sess.open_run(_streams(1), duration=40.0)
    run.step()
    run.step()
    snap = run.snapshot_lane(0)
    run.close()

    state = snapshot_to_state(snap)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(3, state)
    restored_state, manifest = mgr.restore(3, state)
    back = state_to_snapshot(restored_state)
    assert manifest["step"] == 3
    for tree_name in ("params", "opt"):
        a = getattr(snap, tree_name)
        b = getattr(back, tree_name)
        for la, lb in zip(jax.tree_util.tree_leaves(a),
                          jax.tree_util.tree_leaves(b)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    assert back.rng_state == snap.rng_state
    assert back.buffer["capacity"] == snap.buffer["capacity"]
    assert back.buffer["rng_state"] == snap.buffer["rng_state"]
    if snap.buffer["x"] is None:
        assert back.buffer["x"] is None
    else:
        np.testing.assert_array_equal(back.buffer["x"], snap.buffer["x"])
        np.testing.assert_array_equal(back.buffer["y"], snap.buffer["y"])
    assert back.records == snap.records
    assert back.timeline == snap.timeline
    assert back.decision == snap.decision
    assert back.lane_state == snap.lane_state
    assert back.clock == snap.clock


def test_snapshot_restore_requantizes_serving_copy(pretrained, tmp_path):
    """A LaneSnapshot restore freshly quantizes the restored tree: the
    serving cache (PR 7) can never hand a restored lane a stale quantized
    copy — snapshot params are host-copied, so the restored tree is a new
    object and identity keying forces a miss."""
    from repro.core import mx as mx_lib

    hp, tp, sp = pretrained
    spec = FleetSpec(student=RESNET18, teacher=WIDERESNET50, hp=hp,
                     fleet_mode="drift-weighted", apply_mx=True, seed=0,
                     eval_fps=0.5)
    sess = spec.build()
    sess.set_pretrained(tp, sp)
    run = sess.open_run(_streams(1), duration=40.0)
    run.step()
    snap = run.snapshot_lane(0)
    run.close()
    cache = sess.inference.serving_cache
    misses_before = cache.stats()["misses"]

    state = snapshot_to_state(snap)
    ckpt = CheckpointManager(str(tmp_path), async_save=False)
    ckpt.save(1, state)
    restored_state, _ = ckpt.restore(1, state)
    back = state_to_snapshot(restored_state)

    run2 = sess.open_run(None, duration=40.0)
    lane = run2.attach_lane(_streams(1)[0], key="cam0", snapshot=back)
    # New tree object -> cache MISS, never a stale hit.
    assert cache.stats()["misses"] == misses_before + 1
    entry = cache._entries[id(lane.params)]
    assert entry[0] is lane.params
    # PR 9: slots hold the RESIDENT quantized tree; .value memoizes the
    # dequantized serving copy legacy callers (the lane apply path) read.
    (prec, slot), = entry[1].items()
    assert lane.serving is slot.value
    # And the serving copy is exactly quantize_tree(restored params).
    expect = mx_lib.quantize_tree(lane.params, prec)
    for la, lb in zip(jax.tree_util.tree_leaves(lane.serving),
                      jax.tree_util.tree_leaves(expect)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    run2.close()


def test_empty_buffer_snapshot_roundtrip():
    """The zeros((0,)) sentinel: a never-filled buffer survives the npz
    encoding (None is not a pytree leaf)."""
    from repro.core.fleet import LaneSnapshot
    snap = LaneSnapshot(
        key="k", params={"w": np.ones((2, 2), np.float32)},
        opt={"m": np.zeros((2, 2), np.float32)},
        buffer={"x": None, "y": None, "capacity": 16, "rng_state": {}},
        rng_state={}, policy=None, lane_state=(), decision=None,
        eval_cursor=1.0, retrain_time=0.0, label_time=0.0,
        drift_events=0, records=[], timeline=[], clock=2.0)
    back = state_to_snapshot(snapshot_to_state(snap))
    assert back.buffer["x"] is None and back.buffer["y"] is None
    assert back.key == "k" and back.clock == 2.0


# ----------------------------------------------------------- the registry
def test_placement_policy_registry():
    assert set(PLACEMENT_POLICIES) == {"static", "headroom", "drift-pack",
                                       "estimator"}
    assert isinstance(PlacementPolicy("static"), StaticPlacementPolicy)
    assert isinstance(PlacementPolicy("drift-pack"),
                      DriftPackPlacementPolicy)
    assert isinstance(make_placement_policy("headroom", min_gap=3),
                      HeadroomPlacementPolicy)
    assert make_placement_policy("headroom", min_gap=3).min_gap == 3
    inst = StaticPlacementPolicy()
    assert make_placement_policy(inst) is inst
    with pytest.raises(KeyError, match="unknown placement policy"):
        PlacementPolicy("nope")
    with pytest.raises(TypeError, match="unexpected keyword"):
        PlacementPolicy("static", bogus=1)


def test_headroom_policy_places_and_migrates():
    def view(i, n, recent, drifted=0, alive=True, done=False):
        return ShardView(index=i, alive=alive, done=done, n_lanes=n,
                         clock=0.0, t_tsa=0.0, recent_t_tsa=recent,
                         drifted_lanes=drifted)

    pol = HeadroomPlacementPolicy(min_gap=2)
    # Fewest lanes wins; recent T-SA breaks ties.
    assert pol.place([view(0, 2, 1.0), view(1, 1, 9.0)]) == 1
    assert pol.place([view(0, 1, 5.0), view(1, 1, 2.0)]) == 1
    # Dead/done shards are never placement targets.
    assert pol.place([view(0, 0, 0.0, alive=False), view(1, 3, 9.0)]) == 1
    # Migration needs a drifted lane on an oversubscribed shard.
    from repro.core.manager import LaneView
    lanes = [LaneView(shard=0, index=0, key="a", drifted=True,
                      drift_events=2),
             LaneView(shard=0, index=1, key="b", drifted=False,
                      drift_events=0)]
    got = pol.migrate([view(0, 3, 9.0, drifted=1), view(1, 1, 1.0)], lanes)
    assert got is not None and got[0].key == "a" and got[1] == 1
    # Gap below min_gap: hysteresis holds the lane in place.
    assert pol.migrate([view(0, 2, 9.0, drifted=1), view(1, 1, 1.0)],
                       lanes) is None


def test_manager_spec_builds(pretrained):
    hp, _, _ = pretrained
    spec = ManagerSpec(fleet=_fleet_spec(hp), n_shards=3,
                       placement="drift-pack", migration=False,
                       parallel_shards=3, shard_pace=0.0,
                       migration_cost_s=1.0)
    mgr = spec.build()
    assert mgr.n_shards == 3
    assert isinstance(mgr.placement, DriftPackPlacementPolicy)
    assert not mgr.migration
    assert mgr.parallel_shards == 3
    assert mgr.migration_cost_s == 1.0
    with pytest.raises(ValueError):
        FleetManager(_fleet_spec(hp), n_shards=0)


# ------------------------------------------- overlapped (parallel) stepping
def _assert_manager_results_identical(a, b):
    """Full bit-identity of two ManagerResults: accuracy, two-level
    ledgers, the decision stream, the event timeline, and every lane's
    records."""
    assert a.fleet_avg_accuracy == b.fleet_avg_accuracy
    assert a.ledger == b.ledger
    assert a.shard_ledgers == b.shard_ledgers
    assert a.rounds == b.rounds
    assert a.decisions == b.decisions
    assert a.events == b.events
    assert set(a.lane_results) == set(b.lane_results)
    for key in a.lane_results:
        la, lb = a.lane_results[key], b.lane_results[key]
        assert la.accuracy_timeline == lb.accuracy_timeline
        _assert_records_identical(la.records, lb.records)


@pytest.mark.parametrize("dispatch", ["sequential", "concurrent"])
def test_parallel_stepping_bit_identical_to_serial(pretrained, dispatch):
    """The tentpole contract: a 3-shard manager stepped on the worker
    pool produces the same ManagerResult — records, ledgers, decisions,
    events — as serial stepping, in both dispatch modes."""
    hp, tp, sp = pretrained
    results = {}
    for workers in (0, 3):
        mgr = FleetManager(_fleet_spec(hp, dispatch), n_shards=3,
                           placement="static", migration=False,
                           parallel_shards=workers)
        mgr.set_pretrained(tp, sp)
        results[workers] = mgr.run(_streams(3), duration=40.0)
    assert results[0].parallel_rounds == 0
    assert results[3].parallel_rounds > 0  # the pool really stepped
    _assert_manager_results_identical(results[0], results[3])


def test_parallel_fault_recovery_matches_serial(pretrained, tmp_path):
    """A shard dying mid-round UNDER THE POOL recovers exactly like the
    serial path: same fail/recover events, same recovery placements,
    same conserved ledger, same surviving-lane records."""
    hp, tp, sp = pretrained
    results = {}
    for workers in (0, 3):
        inj = FailureInjector(fail_at_steps=[(2, 1)])
        mgr = FleetManager(_fleet_spec(hp), n_shards=3,
                           placement="static", migration=False,
                           checkpoint_dir=str(tmp_path / f"w{workers}"),
                           checkpoint_every=2, failure_injector=inj,
                           recovery_cost_s=2.0, parallel_shards=workers)
        mgr.set_pretrained(tp, sp)
        results[workers] = mgr.run(_streams(3), duration=40.0)
    par = results[3]
    assert par.parallel_rounds > 0
    kinds = [e.kind for e in par.events]
    assert kinds.count("fail") == 1
    assert par.shard_results[1] is None
    assert "recover" in kinds
    assert set(par.lane_results) == {"cam0", "cam1", "cam2"}
    _assert_manager_results_identical(results[0], par)


def test_parallel_event_ordering_deterministic(pretrained):
    """Two identical overlapped runs — admissions and migrations live —
    emit identical event and decision streams: ordering never depends on
    worker completion order."""
    hp, tp, sp = pretrained
    runs = []
    for _ in range(2):
        mgr = FleetManager(_fleet_spec(hp), n_shards=3,
                           placement="headroom",
                           placement_kwargs={"min_gap": 1},
                           migration=True, migration_cooldown=1,
                           parallel_shards=3)
        mgr.set_pretrained(tp, sp)
        late = DriftStream(scenario("ES1", 2), seed=9, img=24)
        runs.append(mgr.run(_streams(3), duration=40.0,
                            admissions=[(10.0, "late", late)]))
    a, b = runs
    assert a.parallel_rounds > 0
    assert [(e.round, e.kind, e.shard, e.key, e.to_shard) for e in a.events] \
        == [(e.round, e.kind, e.shard, e.key, e.to_shard) for e in b.events]
    _assert_manager_results_identical(a, b)


# ------------------------------------------------- estimator-driven placement
def _eview(i, n, recent, phase_s=10.0, drifted=0, alive=True, done=False):
    return ShardView(index=i, alive=alive, done=done, n_lanes=n, clock=0.0,
                     t_tsa=0.0, recent_t_tsa=recent, drifted_lanes=drifted,
                     recent_phase_s=phase_s)


def test_estimator_policy_registered_with_knobs():
    pol = PlacementPolicy("estimator", migration_cost_s=1.0,
                          horizon_rounds=2, oversub_limit=1.2)
    assert isinstance(pol, EstimatorPlacementPolicy)
    assert pol.model.migration_cost_s == 1.0
    assert pol.model.horizon_rounds == 2
    assert pol.model.oversub_limit == 1.2
    with pytest.raises(TypeError, match="unexpected keyword"):
        PlacementPolicy("estimator", bogus=1)


def test_estimator_places_and_admits_by_seconds():
    pol = EstimatorPlacementPolicy(oversub_limit=1.0)
    # Placement minimizes predicted load in SECONDS, not lane count.
    assert pol.place([_eview(0, 1, 8.0), _eview(1, 3, 2.0)]) == 1
    # Cold start (no phase history anywhere): always admits.
    assert pol.admit([_eview(0, 0, 0.0, phase_s=0.0),
                      _eview(1, 0, 0.0, phase_s=0.0)]) == 0
    # Mean lane cost (4+6)/2 = 5s: shard 0 fits ((4+5)/10 <= 1.0),
    # shard 1 would oversubscribe ((6+5)/10 > 1.0).
    assert pol.admit([_eview(0, 1, 4.0), _eview(1, 1, 6.0)]) == 0
    # Every shard past the utilization limit with one more lane: reject.
    assert pol.admit([_eview(0, 2, 9.5), _eview(1, 2, 9.0)]) is None


def test_estimator_migrates_on_load_max_gain():
    lanes = [LaneView(shard=0, index=0, key="a", drifted=True,
                      drift_events=1, recent_t_tsa=6.0),
             LaneView(shard=0, index=1, key="b", drifted=False,
                      drift_events=0, recent_t_tsa=2.0),
             LaneView(shard=1, index=0, key="c", drifted=False,
                      drift_events=0, recent_t_tsa=1.0)]
    views = [_eview(0, 2, 8.0), _eview(1, 1, 1.0)]
    pol = EstimatorPlacementPolicy(migration_cost_s=2.0, horizon_rounds=4)
    got = pol.migrate(views, lanes)
    # Moving "a" (6s): loads [8,1] -> [2,7], gain (8-7)*4 = 4s.
    # Moving "b" (2s): loads [8,1] -> [6,3], gain (8-6)*4 = 8s — best.
    assert got is not None
    assert got[0].key == "b" and got[1] == 1
    # The same proposal under a prohibitive move cost does not fire.
    dear = EstimatorPlacementPolicy(migration_cost_s=10.0, horizon_rounds=4)
    assert dear.migrate(views, lanes) is None
    # A shard's last lane never migrates, whatever the gain.
    solo = [LaneView(shard=0, index=0, key="a", drifted=True,
                     drift_events=1, recent_t_tsa=8.0)]
    assert pol.migrate([_eview(0, 1, 8.0), _eview(1, 1, 0.5)], solo) is None


def test_placement_cost_model_arithmetic():
    from repro.core.estimator import PlacementCostModel
    model = PlacementCostModel(migration_cost_s=3.0, horizon_rounds=2,
                               oversub_limit=1.5)
    assert model.round_time_s([4.0, 9.0, 1.0]) == 9.0
    assert model.migration_gain_s([9.0, 1.0], 0, 1, 4.0) \
        == pytest.approx((9.0 - 5.0) * 2)
    assert model.worth_migrating([9.0, 1.0], 0, 1, 4.0)
    assert not model.worth_migrating([9.0, 8.0], 0, 1, 0.5)
    assert model.utilization(12.0, 8.0) == 1.5
    assert model.utilization(1.0, 0.0) == 0.0
    assert model.admits(8.0, 8.0, 4.0)       # 1.5 <= 1.5
    assert not model.admits(8.1, 8.0, 4.0)   # just past the limit


def test_manager_surfaces_admission_rejection(pretrained):
    """An oversubscribed fleet turns a late camera away: the rejection is
    a first-class PlacementAction/event and the camera never runs."""
    hp, tp, sp = pretrained
    mgr = FleetManager(_fleet_spec(hp), n_shards=2, placement="estimator",
                       placement_kwargs={"oversub_limit": -1.0},
                       migration=False)
    mgr.set_pretrained(tp, sp)
    late = DriftStream(scenario("ES1", 2), seed=9, img=24)
    res = mgr.run(_streams(2), duration=40.0,
                  admissions=[(10.0, "late", late)])
    assert "late" not in res.lane_results
    assert set(res.lane_results) == {"cam0", "cam1"}
    rejects = [p for d in res.decisions for p in d.placements
               if p.kind == "reject"]
    assert len(rejects) == 1
    assert rejects[0].key == "late" and rejects[0].to_shard is None
    assert any(e.kind == "reject" and e.key == "late" for e in res.events)


def test_migration_cost_charged_to_ledger(pretrained):
    """Every policy migration charges migration_cost_s to the manager
    ledger, and 'total' carries it on top of T-SA + recovery."""
    hp, tp, sp = pretrained
    mgr = FleetManager(_fleet_spec(hp), n_shards=2,
                       placement=_MigrateOnce(), migration=True,
                       migration_cooldown=0, migration_cost_s=1.5)
    mgr.set_pretrained(tp, sp)
    res = mgr.run(_streams(2), duration=40.0)
    migs = [e for e in res.events if e.kind == "migrate"]
    assert len(migs) == 1
    assert res.ledger["migration_cost"] == 1.5
    assert res.ledger["total"] == pytest.approx(
        res.ledger["t_tsa"] + res.ledger["recovery_cost"] + 1.5, rel=1e-12)
    assert res.conservation_gap() == pytest.approx(0.0, abs=1e-9)
