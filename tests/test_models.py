"""Per-arch smoke tests (reduced configs): fwd/train-step shapes + no NaNs,
prefill->decode consistency, and the Table III vision models."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.dacapo_pairs import TABLE_III, VISION_MODELS
from repro.models.registry import make_vision_model
from repro.models.transformer import make_model

ARCH_NAMES = sorted(configs.ARCHS)


def _batch(cfg, key, b=2, s=32):
    if cfg.input_mode == "embeddings":
        inputs = jax.random.normal(key, (b, s, cfg.d_model))
    else:
        inputs = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.num_output_heads > 1:
        labels = jax.random.randint(key, (b, s, cfg.num_output_heads), 0,
                                    cfg.vocab_size)
    else:
        labels = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return {"inputs": inputs, "labels": labels}


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_and_train_step(name):
    cfg = configs.ARCHS[name].reduced()
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), name
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves), name
    # Output head shapes.
    x, _, _ = model.hidden(params, batch["inputs"], mode="prefill",
                           positions=jnp.arange(32),
                           caches=model.init_caches(2, 32), remat=False)
    logits = model.logits(params, x)
    if cfg.num_output_heads > 1:
        assert logits.shape == (2, 32, cfg.num_output_heads, cfg.vocab_size)
    else:
        assert logits.shape == (2, 32, cfg.vocab_size)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_consistency(name):
    cfg = configs.ARCHS[name].reduced()
    over = {}
    if cfg.sliding_window:
        over["sliding_window"] = 8
    if cfg.local_window:
        over["local_window"] = 8
    if cfg.num_experts:
        over["capacity_factor"] = 16.0  # no token drops -> exact equality
    cfg = dataclasses.replace(cfg, **over)
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    key = jax.random.PRNGKey(1)
    if cfg.input_mode == "embeddings":
        full = jax.random.normal(key, (b, s + 1, cfg.d_model))
    else:
        full = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
    x, _, _ = model.hidden(params, full, mode="prefill",
                           positions=jnp.arange(s + 1),
                           caches=model.init_caches(b, s + 1), remat=False)
    ref = model.logits(params, x[:, -1:])[:, 0]
    _, caches = model.prefill(params, full[:, :s], cache_capacity=s + 1)
    out, _ = model.decode_step(params, full[:, s:s + 1], jnp.asarray(s),
                               caches)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-3)


def test_multi_token_decode_matches_prefill():
    """Decode 4 tokens sequentially == prefill of the longer sequence."""
    cfg = configs.ARCHS["yi-6b"].reduced()
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s, extra = 2, 12, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s + extra), 0,
                              cfg.vocab_size)
    _, caches = model.prefill(params, toks[:, :s], cache_capacity=s + extra)
    outs = []
    for i in range(extra):
        logits, caches = model.decode_step(
            params, toks[:, s + i: s + i + 1], jnp.asarray(s + i), caches)
        outs.append(logits)
    x, _, _ = model.hidden(params, toks, mode="prefill",
                           positions=jnp.arange(s + extra),
                           caches=model.init_caches(b, s + extra),
                           remat=False)
    ref = model.logits(params, x)
    for i, got in enumerate(outs):
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref[:, s + i]),
                                   rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("name", sorted(VISION_MODELS))
def test_vision_param_counts_match_table3(name):
    cfg = VISION_MODELS[name]
    m = make_vision_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    n = m.param_count(params)
    ref_n, _ = TABLE_III[name]
    assert abs(n - ref_n) / ref_n < 0.02, (name, n, ref_n)


@pytest.mark.parametrize("name", ["resnet18", "vit-b32"])
def test_vision_forward(name):
    cfg = VISION_MODELS[name].reduced()
    m = make_vision_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (2, cfg.img_size, cfg.img_size, 3))
    out = m.apply(params, x)
    assert out.shape == (2, cfg.num_classes)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_param_count_analytic_close_to_actual():
    for name in ("yi-6b", "gemma2-2b", "mixtral-8x7b"):
        cfg = configs.ARCHS[name].reduced()
        model = make_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        actual = sum(p.size for p in jax.tree_util.tree_leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.06, (name, actual,
                                                        analytic)
