"""Pallas flash-attention kernel: shape/dtype sweep vs the pure-jnp oracle,
plus the chunked-jnp model attention vs the oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as fa_kernel
from repro.models.attention import flash_attention as fa_chunked
from repro.models.attention import flash_decode


@pytest.mark.parametrize("b,sq,skv,h,kv,d", [
    (1, 128, 128, 4, 4, 64),   # MHA
    (2, 128, 128, 8, 2, 64),   # GQA
    (1, 256, 256, 4, 1, 32),   # MQA
    (2, 64, 64, 4, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_sweep(b, sq, skv, h, kv, d, dtype):
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (b, sq, h, d), dtype)
    k = jax.random.normal(keys[1], (b, skv, kv, d), dtype)
    v = jax.random.normal(keys[2], (b, skv, kv, d), dtype)
    out = fa_kernel(q, k, v, causal=True, interpret=True, qb=64, kvb=64)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [32, 64])
@pytest.mark.parametrize("softcap", [None, 20.0])
def test_flash_kernel_window_softcap(window, softcap):
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(keys[0], (1, 256, 4, 32))
    k = jax.random.normal(keys[1], (1, 256, 2, 32))
    v = jax.random.normal(keys[2], (1, 256, 2, 32))
    out = fa_kernel(q, k, v, causal=True, window=window, softcap=softcap,
                    interpret=True, qb=64, kvb=64)
    expect = ref.flash_attention_ref(q, k, v, causal=True, window=window,
                                     softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [None, 16, 48])
def test_chunked_jnp_attention_matches_oracle(window):
    """The model's scan-based flash (dry-run path) == the naive oracle."""
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(keys[0], (2, 96, 8, 32))
    k = jax.random.normal(keys[1], (2, 96, 2, 32))
    v = jax.random.normal(keys[2], (2, 96, 2, 32))
    out = fa_chunked(q, k, v, causal=True, window=window, q_block=32)
    expect = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=3e-5, atol=3e-5)


def test_flash_decode_matches_full_attention():
    """Single-token flash-decode == last row of full attention."""
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    b, s, h, kv, d = 2, 64, 8, 4, 32
    q_all = jax.random.normal(keys[0], (b, s, h, d))
    k = jax.random.normal(keys[1], (b, s, kv, d))
    v = jax.random.normal(keys[2], (b, s, kv, d))
    expect = ref.flash_attention_ref(q_all, k, v, causal=True)[:, -1]
    kv_pos = jnp.arange(s)
    out = flash_decode(q_all[:, -1], k.transpose(0, 2, 1, 3),
                       v.transpose(0, 2, 1, 3), kv_pos, jnp.asarray(s - 1),
                       window=None, logit_softcap=None, scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=3e-5, atol=3e-5)


def test_flash_decode_ring_buffer_semantics():
    """Slots with pos outside the window are masked out."""
    b, L, kv, d, h = 1, 8, 1, 16, 2
    keys = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(keys[0], (b, h, d))
    k = jax.random.normal(keys[1], (b, kv, L, d))  # head-major cache
    v = jax.random.normal(keys[2], (b, kv, L, d))
    kv_pos = jnp.array([16, 9, 10, 11, 12, 13, 14, 15])  # ring at t=16
    out_w4 = flash_decode(q, k, v, kv_pos, jnp.asarray(16), window=4,
                          logit_softcap=None, scale=d ** -0.5)
    # manual: only pos in (12, 16] valid -> slots 0 (16), 5..7 (13,14,15)
    s = jnp.einsum("bhd,bld->bhl", q, k[:, 0]) * d ** -0.5
    valid = (kv_pos > 12) & (kv_pos <= 16)
    s = jnp.where(valid[None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    expect = jnp.einsum("bhl,bld->bhd", p, v[:, 0])
    np.testing.assert_allclose(np.asarray(out_w4), np.asarray(expect),
                               rtol=3e-5, atol=3e-5)


def test_kernel_flop_scaling_window():
    """Windowed flash does O(S*W) work: HLO flops must shrink with W."""
    from repro.launch.hlo_analysis import analyze_hlo_text

    def lower_flops(window):
        q = jax.ShapeDtypeStruct((1, 1024, 4, 32), jnp.float32)
        k = jax.ShapeDtypeStruct((1, 1024, 2, 32), jnp.float32)
        fn = lambda q, k, v: fa_chunked(q, k, v, causal=True, window=window,
                                        q_block=128)
        text = jax.jit(fn).lower(q, k, k).compile().as_text()
        return analyze_hlo_text(text).flops

    full = lower_flops(None)
    win = lower_flops(128)
    assert win < full * 0.5, (win, full)
