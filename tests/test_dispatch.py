"""Dispatch-layer tests: PhasePlan clock semantics (sum vs. max), async
handles, concurrent-mode session accounting, fused/microbatched kernel entry
points, retrain cost accounting, single-row mesh degeneration, online
re-partitioning, and the prefetching window iterator."""
import dataclasses

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs.dacapo_pairs import RESNET18, WIDERESNET50
from repro.core.allocation import AllocationPolicy, CLHyperParams
from repro.core.dispatch import (
    CONCURRENT,
    SEQUENTIAL,
    KernelDispatcher,
    PhasePlan,
    ProgramHandle,
)
from repro.core.estimator import DaCapoEstimator
from repro.core.kernel import InferenceKernel, LabelingKernel, RetrainKernel
from repro.core.partition import forced_row_mesh
from repro.core.session import CLSystemSpec, pretrain_model
from repro.core import session as session_mod
from repro.data.stream import DriftStream, PrefetchingWindowIterator, scenario
from repro.models.registry import make_vision_model


# ------------------------------------------------------------- plan clock --
def test_phaseplan_sequential_charges_sum():
    plan = PhasePlan(SEQUENTIAL, start=10.0)
    plan.charge("t_sa", 2.0)
    plan.dispatch("t_sa", "valid", lambda: np.arange(3), cost_s=1.0)
    plan.dispatch("b_sa", "score", lambda: np.arange(3), cost_s=100.0)
    # B-SA measurement never gates the serial chain (seed semantics).
    assert plan.now() == 13.0
    assert plan.finish() == 13.0
    assert plan.t_tsa == 3.0 and plan.t_bsa == 100.0


def test_phaseplan_concurrent_charges_max():
    plan = PhasePlan(CONCURRENT, start=10.0)
    plan.charge("t_sa", 3.0)
    plan.dispatch("b_sa", "score", lambda: np.arange(3), cost_s=1.0)
    assert plan.finish() == pytest.approx(13.0)  # T-SA dominates
    plan.dispatch("b_sa", "score", lambda: np.arange(3), cost_s=4.0)
    assert plan.finish() == pytest.approx(15.0)  # B-SA now dominates
    # now() remains the T-SA running clock in both modes.
    assert plan.now() == 13.0


@pytest.mark.parametrize("mode", [SEQUENTIAL, CONCURRENT])
def test_phaseplan_pacing_floor(mode):
    plan = PhasePlan(mode, start=0.0)
    plan.charge("t_sa", 1.0)
    plan.pad_to(10.0)
    assert plan.finish() == 10.0
    plan.charge("t_sa", 20.0)
    assert plan.finish() == 21.0  # kernel time beyond the floor wins


def test_program_handle_collects_once():
    calls = []

    class Tracker:
        def __array__(self, dtype=None):
            calls.append(1)
            return np.arange(4, dtype=dtype)

    h = ProgramHandle(Tracker())
    a = h.collect()
    b = h.collect()
    assert a is b and len(calls) == 1
    assert isinstance(a, np.ndarray)


def test_dispatcher_rejects_unknown_mode_and_counts():
    with pytest.raises(ValueError):
        KernelDispatcher("warp-speed")
    d = KernelDispatcher(CONCURRENT)
    assert d.concurrent
    plan = d.begin_phase(0.0)
    plan.dispatch("t_sa", "x", lambda: np.zeros(1))
    plan.dispatch("b_sa", "y", lambda: np.zeros(1))
    assert d.phases_dispatched == 1 and d.programs_dispatched == 2
    plan.collect_all()  # must not raise; all handles materialized
    assert all(p.handle._collected for p in plan.programs)


# --------------------------------------------------------------- kernels --
@pytest.fixture(scope="module")
def kernel_setup():
    est = DaCapoEstimator()
    model = make_vision_model(RESNET18.reduced())
    params = model.init(jax.random.PRNGKey(0))
    x = np.asarray(
        jax.random.normal(jax.random.PRNGKey(1), (20, 24, 24, 3)),
        np.float32)
    return est, model, params, x


def test_predict_batched_fuses_windows(kernel_setup):
    est, model, params, x = kernel_setup
    k = InferenceKernel(model, RESNET18, est, apply_mx=False)
    windows = [x[:6], x[6:13], x[13:]]
    k.n_apply_calls = 0
    per_window = [np.asarray(k.predict_async(params, w)) for w in windows]
    calls_pw = k.n_apply_calls
    k.n_apply_calls = 0
    fused = [np.asarray(p) for p in k.predict_batched(params, windows)]
    calls_f = k.n_apply_calls
    assert calls_pw == 3 and calls_f == 1  # fewer jitted calls, same preds
    for a, b in zip(per_window, fused):
        assert np.array_equal(a, b)
    assert k.predict_batched(params, []) == []


def test_label_microbatch_equivalence(kernel_setup):
    est, model, params, x = kernel_setup
    k = LabelingKernel(model, WIDERESNET50, est, apply_mx=False)
    k.n_apply_calls = 0
    full = k.label(params, x, "mx9")
    assert k.n_apply_calls == 1
    micro = k.label(params, x, "mx9", microbatch=8)
    assert k.n_apply_calls == 1 + 3  # ceil(20/8) chunks
    assert np.array_equal(full, micro)


def test_retrain_fit_charges_only_executed_batches(kernel_setup):
    est, model, params, x = kernel_setup
    hp = CLHyperParams(sgd_batch=16, epochs=2)
    k = RetrainKernel(model, RESNET18, est, hp)
    opt = k.init_state(params)
    rng = np.random.default_rng(0)
    # D_t smaller than one SGD batch: zero steps execute -> zero charged.
    xt, yt = x[:8], np.zeros(8, np.int32)
    new_params, _, n_batches = k.fit(params, opt, xt, yt, rng)
    assert n_batches == 0
    before = jax.tree_util.tree_leaves(params)
    after = jax.tree_util.tree_leaves(new_params)
    assert all(np.array_equal(a, b) for a, b in zip(before, after))
    # A full batch executes (and charges) exactly epochs steps.
    xt, yt = x[:16], np.zeros(16, np.int32)
    _, _, n_batches = k.fit(params, opt, xt, yt, rng)
    assert n_batches == 2


# --------------------------------------------------------------- session --
@pytest.fixture(scope="module")
def small_setup():
    stream = DriftStream(scenario("S1", 2), seed=5, img=24)
    hp = CLHyperParams(n_t=32, n_l=16, c_b=128, epochs=1)
    rng = np.random.default_rng(0)
    tp = pretrain_model(make_vision_model(WIDERESNET50.reduced()), stream,
                        10, 32, rng)
    sp = pretrain_model(make_vision_model(RESNET18.reduced()), stream,
                        8, 32, rng, segments=stream.segments[:1], seed=8)
    return stream, hp, tp, sp


def _fake_mesh(n_rows: int) -> Mesh:
    return forced_row_mesh(n_rows)


def _spec(hp, **kw) -> CLSystemSpec:
    return CLSystemSpec(student=RESNET18, teacher=WIDERESNET50, hp=hp,
                        allocator="dacapo-spatiotemporal", apply_mx=False,
                        seed=0, eval_fps=0.5, **kw)


def test_concurrent_session_charges_max_per_phase(small_setup):
    """Acceptance: on a forced multi-row mesh, every phase's virtual time is
    exactly max(t_TSA, t_BSA), with both branches of the max exercised."""
    stream, hp, tp, sp = small_setup
    session = _spec(hp, mesh=_fake_mesh(2), dispatch="concurrent").build()
    assert session.dispatcher.concurrent
    session.set_pretrained(tp, sp)
    res = session.run(stream, duration=20.0)
    assert len(res.records) >= 3
    for rec in res.records:
        dt = rec.t - rec.phase_start
        assert dt == pytest.approx(max(rec.t_tsa, rec.t_bsa), rel=1e-12)
        assert rec.t_tsa > 0.0 and rec.t_bsa > 0.0
    # Both sub-accelerators dominate at least once (labeling-only phases are
    # B-SA-bound; retraining phases are T-SA-bound on this fixture).
    assert any(r.t_bsa > r.t_tsa for r in res.records)
    assert any(r.t_tsa > r.t_bsa for r in res.records)
    # Phase 0 closed form: empty buffer -> no retraining, so t_TSA is the
    # teacher labeling time alone.
    rec0 = res.records[0]
    d0 = rec0.decision
    expect_tsa = (d0.total_label_samples
                  * session.labeling.time_per_sample(
                      d0.rows_tsa, d0.precisions.labeling))
    assert rec0.t_tsa == pytest.approx(expect_tsa, rel=1e-12)
    # Learning still happens and the timeline stays ordered.
    assert res.avg_accuracy > 0.0
    ts = [t for t, _ in res.accuracy_timeline]
    assert ts == sorted(ts)


def test_sequential_session_charges_tsa_chain(small_setup):
    """Default mode: phase time is the T-SA serial chain (seed accounting);
    the B-SA ledger is informational only."""
    stream, hp, tp, sp = small_setup
    session = _spec(hp).build()
    assert not session.dispatcher.concurrent
    session.set_pretrained(tp, sp)
    res = session.run(stream, duration=20.0)
    for rec in res.records:
        assert rec.t - rec.phase_start == pytest.approx(rec.t_tsa, rel=1e-12)


def test_concurrent_fuses_score_windows(small_setup):
    """Concurrent dispatch batches each phase's score windows into one
    jitted call — fewer inference dispatches than sequential on the same
    run length."""
    stream, hp, tp, sp = small_setup
    counts = {}
    for mode in ("sequential", "concurrent"):
        session = _spec(hp, dispatch=mode).build()
        session.set_pretrained(tp, sp)
        session.run(stream, duration=20.0)
        counts[mode] = session.inference.n_apply_calls
    assert counts["concurrent"] < counts["sequential"]


def test_single_row_mesh_degenerates_to_time_sharing(small_setup):
    """Regression: a 1-row mesh cannot be fissioned; the engine must fall
    back to time-sharing instead of calling partition_mesh on it."""
    stream, hp, tp, sp = small_setup
    session = _spec(hp, mesh=_fake_mesh(1)).build()
    assert session._mesh_split(8) == 0
    assert session.partition.time_shared
    assert session.inference.submesh is None
    assert session.labeling.submesh is None
    session.set_pretrained(tp, sp)
    res = session.run(stream, duration=10.0)
    assert res.avg_accuracy > 0.0


# ------------------------------------------------------- re-partitioning --
class ScriptedRowsPolicy(AllocationPolicy):
    """Test policy: replays a script of rows_bsa values (estimator rows)."""

    name = "scripted-rows"

    def __init__(self, hp, precision=None, script=()):
        from repro.core.mx import DEFAULT_POLICY
        super().__init__(hp, precision or DEFAULT_POLICY)
        self._script = list(script)

    def _scripted(self):
        if len(self._script) > 1:
            rows_bsa = self._script.pop(0)
        else:
            rows_bsa = self._script[0]  # hold the last split forever
        d = self._decision(self.hp.n_t)
        total = self._rows[0] + self._rows[1]
        return dataclasses.replace(d, rows_tsa=total - rows_bsa,
                                   rows_bsa=rows_bsa)

    def initial_decision(self):
        return self._scripted()

    def next_decision(self, feedback):
        return self._scripted()


def test_online_repartition_rebinds_kernels(small_setup, monkeypatch):
    """A policy that moves rows between T-SA and B-SA mid-run re-fissions
    the mesh and re-binds every kernel; an unchanged split does not
    re-partition."""
    stream, hp, tp, sp = small_setup
    calls = []
    real = session_mod.partition_mesh
    monkeypatch.setattr(session_mod, "partition_mesh",
                        lambda mesh, want: calls.append(want) or
                        real(mesh, want))
    # 16 estimator rows onto a 4-row mesh: 8 -> 2 mesh rows, 12 -> 3.
    policy = ScriptedRowsPolicy(hp, script=[8, 8, 12])
    session = CLSystemSpec(student=RESNET18, teacher=WIDERESNET50, hp=hp,
                           allocator=policy, apply_mx=False, seed=0,
                           eval_fps=0.5, mesh=_fake_mesh(4)).build()
    session.set_pretrained(tp, sp)
    seen = []
    session.add_observer(lambda rec: seen.append(
        (rec.decision.rows_bsa, session.partition,
         session.inference.submesh, session.labeling.submesh)))
    n_before = len(calls)
    res = session.run(stream, duration=16.0)
    assert len(res.records) >= 4
    rows0, part0, inf0, lab0 = seen[0]
    rows1, part1, inf1, lab1 = seen[1]
    rows2, part2, inf2, lab2 = seen[2]
    assert (rows0, rows1, rows2) == (8, 8, 12)
    # Unchanged split: the exact same partition object, no new fission.
    assert part1 is part0 and inf1 is inf0
    # Changed split: new partition, kernels re-bound to the new sub-meshes.
    assert part2 is not part1
    assert inf2 is part2.b_sa and lab2 is part2.t_sa
    assert part0.b_sa.devices.shape[0] == 2  # 8/16 of 4 rows
    assert part2.b_sa.devices.shape[0] == 3  # 12/16 of 4 rows
    assert part2.t_sa.devices.shape[0] == 1
    # partition_mesh ran once per *distinct* split during the run: the
    # offline->8 transition (if any) plus the scripted 8->12 move.
    w_offline = session._mesh_split(session.r_bsa)
    expected = (0 if w_offline == 2 else 1) + 1
    assert len(calls) - n_before == expected


# ------------------------------------------------------------- prefetch --
def test_prefetching_window_iterator_matches_inline():
    stream = DriftStream(scenario("S1", 2), seed=7, img=24)
    it = stream.windows(0.0, 4.0, 1.0, max_frames=6, prefetch=2)
    got = list(it)
    assert [(t0, t1) for t0, t1, _, _ in got] == [
        (0.0, 1.0), (1.0, 2.0), (2.0, 3.0), (3.0, 4.0)]
    for t0, t1, x, y in got:
        xi, yi = stream.frames(t0, t1, max_frames=6)
        assert np.array_equal(x, xi) and np.array_equal(y, yi)


def test_prefetching_window_iterator_close_early():
    stream = DriftStream(scenario("S1", 2), seed=7, img=24)
    it = PrefetchingWindowIterator(
        stream, [(i * 1.0, i * 1.0 + 1.0) for i in range(50)],
        max_frames=4, depth=2)
    next(it)
    it.close()
    assert not it._thread.is_alive()
    # A closed iterator is exhausted — next() must not block on the drained
    # queue.
    with pytest.raises(StopIteration):
        next(it)


def test_prefetching_iterator_abandoned_consumer_stops_producer():
    """Dropping the iterator without close() must not leak a blocked
    producer thread (the producer holds no reference to the iterator, so
    __del__ runs and signals it to stop)."""
    stream = DriftStream(scenario("S1", 2), seed=7, img=24)
    it = PrefetchingWindowIterator(
        stream, [(i * 1.0, i * 1.0 + 1.0) for i in range(100)],
        max_frames=2, depth=1)
    thread = it._thread
    next(it)
    del it
    thread.join(timeout=5.0)
    assert not thread.is_alive()
