"""End-to-end behaviour: the eight deliverables are wired together."""
import jax
import numpy as np


def test_public_api_surface():
    """Deliverable (a): the paper's contribution is importable + composable."""
    from repro.core import (
        CLHyperParams,
        ContinuousLearningSystem,
        DaCapoEstimator,
        PrecisionPolicy,
        SCHEDULERS,
        SampleBuffer,
        mx_dense,
        partition_mesh,
        spatial_allocation,
    )

    # The paper's four systems, plus any later-grown allocators (DC-ST-
    # Online) — the legacy registry is a live view over ALLOCATORS.
    assert set(SCHEDULERS) >= {"dacapo-spatiotemporal", "dacapo-spatial",
                               "ekya", "eomu"}
    assert PrecisionPolicy().retraining == "mx9"  # paper §IV
    assert PrecisionPolicy().inference == "mx6"


def test_all_assigned_cells_enumerate():
    """Deliverable (f): 10 archs x 4 shapes = 40 cells; long_500k skips
    exactly the five pure-full-attention archs."""
    from repro import configs

    cells = list(configs.all_cells(include_skipped=True))
    assert len(cells) == 40
    skipped = [(a.name, s.name) for a, s, ok in cells if not ok]
    assert all(s == "long_500k" for _, s in skipped)
    assert len(skipped) == 5


def test_dryrun_results_artifact():
    """Deliverable (e): the multi-pod dry-run passed for every cell."""
    import json
    import os

    path = os.path.join(os.path.dirname(__file__), "..",
                        "dryrun_results.json")
    if not os.path.exists(path):
        import pytest

        pytest.skip("dry-run artifact not generated yet")
    results = json.load(open(path))
    assert sum(r["status"] == "fail" for r in results) == 0
    assert sum(r["status"] == "ok" for r in results) >= 70
    meshes = {r["mesh"] for r in results}
    assert meshes == {"pod", "multipod"}


def test_train_step_reduces_loss_end_to_end():
    """Deliverable (b): the training driver learns on the bigram corpus."""
    from repro.launch.train import main

    # tiny run through the full substrate (mesh, sharding, ckpt, heartbeat)
    rc = main(["--arch", "xlstm-125m", "--reduced", "--steps", "30",
               "--batch", "8", "--seq", "64", "--lr", "3e-3",
               "--checkpoint-dir", "/tmp/repro_test_ckpt",
               "--checkpoint-every", "1000", "--log-every", "29"])
    assert rc == 0
