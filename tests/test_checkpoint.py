"""Checkpointing: atomic roundtrip, retention, async, torn-write
invisibility, context-manager flush, SampleBuffer state round-trip,
resilient restart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core.sample_buffer import SampleBuffer
from repro.runtime.fault import (
    FailureInjector,
    Heartbeat,
    StragglerDetector,
    resilient_loop,
)


def _state(v=0.0):
    return {"params": {"w": jnp.full((4, 4), v), "b": jnp.zeros((4,))},
            "step": jnp.asarray(int(v), jnp.int32)}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    state = _state(3.0)
    mgr.save(7, state)
    restored, manifest = mgr.restore(None, _state())
    assert manifest["step"] == 7
    np.testing.assert_array_equal(restored["params"]["w"],
                                  state["params"]["w"])


def test_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), max_to_keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(float(s)))
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(5, _state(5.0))
    mgr.wait()
    restored, m = mgr.restore(None, _state())
    assert m["step"] == 5
    assert float(restored["params"]["w"][0, 0]) == 5.0


def test_no_tmp_dirs_left_behind(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _state())
    leftovers = [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]
    assert leftovers == []


def test_close_flushes_inflight_async_save(tmp_path):
    """close() (and the ``with`` form) joins the background writer, so a
    process exiting right after a non-blocking save cannot drop it."""
    with CheckpointManager(str(tmp_path), async_save=True) as mgr:
        mgr.save(9, _state(9.0))
    assert mgr.latest_step() == 9  # committed by __exit__ -> close()
    restored, m = mgr.restore(None, _state())  # manager usable after close
    assert m["step"] == 9
    assert float(restored["params"]["w"][0, 0]) == 9.0


def test_incomplete_manifest_is_invisible(tmp_path):
    """A step directory without a committed manifest.json (torn write) is
    skipped by all_steps/latest_step, and restore falls back to the last
    complete checkpoint instead of crashing."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _state(1.0))
    mgr.save(2, _state(2.0))
    # Simulate a torn step-3: directory exists, manifest never committed.
    torn = tmp_path / "step_0000000003"
    torn.mkdir()
    (torn / "shard_0.npz").write_bytes(b"garbage")
    assert mgr.all_steps() == [1, 2]
    assert mgr.latest_step() == 2
    restored, m = mgr.restore(None, _state())
    assert m["step"] == 2
    assert float(restored["params"]["w"][0, 0]) == 2.0
    # A manifest that exists as a *directory* is equally invisible.
    (torn / "manifest.json").mkdir()
    assert mgr.latest_step() == 2


def test_sample_buffer_state_roundtrip():
    """state_dict/load_state_dict round-trips contents AND the draw RNG's
    bit-generator state: the restored buffer makes bit-identical future
    get_data permutations."""
    rng = np.random.default_rng(0)
    a = SampleBuffer(capacity=8, seed=11)
    for _ in range(5):  # overflow capacity -> eviction path exercised
        a.update(rng.normal(size=(4, 3)).astype(np.float32),
                 rng.integers(0, 10, size=4))
    a.get_data(4, 2)  # advance the RNG so its state is mid-stream
    state = a.state_dict()
    b = SampleBuffer(capacity=1, seed=99)  # wrong capacity/seed on purpose
    b.load_state_dict(state)
    np.testing.assert_array_equal(a._x, b._x)
    np.testing.assert_array_equal(a._y, b._y)
    assert b.capacity == a.capacity
    for _ in range(3):  # future draws bit-identical
        da, db = a.get_data(6, 2), b.get_data(6, 2)
        for arr_a, arr_b in zip(da, db):
            np.testing.assert_array_equal(arr_a, arr_b)


def test_sample_buffer_state_dict_is_a_snapshot():
    """Mutating the buffer after state_dict() must not alter the captured
    state (the checkpoint writer may serialize it later, off-thread) —
    and an empty buffer round-trips too."""
    a = SampleBuffer(capacity=4, seed=3)
    a.update(np.ones((2, 3), np.float32), np.zeros(2, np.int64))
    state = a.state_dict()
    a.update(np.full((2, 3), 7.0, np.float32), np.ones(2, np.int64))
    assert state["x"].shape[0] == 2  # unchanged by the later update
    b = SampleBuffer(capacity=4, seed=5)
    b.load_state_dict(state)
    assert b._x.shape[0] == 2
    a.reset()
    empty = a.state_dict()
    b.load_state_dict(empty)
    assert len(b) == 0 and b._x is None


def test_resilient_loop_survives_injected_failures(tmp_path):
    """Node failures at steps 7 and 23 -> restore + continue to completion."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)

    def step_fn(state, step):
        return {"params": jax.tree_util.tree_map(
            lambda x: x + 1.0, state["params"]),
            "step": state["step"] + 1}

    injector = FailureInjector(fail_at_steps=(7, 23))
    state = {"params": {"w": jnp.zeros((2,))}, "step": jnp.asarray(0)}
    final, report = resilient_loop(
        step_fn, state, num_steps=30, checkpoint_manager=mgr,
        checkpoint_every=5, failure_injector=injector)
    assert report.final_step == 30
    assert report.restarts == 2
    # State reflects exactly 30 effective steps (no lost/duplicated work).
    assert float(final["params"]["w"][0]) == 30.0


def test_resilient_loop_gives_up_after_max_restarts(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)

    def bad_step(state, step):
        raise RuntimeError("always fails")

    with pytest.raises(RuntimeError):
        resilient_loop(bad_step, _state(), 10, mgr, checkpoint_every=5,
                       max_restarts=2)


def test_straggler_detector():
    sd = StragglerDetector(factor=3.0)
    assert not sd.observe(0, 0.1, 0.1)
    assert sd.observe(1, 1.0, 0.1)
    assert len(sd.events) == 1


def test_heartbeat_median():
    import time

    hb = Heartbeat()
    hb.beat()
    time.sleep(0.01)
    hb.beat()
    assert hb.median() > 0


def test_elastic_restore_new_sharding(tmp_path):
    """Checkpoint saved unsharded restores onto explicit shardings."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path), async_save=False)
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, state)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    shardings = {"w": NamedSharding(mesh, P(None, None))}
    restored, _ = mgr.restore(None, state, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert restored["w"].sharding == shardings["w"]
