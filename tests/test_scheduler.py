"""Algorithm 1 unit tests + hypothesis properties on scheduler invariants."""
import hypothesis
import hypothesis.strategies as st
import numpy as np
import pytest

from repro.core.sample_buffer import SampleBuffer
from repro.core.scheduler import (
    CLHyperParams,
    EOMUScheduler,
    SCHEDULERS,
    SpatialScheduler,
    SpatiotemporalScheduler,
)


def test_hyperparams_paper_relations():
    hp = CLHyperParams(n_t=256, n_l=128)
    assert hp.n_v == 64  # N_v = N_t / 4 (§VI-B)
    assert hp.n_ldd == 4 * hp.n_l  # N_ldd = 4 x N_l (§VI-B)


def test_drift_triggers_reset_and_boost():
    hp = CLHyperParams(v_thr=-0.05)
    sch = SpatiotemporalScheduler(hp)
    # acc_label far below acc_valid -> drift (Alg. 1 line 11).
    plan = sch.next_phase(acc_valid=0.9, acc_label=0.5, t=10.0)
    assert plan.reset_buffer
    assert plan.extra_label_samples == hp.n_ldd - hp.n_l
    # healthy -> no drift.
    plan = sch.next_phase(acc_valid=0.8, acc_label=0.82, t=20.0)
    assert not plan.reset_buffer
    assert plan.extra_label_samples == 0


def test_spatial_never_resets():
    sch = SpatialScheduler(CLHyperParams())
    plan = sch.next_phase(acc_valid=0.99, acc_label=0.01, t=1.0)
    assert not plan.reset_buffer
    assert plan.extra_label_samples == 0


def test_eomu_triggers_on_drop_only():
    sch = EOMUScheduler(CLHyperParams(n_t=100))
    p1 = sch.next_phase(0.8, 0.8, 1.0)
    assert p1.retrain_samples == 100  # first window trains
    p2 = sch.next_phase(0.8, 0.81, 2.0)  # no drop
    assert p2.retrain_samples == 0
    p3 = sch.next_phase(0.8, 0.5, 3.0)  # drop -> retrain
    assert p3.retrain_samples == 100


@hypothesis.settings(max_examples=50, deadline=None)
@hypothesis.given(
    accs=st.lists(st.tuples(st.floats(0, 1), st.floats(0, 1)), min_size=1,
                  max_size=30),
    v_thr=st.floats(-0.5, 0.0),
    name=st.sampled_from(sorted(SCHEDULERS)))
def test_plans_always_valid(accs, v_thr, name):
    """Whatever the accuracy sequence, plans stay within Table I bounds."""
    hp = CLHyperParams(v_thr=v_thr)
    sch = SCHEDULERS[name](hp)
    plan = sch.initial_plan()
    for i, (av, al) in enumerate(accs):
        assert 0 <= plan.retrain_samples <= hp.n_t
        assert plan.valid_samples == hp.n_v
        total_label = plan.label_samples + plan.extra_label_samples
        assert hp.n_l <= total_label <= hp.n_ldd
        plan = sch.next_phase(av, al, float(i))


@hypothesis.settings(max_examples=50, deadline=None)
@hypothesis.given(
    capacity=st.integers(4, 64),
    batches=st.lists(st.integers(1, 40), min_size=1, max_size=12))
def test_buffer_capacity_invariant(capacity, batches):
    buf = SampleBuffer(capacity)
    total = 0
    for i, n in enumerate(batches):
        x = np.full((n, 2), i, np.float32)
        y = np.full((n,), i, np.int32)
        buf.update(x, y)
        total += n
        assert len(buf) == min(total, capacity)  # never exceeds C_b
    # Eviction is FIFO: newest samples survive.
    if total >= capacity:
        assert buf._y[-1] == len(batches) - 1
    buf.reset()
    assert len(buf) == 0


@hypothesis.settings(max_examples=30, deadline=None)
@hypothesis.given(
    n=st.integers(8, 200), n_t=st.integers(1, 300), n_v=st.integers(1, 80))
def test_buffer_draws_disjoint(n, n_t, n_v):
    buf = SampleBuffer(capacity=512)
    x = np.arange(n, dtype=np.float32)[:, None]
    buf.update(x, np.arange(n, dtype=np.int32))
    xt, yt, xv, yv = buf.get_data(n_t, n_v)
    assert len(set(yt.tolist()) & set(yv.tolist())) == 0  # D_t ∩ D_v = ∅
    assert len(xt) >= 1 and len(xv) >= 1
    assert len(xt) + len(xv) <= n


def test_spatial_allocation_meets_fps():
    from repro.configs.dacapo_pairs import RESNET18
    from repro.core.estimator import DaCapoEstimator, spatial_allocation

    est = DaCapoEstimator()
    r_tsa, r_bsa = spatial_allocation(est, RESNET18, fps=30.0,
                                      precision="mx6")
    assert r_tsa + r_bsa == est.total_rows
    assert r_tsa >= 1 and r_bsa >= 1
    # B-SA must actually sustain 30 FPS (unless it took everything).
    if r_tsa > 1:
        assert est.inference_fps(RESNET18, r_bsa, "mx6") >= 30.0
        # Minimality: one fewer row would miss the frame rate.
        if r_bsa > 1:
            assert est.inference_fps(RESNET18, r_bsa - 1, "mx6") < 30.0


def test_mx_precision_cycle_ordering():
    """MX4 < MX6 < MX9 cycles per dot (paper §V-B: 1/4/16)."""
    from repro.core.estimator import MX_CYCLES

    assert MX_CYCLES["mx4"] == 1
    assert MX_CYCLES["mx6"] == 4
    assert MX_CYCLES["mx9"] == 16


def test_partition_mesh_row_split():
    import jax
    from jax.sharding import Mesh
    import numpy as np_

    from repro.core.partition import partition_mesh

    devs = np_.array(jax.devices() * 8).reshape(8, 1)  # fake 8-row mesh
    mesh = Mesh(devs, ("data", "model"))
    part = partition_mesh(mesh, rows_bsa=3)
    assert not part.time_shared
    assert part.t_sa.devices.shape == (5, 1)
    assert part.b_sa.devices.shape == (3, 1)
    # Degenerate cases fall back to time-sharing.
    assert partition_mesh(mesh, rows_bsa=0).time_shared
    assert partition_mesh(mesh, rows_bsa=8).time_shared
