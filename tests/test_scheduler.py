"""Algorithm 1 policy unit tests + property invariants on allocator
decisions (hypothesis when installed, deterministic fallback otherwise)."""
import numpy as np

from _hypothesis_compat import given, settings, st
from repro.core.allocation import (
    ALLOCATORS,
    AllocationDecision,
    CLHyperParams,
    EOMUAllocator,
    PhaseFeedback,
    SpatialAllocator,
    SpatiotemporalAllocator,
)
from repro.core.sample_buffer import SampleBuffer


def _fb(acc_valid, acc_label, t):
    return PhaseFeedback(acc_valid=acc_valid, acc_label=acc_label, t=t)


def test_hyperparams_paper_relations():
    hp = CLHyperParams(n_t=256, n_l=128)
    assert hp.n_v == 64  # N_v = N_t / 4 (§VI-B)
    assert hp.n_ldd == 4 * hp.n_l  # N_ldd = 4 x N_l (§VI-B)


def test_drift_triggers_reset_and_boost():
    hp = CLHyperParams(v_thr=-0.05)
    pol = SpatiotemporalAllocator(hp)
    # acc_label far below acc_valid -> drift (Alg. 1 line 11).
    d = pol.next_decision(_fb(acc_valid=0.9, acc_label=0.5, t=10.0))
    assert d.reset_buffer
    assert d.extra_label_samples == hp.n_ldd - hp.n_l
    # healthy -> no drift.
    d = pol.next_decision(_fb(acc_valid=0.8, acc_label=0.82, t=20.0))
    assert not d.reset_buffer
    assert d.extra_label_samples == 0


def test_spatial_never_resets():
    pol = SpatialAllocator(CLHyperParams())
    d = pol.next_decision(_fb(acc_valid=0.99, acc_label=0.01, t=1.0))
    assert not d.reset_buffer
    assert d.extra_label_samples == 0


def test_eomu_triggers_on_drop_only():
    pol = EOMUAllocator(CLHyperParams(n_t=100))
    d1 = pol.next_decision(_fb(0.8, 0.8, 1.0))
    assert d1.retrain_samples == 100  # first window trains
    d2 = pol.next_decision(_fb(0.8, 0.81, 2.0))  # no drop
    assert d2.retrain_samples == 0
    d3 = pol.next_decision(_fb(0.8, 0.5, 3.0))  # drop -> retrain
    assert d3.retrain_samples == 100


def test_window_pacing_is_declared_on_decisions():
    """Window pacing is decision data, not an engine branch."""
    hp = CLHyperParams()
    windows = {"dacapo-spatiotemporal": None,
               "dacapo-spatiotemporal-online": None,
               "dacapo-spatial": None,
               "dacapo-replay": None,
               "ekya": 120.0, "eomu": 10.0}
    for name, cls in ALLOCATORS.items():
        pol = cls(hp)
        assert pol.initial_decision().pace_window_s == windows[name], name


def test_legacy_scheduler_shim():
    """Old imports and the legacy next_phase API keep working — but warn:
    both the shim module and the plan-era aliases are deprecated, so no
    internal caller may touch them (tier-1 stays green under
    -W error::DeprecationWarning)."""
    import importlib
    import sys

    import pytest

    sys.modules.pop("repro.core.scheduler", None)
    with pytest.warns(DeprecationWarning, match="repro.core.scheduler"):
        scheduler = importlib.import_module("repro.core.scheduler")

    assert scheduler.SCHEDULERS is ALLOCATORS
    assert scheduler.PhasePlan is AllocationDecision
    # Positional PhasePlan construction (legacy field order).
    plan = scheduler.PhasePlan(10, 4, 8, True, 2)
    assert plan.retrain_samples == 10 and plan.reset_buffer
    sch = scheduler.SpatiotemporalScheduler(CLHyperParams(v_thr=-0.05))
    with pytest.warns(DeprecationWarning, match="next_phase"):
        plan = sch.next_phase(acc_valid=0.9, acc_label=0.5, t=1.0)
    assert plan.reset_buffer
    with pytest.warns(DeprecationWarning, match="initial_plan"):
        plan = sch.initial_plan()
    assert plan.retrain_samples == sch.hp.n_t


@settings(max_examples=50, deadline=None)
@given(
    accs=st.lists(st.tuples(st.floats(0, 1), st.floats(0, 1)), min_size=1,
                  max_size=30),
    v_thr=st.floats(-0.5, 0.0),
    name=st.sampled_from(sorted(ALLOCATORS)))
def test_decisions_always_valid(accs, v_thr, name):
    """Whatever the accuracy sequence, decisions stay within Table I
    bounds."""
    hp = CLHyperParams(v_thr=v_thr)
    pol = ALLOCATORS[name](hp)
    d = pol.initial_decision()
    for i, (av, al) in enumerate(accs):
        assert 0 <= d.retrain_samples <= hp.n_t
        assert d.valid_samples == hp.n_v
        assert hp.n_l <= d.total_label_samples <= hp.n_ldd
        d = pol.next_decision(_fb(av, al, float(i)))


@settings(max_examples=50, deadline=None)
@given(
    capacity=st.integers(4, 64),
    batches=st.lists(st.integers(1, 40), min_size=1, max_size=12))
def test_buffer_capacity_invariant(capacity, batches):
    buf = SampleBuffer(capacity)
    total = 0
    for i, n in enumerate(batches):
        x = np.full((n, 2), i, np.float32)
        y = np.full((n,), i, np.int32)
        buf.update(x, y)
        total += n
        assert len(buf) == min(total, capacity)  # never exceeds C_b
    # Eviction is FIFO: newest samples survive.
    if total >= capacity:
        assert buf._y[-1] == len(batches) - 1
    buf.reset()
    assert len(buf) == 0


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(8, 200), n_t=st.integers(1, 300), n_v=st.integers(1, 80))
def test_buffer_draws_disjoint(n, n_t, n_v):
    buf = SampleBuffer(capacity=512)
    x = np.arange(n, dtype=np.float32)[:, None]
    buf.update(x, np.arange(n, dtype=np.int32))
    xt, yt, xv, yv = buf.get_data(n_t, n_v)
    assert len(set(yt.tolist()) & set(yv.tolist())) == 0  # D_t ∩ D_v = ∅
    assert len(xt) >= 1 and len(xv) >= 1
    assert len(xt) + len(xv) <= n


def test_spatial_allocation_meets_fps():
    from repro.configs.dacapo_pairs import RESNET18
    from repro.core.estimator import DaCapoEstimator, spatial_allocation

    est = DaCapoEstimator()
    r_tsa, r_bsa = spatial_allocation(est, RESNET18, fps=30.0,
                                      precision="mx6")
    assert r_tsa + r_bsa == est.total_rows
    assert r_tsa >= 1 and r_bsa >= 1
    # B-SA must actually sustain 30 FPS (unless it took everything).
    if r_tsa > 1:
        assert est.inference_fps(RESNET18, r_bsa, "mx6") >= 30.0
        # Minimality: one fewer row would miss the frame rate.
        if r_bsa > 1:
            assert est.inference_fps(RESNET18, r_bsa - 1, "mx6") < 30.0


def test_spatial_allocation_degenerate_cases():
    """Regression: the fallback must never allocate more rows than exist."""
    import dataclasses

    from repro.configs.dacapo_pairs import RESNET18
    from repro.core.estimator import spatial_allocation

    @dataclasses.dataclass(frozen=True)
    class FakeEstimator:
        total_rows: int
        fps_per_row: float

        def inference_fps(self, cfg, rows, precision):
            return rows * self.fps_per_row

    # Single-row array: seed code returned (1, 1) — two rows from one.
    r_tsa, r_bsa = spatial_allocation(FakeEstimator(1, 100.0), RESNET18,
                                      fps=30.0, precision="mx6")
    assert (r_tsa, r_bsa) == (0, 1)
    # rows == total sustains fps but no proper split does: whole array to
    # B-SA instead of the old under-provisioned (1, total-1) fallback.
    r_tsa, r_bsa = spatial_allocation(FakeEstimator(2, 20.0), RESNET18,
                                      fps=30.0, precision="mx6")
    assert (r_tsa, r_bsa) == (0, 2)
    # Overloaded even at full width: keep one training row.
    r_tsa, r_bsa = spatial_allocation(FakeEstimator(4, 1.0), RESNET18,
                                      fps=30.0, precision="mx6")
    assert (r_tsa, r_bsa) == (1, 3)
    # Invariant across regimes: rows always sum to the array size.
    for total in (1, 2, 3, 8):
        for fps_per_row in (0.1, 10.0, 100.0):
            r_tsa, r_bsa = spatial_allocation(
                FakeEstimator(total, fps_per_row), RESNET18, fps=30.0,
                precision="mx6")
            assert r_tsa + r_bsa == total, (total, fps_per_row)
            assert r_bsa >= 1


def test_mx_precision_cycle_ordering():
    """MX4 < MX6 < MX9 cycles per dot (paper §V-B: 1/4/16)."""
    from repro.core.estimator import MX_CYCLES

    assert MX_CYCLES["mx4"] == 1
    assert MX_CYCLES["mx6"] == 4
    assert MX_CYCLES["mx9"] == 16


def test_partition_mesh_row_split():
    import jax
    from jax.sharding import Mesh
    import numpy as np_

    from repro.core.partition import partition_mesh

    devs = np_.array(jax.devices() * 8).reshape(8, 1)  # fake 8-row mesh
    mesh = Mesh(devs, ("data", "model"))
    part = partition_mesh(mesh, rows_bsa=3)
    assert not part.time_shared
    assert part.t_sa.devices.shape == (5, 1)
    assert part.b_sa.devices.shape == (3, 1)
    # Degenerate cases fall back to time-sharing.
    assert partition_mesh(mesh, rows_bsa=0).time_shared
    assert partition_mesh(mesh, rows_bsa=8).time_shared
