"""Fleet-session tests: the 1-stream degeneracy golden (a 1-lane
FleetSession is bit-identical to CLSession — records, accuracy timeline,
speculation counters — and hits the seed goldens of tests/test_session.py),
a heterogeneous 3-stream run with T-SA ledger conservation and per-stream
PhaseRecord lanes, the FleetAllocator split modes, cross-stream batched
labeling, Ekya's non-idealized profiling cost, and decision-aware
speculation hints."""
import jax
import numpy as np
import pytest

from repro.configs.dacapo_pairs import RESNET18, WIDERESNET50
from repro.core.allocation import (
    FLEET_MODES,
    CLHyperParams,
    EkyaAllocator,
    FleetAllocator,
    PhaseFeedback,
)
from repro.core.decision import DriftSurgeRowPolicy, FleetRowPolicy
from repro.core.estimator import DaCapoEstimator
from repro.core.fleet import FleetSession, FleetSpec
from repro.core.kernel import LabelingKernel
from repro.core.session import CLSystemSpec, pretrain_model
from repro.data.pipeline import FramePipeline
from repro.data.stream import DriftStream, scenario
from repro.models.registry import make_vision_model

# The seed-capture goldens of tests/test_session.py (same fixture: S1 x3
# segments seed=5 img=24, hp(48, 24, c_b=192), pretrain rng(0) 25/15 steps,
# duration 90 s, apply_mx False, eval_fps 0.5). A 1-stream fleet must hit
# them bit-for-bit.
GOLDEN_ST = dict(avg_accuracy=0.32608695652173914, phases=23, drifts=9,
                 retrain_time=54.54179220000003,
                 label_time=36.060292799999985)

_RECORD_FIELDS = ("index", "t", "acc_valid", "acc_label", "drift",
                  "retrain_time", "label_time", "phase_start", "t_tsa",
                  "t_bsa", "spec_hits", "spec_misses", "stream")


def _assert_records_identical(recs_a, recs_b):
    assert len(recs_a) == len(recs_b)
    for a, b in zip(recs_a, recs_b):
        for field in _RECORD_FIELDS:
            assert getattr(a, field) == getattr(b, field), field
        assert a.decision == b.decision
        assert a.next_decision == b.next_decision


@pytest.fixture(scope="module")
def golden_setup():
    stream = DriftStream(scenario("S1", 3), seed=5, img=24)
    hp = CLHyperParams(n_t=48, n_l=24, c_b=192, epochs=1)
    rng = np.random.default_rng(0)
    tp = pretrain_model(make_vision_model(WIDERESNET50.reduced()), stream,
                        25, 32, rng)
    sp = pretrain_model(make_vision_model(RESNET18.reduced()), stream, 15,
                        32, rng, segments=stream.segments[:1], seed=8)
    return stream, hp, tp, sp


@pytest.fixture(scope="module")
def small_setup():
    stream = DriftStream(scenario("S1", 2), seed=5, img=24)
    hp = CLHyperParams(n_t=32, n_l=16, c_b=128, epochs=1)
    rng = np.random.default_rng(0)
    tp = pretrain_model(make_vision_model(WIDERESNET50.reduced()), stream,
                        10, 32, rng)
    sp = pretrain_model(make_vision_model(RESNET18.reduced()), stream, 8,
                        32, rng, segments=stream.segments[:1], seed=8)
    return stream, hp, tp, sp


def _fleet(hp, mode="drift-weighted", **kw) -> FleetSession:
    kw.setdefault("allocator", "dacapo-spatiotemporal")
    return FleetSpec(student=RESNET18, teacher=WIDERESNET50, hp=hp,
                     fleet_mode=mode, apply_mx=False, seed=0, eval_fps=0.5,
                     **kw).build()


def _session(hp, **kw):
    kw.setdefault("allocator", "dacapo-spatiotemporal")
    return CLSystemSpec(student=RESNET18, teacher=WIDERESNET50, hp=hp,
                        apply_mx=False, seed=0, eval_fps=0.5, **kw).build()


# ------------------------------------------------------ degeneracy golden --
def test_one_stream_fleet_hits_seed_goldens(golden_setup):
    """Acceptance: a 1-stream fleet reproduces the seed-capture goldens of
    tests/test_session.py bit-for-bit, AND is record-for-record identical
    to a live CLSession on the same fixture."""
    stream, hp, tp, sp = golden_setup
    session = _session(hp)
    session.set_pretrained(tp, sp)
    res = session.run(stream, duration=90.0)

    fleet = _fleet(hp)
    fleet.set_pretrained(tp, sp)
    fres = fleet.run([stream], duration=90.0)
    assert fres.n_streams == 1
    lane = fres.streams[0]

    # The seed goldens (same constants test_session pins).
    assert abs(lane.avg_accuracy - GOLDEN_ST["avg_accuracy"]) < 1e-6
    assert len(lane.phase_log) == GOLDEN_ST["phases"]
    assert lane.drift_events == GOLDEN_ST["drifts"]
    assert abs(lane.retrain_time - GOLDEN_ST["retrain_time"]) < 1e-6
    assert abs(lane.label_time - GOLDEN_ST["label_time"]) < 1e-6

    # Bit-identity against the live session: timeline and every record.
    assert lane.accuracy_timeline == res.accuracy_timeline
    assert lane.retrain_time == res.retrain_time
    assert lane.label_time == res.label_time
    _assert_records_identical(lane.records, res.records)
    assert fres.fleet_avg_accuracy == lane.avg_accuracy


@pytest.mark.parametrize("mode", FLEET_MODES)
def test_one_stream_fleet_degenerate_in_every_mode(small_setup, mode):
    """Every split mode is the identity at N=1 (weights collapse to 1)."""
    stream, hp, tp, sp = small_setup
    session = _session(hp)
    session.set_pretrained(tp, sp)
    res = session.run(stream, duration=20.0)

    fleet = _fleet(hp, mode=mode)
    fleet.set_pretrained(tp, sp)
    fres = fleet.run([stream], duration=20.0)
    assert fres.streams[0].accuracy_timeline == res.accuracy_timeline
    _assert_records_identical(fres.streams[0].records, res.records)


def test_one_stream_fleet_concurrent_with_speculation(small_setup):
    """Concurrent dispatch: the 1-lane fleet matches CLSession including
    the per-phase speculation hit/miss counters."""
    stream, hp, tp, sp = small_setup
    session = _session(hp, dispatch="concurrent")
    session.set_pretrained(tp, sp)
    res = session.run(stream, duration=20.0)

    fleet = _fleet(hp, dispatch="concurrent")
    assert fleet.speculative_frames
    fleet.set_pretrained(tp, sp)
    fres = fleet.run([stream], duration=20.0)
    lane = fres.streams[0]
    assert sum(r.spec_hits for r in lane.records) > 0
    assert lane.accuracy_timeline == res.accuracy_timeline
    _assert_records_identical(lane.records, res.records)


# --------------------------------------------------- heterogeneous fleet --
def test_three_stream_fleet_ledger_conservation(small_setup):
    """A heterogeneous 3-stream fleet (different scenarios/seeds): the
    shared T-SA ledger is conserved — each fleet phase's charge equals the
    sum of the per-stream charges — and the records arrive in per-stream
    lanes."""
    _, hp, tp, sp = small_setup
    streams = [DriftStream(scenario("S1", 2), seed=5, img=24),
               DriftStream(scenario("S3", 2), seed=6, img=24),
               DriftStream(scenario("ES1", 2), seed=7, img=24)]
    fleet = _fleet(hp, mode="drift-weighted")
    fleet.set_pretrained(tp, sp)
    seen = []
    fres = fleet.run(streams, duration=40.0, observers=(seen.append,))

    assert fres.n_streams == 3
    assert fres.fleet_phase_log, "fleet executed no phases"
    for entry in fres.fleet_phase_log:
        # Sum of per-stream charges == fleet charge, both roles.
        assert sum(entry["per_stream_t_tsa"]) == pytest.approx(
            entry["t_tsa"], rel=1e-9, abs=1e-12)
        assert sum(entry["per_stream_t_bsa"]) == pytest.approx(
            entry["t_bsa"], rel=1e-9, abs=1e-12)
        assert len(entry["per_stream_t_tsa"]) == 3
    # Per-stream PhaseRecord lanes: contiguous indices, correct lane ids,
    # one record per fleet phase per stream.
    n_phases = len(fres.fleet_phase_log)
    for i, lane in enumerate(fres.streams):
        assert len(lane.records) == n_phases
        for j, rec in enumerate(lane.records):
            assert rec.stream == i and rec.index == j
        assert lane.avg_accuracy > 0.0
        ts = [t for t, _ in lane.accuracy_timeline]
        assert ts == sorted(ts)
    # Observers saw every lane's records.
    assert {rec.stream for rec in seen} == {0, 1, 2}
    assert len(seen) == 3 * n_phases
    # Per-lane record t_tsa matches the fleet ledger attribution.
    for i, lane in enumerate(fres.streams):
        for rec, entry in zip(lane.records, fres.fleet_phase_log):
            assert rec.t_tsa == entry["per_stream_t_tsa"][i]


def test_fleet_serve_batched_matches_per_lane(small_setup):
    """``serve_batched`` (one vmapped B-SA program flushing every lane's
    queued score windows per phase) preserves the run: ledgers exactly,
    accuracy to float tolerance — with fewer jitted apply dispatches."""
    _, hp, tp, sp = small_setup
    streams = [DriftStream(scenario("S1", 2), seed=5, img=24),
               DriftStream(scenario("S3", 2), seed=6, img=24)]

    def run(batched):
        fleet = _fleet(hp, dispatch="concurrent", serve_batched=batched)
        fleet.set_pretrained(tp, sp)
        res = fleet.run(streams, duration=40.0)
        return fleet, res

    f0, r0 = run(False)
    f1, r1 = run(True)
    for a, b in zip(r0.streams, r1.streams):
        assert b.avg_accuracy == pytest.approx(a.avg_accuracy, abs=1e-6)
        assert b.retrain_time == a.retrain_time  # ledgers exact
        assert b.label_time == a.label_time
        assert [t for t, _ in b.accuracy_timeline] \
            == [t for t, _ in a.accuracy_timeline]
    # The whole point: multi-lane flushes fuse into single programs.
    assert f1.inference.n_apply_calls < f0.inference.n_apply_calls


def test_fleet_budget_scales_phase_cost(small_setup):
    """The point of the fleet layer: a uniform 3-stream split spends about
    one session's T-SA budget per phase, while the isolated baseline spends
    ~3x — so at equal virtual duration the split fleet executes more
    phases (more frequent per-stream updates)."""
    _, hp, tp, sp = small_setup
    streams = [DriftStream(scenario("S1", 2), seed=5, img=24),
               DriftStream(scenario("S3", 2), seed=6, img=24),
               DriftStream(scenario("S5", 2), seed=7, img=24)]
    phases = {}
    for mode in ("uniform", "isolated"):
        fleet = _fleet(hp, mode=mode)
        fleet.set_pretrained(tp, sp)
        fres = fleet.run(streams, duration=40.0)
        phases[mode] = len(fres.fleet_phase_log)
    assert phases["uniform"] > phases["isolated"]


# ---------------------------------------------------- fleet row policies --
# PR 4 capture: the 3-stream heterogeneous fleet (S1/S3/ES1, seeds 5/6/7,
# small_setup hp, drift-weighted, 40 s) run on the pre-plane engine — the
# hard-coded max/min `_fleet_rows` era. FleetRowPolicy("resolve-max") must
# reproduce every number bit-for-bit in both dispatch modes.
GOLDEN_FLEET_3S = {
    "sequential": dict(
        fleet_avg_accuracy=0.12683780399428937, phases=14,
        per_stream_acc=[0.19025670599143407, 0.12230788242306476,
                        0.0679488235683693],
        retrain=[9.768679200000001, 8.9546226, 8.9546226],
        label=[5.0484409919999935, 3.1252253759999924, 3.1252253759999924],
        last_t=40.22439956399999, drifts=[1, 0, 0]),
    "concurrent": dict(
        fleet_avg_accuracy=0.14722245106480017, phases=14,
        per_stream_acc=[0.12619067234125728, 0.13880973957538303,
                        0.1766669412777602],
        retrain=[9.768679200000001, 8.9546226, 8.9546226],
        label=[5.048440991999991, 4.5676370879999935, 2.644421471999993],
        last_t=40.672010568, drifts=[1, 1, 0]),
}


def _golden_streams():
    return [DriftStream(scenario("S1", 2), seed=5, img=24),
            DriftStream(scenario("S3", 2), seed=6, img=24),
            DriftStream(scenario("ES1", 2), seed=7, img=24)]


@pytest.mark.parametrize("dispatch", ["sequential", "concurrent"])
def test_resolve_max_row_policy_pins_pr4_fleet_goldens(small_setup,
                                                       dispatch):
    """Acceptance: the pluggable resolve-max policy is bit-identical to
    PR 4's hard-coded fleet row resolution, in both dispatch modes."""
    _, hp, tp, sp = small_setup
    fleet = _fleet(hp, mode="drift-weighted", dispatch=dispatch,
                   row_policy=FleetRowPolicy("resolve-max"))
    fleet.set_pretrained(tp, sp)
    fres = fleet.run(_golden_streams(), duration=40.0)
    gold = GOLDEN_FLEET_3S[dispatch]
    assert fres.fleet_avg_accuracy == gold["fleet_avg_accuracy"]
    assert len(fres.fleet_phase_log) == gold["phases"]
    assert fres.fleet_phase_log[-1]["t"] == gold["last_t"]
    for lane, acc, ret, lab, drifts in zip(
            fres.streams, gold["per_stream_acc"], gold["retrain"],
            gold["label"], gold["drifts"]):
        assert lane.avg_accuracy == acc
        assert lane.retrain_time == ret
        assert lane.label_time == lab
        assert lane.drift_events == drifts
    # The phase log now also tracks the executed fleet spatial plane, and
    # resolve-max keeps it pinned to the offline split throughout.
    for entry in fres.fleet_phase_log:
        assert (entry["rows_tsa"], entry["rows_bsa"]) \
            == (fleet.r_tsa, fleet.r_bsa)


def test_drift_surge_fleet_moves_rows_and_returns(small_setup):
    """FleetRowPolicy('drift-surge') in a live fleet: the fleet spatial
    plane grows the T-SA when the drift quorum fires, rows-over-time is
    auditable in the fleet phase log, and the surge releases after the
    hysteresis window."""
    _, hp, tp, sp = small_setup
    fleet = _fleet(hp, mode="drift-weighted", dispatch="concurrent",
                   row_policy=DriftSurgeRowPolicy(
                       surge_rows=1, quorum=0.3, hysteresis_phases=1))
    fleet.set_pretrained(tp, sp)
    fres = fleet.run(_golden_streams(), duration=40.0)
    base = fleet.r_tsa
    rows = [e["rows_tsa"] for e in fres.fleet_phase_log]
    assert rows[0] == base  # offline split first
    assert base + 1 in rows  # the surge fired (some lane drifted)
    assert rows[-1] == base  # ...and released after the hysteresis window
    for e in fres.fleet_phase_log:  # the array stays whole
        assert e["rows_tsa"] + e["rows_bsa"] == fleet.estimator.total_rows
    # The surged phases bought a bigger T-SA (ledger runs at more rows).
    assert fres.drift_events > 0


def test_weighted_vote_fleet_invariants(small_setup):
    """FleetRowPolicy('weighted-vote') in a live fleet: rows stay a valid
    split of the whole array (healthy phases run serving-heavy — below
    the offline T-SA split — and both sides always keep a row)."""
    _, hp, tp, sp = small_setup
    fleet = _fleet(hp, mode="drift-weighted", dispatch="concurrent",
                   row_policy="weighted-vote")
    fleet.set_pretrained(tp, sp)
    fres = fleet.run(_golden_streams(), duration=20.0)
    assert "weighted-vote" in fres.name
    for e in fres.fleet_phase_log:
        assert e["rows_tsa"] + e["rows_bsa"] == fleet.estimator.total_rows
        assert e["rows_tsa"] >= 1 and e["rows_bsa"] >= 1


# ------------------------------------------------------- allocator modes --
def _bound_fleet_allocator(mode, **kw) -> FleetAllocator:
    hp = CLHyperParams(n_t=64, n_l=32)
    alloc = FleetAllocator(hp, policy="dacapo-spatiotemporal", mode=mode,
                           **kw)
    return alloc.bind(DaCapoEstimator(), RESNET18)


_HEALTHY = PhaseFeedback(acc_valid=0.8, acc_label=0.82, t=1.0)


def test_fleet_allocator_uniform_split():
    alloc = _bound_fleet_allocator("uniform")
    decisions = alloc.initial_decisions(4)
    assert len(decisions) == 4 == len(alloc.policies)
    for d in decisions:
        assert d.retrain_samples == round(alloc.hp.n_t / 4)
        assert d.rows_tsa is not None  # spatial split still carried
    decisions = alloc.next_decisions([_HEALTHY] * 4)
    total_label = sum(d.label_samples for d in decisions)
    assert total_label <= alloc.hp.n_l + 4  # ~one session's labeling budget


def test_fleet_allocator_round_robin_rotates_focus():
    alloc = _bound_fleet_allocator("round-robin")
    focus_order = []
    alloc.initial_decisions(3)
    for _ in range(3):
        decisions = alloc.next_decisions([_HEALTHY] * 3)
        focus = [i for i, d in enumerate(decisions)
                 if d.retrain_samples == alloc.hp.n_t]
        assert len(focus) == 1
        focus_order.append(focus[0])
        for i, d in enumerate(decisions):
            if i != focus[0]:
                # Heartbeat: non-focus lanes keep one SGD batch + full N_v
                # so their drift detectors stay live.
                assert d.retrain_samples == alloc.hp.sgd_batch
                assert d.valid_samples == alloc.hp.n_v
                assert d.label_samples >= 1  # drift stays detectable
    assert len(set(focus_order)) == 3  # every stream got a turn


def test_fleet_allocator_drift_weighted_follows_drift():
    alloc = _bound_fleet_allocator("drift-weighted", drift_bias=4.0)
    alloc.initial_decisions(3)
    alloc.next_decisions([_HEALTHY] * 3)  # settle EMAs
    # Stream 1 falls off a cliff (fresh-label acc collapses -> drift).
    cliff = PhaseFeedback(acc_valid=0.9, acc_label=0.2, t=2.0)
    decisions = alloc.next_decisions([_HEALTHY, cliff, _HEALTHY])
    assert decisions[1].reset_buffer  # lane policy fired drift
    assert decisions[1].retrain_samples > decisions[0].retrain_samples
    assert (decisions[1].total_label_samples
            > decisions[0].total_label_samples)


def test_fleet_allocator_isolated_keeps_full_budgets():
    alloc = _bound_fleet_allocator("isolated")
    decisions = alloc.initial_decisions(3)
    for d in decisions:
        assert d.retrain_samples == alloc.hp.n_t
        assert d.label_samples == alloc.hp.n_l


def test_fleet_allocator_one_stream_identity_and_guards():
    alloc = _bound_fleet_allocator("drift-weighted")
    decisions = alloc.initial_decisions(1)
    base = alloc.policies[0]
    # Weight 1 returns the lane decision object untouched.
    assert decisions[0] == base.initial_decision()
    with pytest.raises(ValueError):
        FleetAllocator(CLHyperParams(), mode="nope")
    with pytest.raises(ValueError):
        inst = _bound_fleet_allocator("uniform")
        FleetAllocator(CLHyperParams(), policy=inst)
    shared = EkyaAllocator(CLHyperParams())
    alloc2 = FleetAllocator(CLHyperParams(), policy=shared)
    with pytest.raises(ValueError):
        alloc2.lanes(2)  # shared instance across lanes is refused
    # The single-stream AllocationPolicy surface raises early with
    # guidance (a FleetAllocator inside a plain CLSession would otherwise
    # fail with a bare NotImplementedError after the first phase).
    with pytest.raises(TypeError):
        alloc.initial_decision()
    with pytest.raises(TypeError):
        alloc.next_decision(_HEALTHY)


def test_fleet_allocator_zero_eps_all_healthy_falls_back_uniform():
    alloc = _bound_fleet_allocator("drift-weighted", gap_eps=0.0)
    alloc.initial_decisions(2)
    decisions = alloc.next_decisions([_HEALTHY] * 2)  # raw weights all 0
    assert [d.retrain_samples for d in decisions] == [32, 32]  # 1/2 each


def test_fleet_allocator_scale_epochs():
    alloc = _bound_fleet_allocator("round-robin", scale_epochs=True)
    alloc.initial_decisions(3)
    decisions = alloc.next_decisions([_HEALTHY] * 3)
    focus = [d for d in decisions
             if d.retrain_samples == alloc.hp.n_t][0]
    # Focus lane holds 3x the uniform share -> 3x the retraining depth;
    # heartbeat lanes stay at 1 epoch.
    assert focus.retrain_epochs == 3
    for d in decisions:
        if d is not focus:
            assert d.retrain_epochs == 1


# ------------------------------------------- cross-stream batched labeling --
def test_label_fleet_async_batches_microbatches_across_streams():
    model = make_vision_model(WIDERESNET50.reduced())
    params = model.init(jax.random.PRNGKey(0))
    kernel = LabelingKernel(model, WIDERESNET50, DaCapoEstimator(),
                            apply_mx=False)
    rng = np.random.default_rng(0)
    bursts = [np.asarray(rng.normal(size=(n, 24, 24, 3)), np.float32)
              for n in (24, 24, 24)]
    # Per-stream calls: 3 bursts of 24 <= mb=64 -> one jitted call each.
    kernel.n_apply_calls = 0
    separate = [kernel.label(params, b, "mx6", microbatch=64)
                for b in bursts]
    calls_separate = kernel.n_apply_calls
    # Fleet call: 72 samples -> ceil(72/64) = 2 microbatches total.
    kernel.n_apply_calls = 0
    fused = [np.asarray(y) for y in
             kernel.label_fleet_async(params, bursts, "mx6", microbatch=64)]
    calls_fused = kernel.n_apply_calls
    assert calls_fused < calls_separate
    for a, b in zip(separate, fused):
        np.testing.assert_array_equal(a, b)
    # Single-burst fleets take the exact label_async path.
    kernel.n_apply_calls = 0
    solo = kernel.label_fleet_async(params, bursts[:1], "mx6",
                                    microbatch=64)
    assert len(solo) == 1 and kernel.n_apply_calls == 1
    assert kernel.label_fleet_async(params, [], "mx6") == []


# ----------------------------------------------------- ekya profiling cost --
def test_ekya_profile_cost_charged_to_tsa_ledger(small_setup):
    """profile_cost=0 (default) is the idealized seed behaviour; a positive
    cost rides on every decision and lands in the phase's T-SA ledger."""
    stream, hp, tp, sp = small_setup
    ideal = EkyaAllocator(hp)
    assert ideal.initial_decision().profile_cost_s == 0.0
    profiled = EkyaAllocator(hp, profile_cost=5.0)
    assert profiled.initial_decision().profile_cost_s == 5.0
    assert profiled.next_decision(_HEALTHY).profile_cost_s == 5.0

    recs = {}
    for name, alloc in (("ideal", EkyaAllocator(hp)),
                        ("profiled", EkyaAllocator(hp, profile_cost=5.0))):
        session = _session(hp, allocator=alloc)
        session.set_pretrained(tp, sp)
        recs[name] = session.run(stream, duration=30.0).records
    assert recs["ideal"] and recs["profiled"]
    # Same phase structure (the 120 s window pacing absorbs the cost), but
    # the T-SA ledger carries the extra 5 s of microprofiling per window.
    assert len(recs["ideal"]) == len(recs["profiled"])
    assert recs["profiled"][0].t_tsa == pytest.approx(
        recs["ideal"][0].t_tsa + 5.0)


# ------------------------------------------------- decision-aware hints --
def test_label_hint_presizes_speculated_burst():
    """The decision-aware predictor: a label-tagged window is re-sized to
    the hinted budget on rotation, so a drift-phase burst 4x the replayed
    layout still reconciles as a hit — and stays bit-identical to inline
    synthesis."""
    stream = DriftStream(scenario("S1", 2), seed=7, img=16)
    inline = DriftStream(scenario("S1", 2), seed=7, img=16)
    fps = stream.fps
    pipe = FramePipeline(stream, speculative=True)
    try:
        pipe.begin_phase(0.0)
        pipe.frames(0.0, 0.0 + 16 / fps, max_frames=16, tag="label")
        # Without a hint this request would miss (cf. the misprediction
        # test in test_pipeline); the hint pre-sizes it.
        pipe.begin_phase(3.0, label_hint=(64, fps))
        assert pipe.stats.windows_hinted == 1
        h0, m0 = pipe.hits, pipe.misses
        x, y = pipe.frames(3.0, 3.0 + 64 / fps, max_frames=64, tag="label")
        xi, yi = inline.frames(3.0, 3.0 + 64 / fps, max_frames=64)
        np.testing.assert_array_equal(x, xi)
        np.testing.assert_array_equal(y, yi)
        assert (pipe.hits, pipe.misses) == (h0 + 1, m0)
        # A hint matching the recorded size rewrites nothing.
        pipe.begin_phase(6.0, label_hint=(64, fps))
        assert pipe.stats.windows_hinted == 1
    finally:
        pipe.close()


def test_session_decision_aware_spec_knob(small_setup):
    """The knob only changes speculation efficiency, never results: with
    hints disabled the timeline is identical, and drift phases (budget
    changes) cost at least as many misses."""
    stream, hp, tp, sp = small_setup
    runs = {}
    for aware in (True, False):
        session = _session(hp, dispatch="concurrent",
                           decision_aware_spec=aware)
        session.set_pretrained(tp, sp)
        runs[aware] = session.run(stream, duration=20.0)
    assert (runs[True].accuracy_timeline
            == runs[False].accuracy_timeline)
    hits = {k: sum(r.spec_hits for r in v.records) for k, v in runs.items()}
    misses = {k: sum(r.spec_misses for r in v.records)
              for k, v in runs.items()}
    assert hits[True] >= hits[False]
    assert misses[True] <= misses[False]
