"""MX format: kernel-vs-oracle equivalence sweeps + property invariants
(hypothesis when installed, deterministic fallback otherwise)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.kernels import ops, ref
from repro.kernels.mx_matmul import mx_matmul as mx_matmul_kernel
from repro.kernels.mx_quantize import mx_quantize as mx_quantize_kernel
from repro.kernels.ref import BLOCK, MANTISSA_BITS, MXTensor

PRECISIONS = ("mx4", "mx6", "mx9")


@pytest.mark.parametrize("precision", PRECISIONS)
@pytest.mark.parametrize("shape", [(8, 16), (32, 64), (128, 512), (16, 48)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_kernel_matches_ref(precision, shape, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(0), shape) * 5.0).astype(dtype)
    qk = mx_quantize_kernel(x.astype(jnp.float32), precision, interpret=True)
    qr = ref.mx_quantize_ref(x.astype(jnp.float32), precision)
    np.testing.assert_array_equal(qk.mantissa, qr.mantissa)
    np.testing.assert_array_equal(qk.exponent, qr.exponent)
    np.testing.assert_array_equal(qk.mx_bits, qr.mx_bits)


@pytest.mark.parametrize("precision,max_rel", [("mx4", 0.35), ("mx6", 0.09),
                                               ("mx9", 0.012)])
def test_quantization_error_bounds(precision, max_rel):
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 256)) * 3.0
    y = ref.mx_quant_dequant_ref(x, precision)
    rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
    assert rel < max_rel, (precision, rel)


@pytest.mark.parametrize("mnk", [(8, 128, 128), (128, 256, 128),
                                 (64, 512, 384)])
@pytest.mark.parametrize("pa,pb", [("mx9", "mx9"), ("mx6", "mx6"),
                                   ("mx9", "mx6")])
def test_matmul_kernel_matches_ref(mnk, pa, pb):
    m, k, n = mnk
    a = jax.random.normal(jax.random.PRNGKey(2), (m, k))
    b = jax.random.normal(jax.random.PRNGKey(3), (k, n))
    qa = ref.mx_quantize_ref(a, pa)
    qbt = ref.mx_quantize_ref(b.T, pb)
    qb = MXTensor(qbt.mantissa.T, qbt.exponent.T, qbt.mx_bits.T, pb)
    out_k = mx_matmul_kernel(qa, qb, interpret=True, bk=128)
    out_r = ref.mx_matmul_ref(qa, qbt)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-4)


def test_mx9_matmul_accuracy_vs_fp32():
    a = jax.random.normal(jax.random.PRNGKey(4), (64, 256))
    b = jax.random.normal(jax.random.PRNGKey(5), (256, 64))
    out = ref.mx_matmul_fp_ref(a, b, "mx9", "mx9")
    rel = float(jnp.linalg.norm(out - a @ b) / jnp.linalg.norm(a @ b))
    assert rel < 0.02


# ------------------------------------------------------------- properties --
@settings(max_examples=30, deadline=None)
@given(
    data=st.lists(
        st.floats(min_value=-1e4, max_value=1e4, allow_nan=False,
                  width=32),
        min_size=BLOCK, max_size=BLOCK),
    precision=st.sampled_from(PRECISIONS))
def test_dequant_error_bounded_per_block(data, precision):
    """|x - dq(q(x))| <= 2^E * 2^-(mb-1) per element: one ULP of the block
    scale. Rounding alone is half an ULP, but the sign-magnitude mantissa
    saturates at 2^mb - 1, so a block max just under 2^(E+1) clips to
    (2 - 2^-(mb-1)) * 2^E — exactly one ULP short."""
    x = jnp.asarray(data, jnp.float32)[None, :]
    q = ref.mx_quantize_ref(x, precision)
    y = ref.mx_dequantize_ref(q)
    mb = MANTISSA_BITS[precision]
    scale = jnp.exp2(q.exponent.astype(jnp.float32))  # block scale
    bound = float(scale[0, 0]) * 2.0 ** (-(mb - 1)) + 1e-6
    err = np.max(np.abs(np.asarray(y - x)))
    assert err <= bound * 1.001, (err, bound)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    scale=st.floats(min_value=1e-3, max_value=1e3),
    precision=st.sampled_from(PRECISIONS))
def test_quantize_idempotent(seed, scale, precision):
    """Quantizing an already-quantized tensor is exact (fixed point)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 32)) * scale
    y1 = ref.mx_quant_dequant_ref(x, precision)
    y2 = ref.mx_quant_dequant_ref(y1, precision)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16),
                  precision=st.sampled_from(PRECISIONS))
def test_quantize_sign_and_zero(seed, precision):
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 32))
    x = x.at[:, :4].set(0.0)
    q = ref.mx_quantize_ref(x, precision)
    y = ref.mx_dequantize_ref(q)
    assert np.all(np.asarray(y[:, :4]) == 0.0)
    nz = np.asarray(x) != 0
    assert np.all(np.sign(np.asarray(y))[nz] * np.sign(np.asarray(x))[nz]
                  >= 0)


def test_mx_dense_gradient_flows():
    from repro.core.mx import mx_dense

    x = jax.random.normal(jax.random.PRNGKey(6), (8, 32))
    w = jax.random.normal(jax.random.PRNGKey(7), (32, 16))

    def loss(w):
        return jnp.sum(mx_dense(x, w, "mx9", "mx9") ** 2)

    g = jax.grad(loss)(w)
    assert np.all(np.isfinite(np.asarray(g)))
    # Gradient should be close to the fp32 gradient (mx9 ~ 0.5% error).
    g_ref = jax.grad(lambda w: jnp.sum((x @ w) ** 2))(w)
    rel = float(jnp.linalg.norm(g - g_ref) / jnp.linalg.norm(g_ref))
    assert rel < 0.05, rel


def test_quantize_tree_only_touches_matrices():
    from repro.core.mx import quantize_tree

    params = {"w": jnp.ones((64, 64)), "b": jnp.ones((64,)),
              "step": jnp.zeros((), jnp.int32)}
    q = quantize_tree(params, "mx6", min_size=16)
    np.testing.assert_array_equal(q["b"], params["b"])
    np.testing.assert_array_equal(q["step"], params["step"])
    assert q["w"].shape == params["w"].shape


# ------------------------------------------------- fused hot path (PR 7) --
# Aligned, odd/ragged (exercises the M/N/K padding), and a large mixed case.
FUSED_SHAPES = [(8, 128, 128), (5, 48, 33), (64, 512, 384)]


@pytest.mark.parametrize("mode", ["ref", "interpret"])
@pytest.mark.parametrize("mnk", FUSED_SHAPES)
@pytest.mark.parametrize("pa,pb", [("mx4", "mx4"), ("mx6", "mx6"),
                                   ("mx9", "mx6")])
def test_fused_matches_unfused_bitwise(monkeypatch, mode, mnk, pa, pb):
    """``mx_matmul_fused`` (one program) is bit-identical to the unfused
    ``mx_quantize``→``mx_matmul`` chain in every kernel mode — including
    odd shapes served through the zero-pad + slice path."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", mode)
    m, k, n = mnk
    a = jax.random.normal(jax.random.PRNGKey(10), (m, k))
    b = jax.random.normal(jax.random.PRNGKey(11), (k, n))
    fused = np.asarray(ops.mx_matmul_fused(a, b, pa, pb))
    unfused = np.asarray(ops.mx_matmul(a, b, pa, pb))
    np.testing.assert_array_equal(fused, unfused)
    assert fused.shape == (m, n)


def test_fused_handles_zero_blocks(monkeypatch):
    """All-zero 16-blocks hit the inf-quantize-scale edge (0 * inf = nan
    mantissa); the fused kernel must flush it to zero exactly like the
    unfused int8 mantissa cast does."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    a = jax.random.normal(jax.random.PRNGKey(12), (8, 128))
    a = a.at[:, 32:64].set(0.0)  # two all-zero blocks per row
    a = a.at[2].set(0.0)  # one all-zero row
    b = jax.random.normal(jax.random.PRNGKey(13), (128, 128))
    for prec in PRECISIONS:
        fused = np.asarray(ops.mx_matmul_fused(a, b, prec, prec))
        unfused = np.asarray(ops.mx_matmul(a, b, prec, prec))
        assert np.all(np.isfinite(fused)), prec
        np.testing.assert_array_equal(fused, unfused)
        np.testing.assert_array_equal(fused[2], np.zeros(128))


def test_fused_kernel_direct_vs_separate_kernels():
    """Kernel-level check (no ops routing): the fused Pallas kernel equals
    quantize-kernel → matmul-kernel composition at the SAME tile sizes."""
    from repro.kernels.mx_fused import mx_matmul_fused as fused_kernel

    m, k, n = 16, 256, 128
    a = jax.random.normal(jax.random.PRNGKey(14), (m, k))
    b = jax.random.normal(jax.random.PRNGKey(15), (k, n))
    out_f = fused_kernel(a, b, "mx6", "mx6", bm=8, bn=128, bk=128,
                         interpret=True)
    qa = mx_quantize_kernel(a, "mx6", interpret=True)
    qbt = mx_quantize_kernel(b.T, "mx6", interpret=True)
    qb = MXTensor(qbt.mantissa.T, qbt.exponent.T, qbt.mx_bits.T, "mx6")
    out_u = mx_matmul_kernel(qa, qb, bm=8, bn=128, bk=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_u))


@pytest.mark.parametrize("mode", ["ref", "interpret"])
def test_mx_dense_vjp_matches_unfused(monkeypatch, mode):
    """The fused-path ``mx_dense`` VJP is bitwise the manual unfused
    composition of the two gradient GEMMs."""
    from repro.core.mx import mx_dense

    monkeypatch.setenv("REPRO_KERNEL_MODE", mode)
    x = jax.random.normal(jax.random.PRNGKey(16), (8, 64))
    w = jax.random.normal(jax.random.PRNGKey(17), (64, 32))

    def loss(x, w):
        return jnp.sum(mx_dense(x, w, "mx6", "mx9") ** 2)

    y = ops.mx_matmul_fused(x, w, "mx6", "mx6")
    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    g2 = np.asarray(2.0 * y, np.float32)
    gx_manual = ops.mx_matmul(jnp.asarray(g2), w.T, "mx9", "mx9")
    gw_manual = ops.mx_matmul(x.T, jnp.asarray(g2), "mx9", "mx9")
    np.testing.assert_array_equal(np.asarray(gx), np.asarray(gx_manual))
    np.testing.assert_array_equal(np.asarray(gw), np.asarray(gw_manual))


def test_kernel_stats_no_silent_ref_fallback(monkeypatch):
    """Odd shapes must be served by the requested kernel path (padded), not
    silently dropped onto the ref oracle; ``kernel_stats`` proves it —
    including the PR 9 backward-pair and weight-resident entries."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    ops.reset_kernel_stats()
    try:
        x = jax.random.normal(jax.random.PRNGKey(18), (5, 33))
        q = ops.mx_quantize(x, "mx6")
        assert q.mantissa.shape[0] == 5
        a = jax.random.normal(jax.random.PRNGKey(19), (5, 48))
        b = jax.random.normal(jax.random.PRNGKey(20), (48, 33))
        out = ops.mx_matmul(a, b, "mx6", "mx6")
        assert out.shape == (5, 33)
        out_f = ops.mx_matmul_fused(a, b, "mx6", "mx6")
        assert out_f.shape == (5, 33)
        g = jax.random.normal(jax.random.PRNGKey(21), (5, 33))
        dx, dw = ops.mx_matmul_bwd_pair(g, a, b, "mx9")
        assert dx.shape == (5, 48) and dw.shape == (48, 33)
        out_p = ops.mx_matmul_prequant(a, ops.mx_quantize_rhs(b, "mx6"),
                                       "mx6")
        assert out_p.shape == (5, 33)
        stats = ops.kernel_stats()
        for op in ("mx_quantize", "mx_matmul", "mx_matmul_fused",
                   "mx_matmul_bwd_pair", "mx_matmul_prequant"):
            assert "ref" not in stats[op], (op, stats)
            assert stats[op]["interpret"] >= 1, (op, stats)
    finally:
        ops.reset_kernel_stats()


# --------------------------------------------- backward pair (PR 9) --------
# (m, k, n): aligned, odd/ragged (M/N/K padding on both GEMMs), large mixed.
BWD_SHAPES = [(8, 128, 128), (5, 33, 48), (16, 432, 64)]


@pytest.mark.parametrize("mode", ["ref", "interpret"])
@pytest.mark.parametrize("mkn", BWD_SHAPES)
@pytest.mark.parametrize("prec", PRECISIONS)
def test_bwd_pair_matches_two_fused_bitwise(monkeypatch, mode, mkn, prec):
    """``mx_matmul_bwd_pair`` (ONE program for both gradients) is
    bit-identical to the two independent fused GEMMs it replaces, in every
    kernel mode, including odd shapes served through the pad + slice path."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", mode)
    m, k, n = mkn
    g = jax.random.normal(jax.random.PRNGKey(30), (m, n))
    x = jax.random.normal(jax.random.PRNGKey(31), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(32), (k, n))
    dx, dw = ops.mx_matmul_bwd_pair(g, x, w, prec)
    dx_u = ops.mx_matmul_fused(g, w.T, prec, prec)
    dw_u = ops.mx_matmul_fused(x.T, g, prec, prec)
    np.testing.assert_array_equal(np.asarray(dx), np.asarray(dx_u))
    np.testing.assert_array_equal(np.asarray(dw), np.asarray(dw_u))
    assert dx.shape == (m, k) and dw.shape == (k, n)


def test_bwd_pair_zero_block_cotangent(monkeypatch):
    """All-zero 16-blocks of the cotangent hit the inf-quantize-scale edge
    (0 * inf = nan mantissa) in BOTH phases of the pair kernel — each must
    flush it to zero exactly like the standalone fused launches do."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    g = jax.random.normal(jax.random.PRNGKey(33), (8, 128))
    g = g.at[:, 32:64].set(0.0)  # zero blocks along N (dX's contraction)
    g = g.at[3].set(0.0)  # zero row -> zero blocks along M (dW's)
    x = jax.random.normal(jax.random.PRNGKey(34), (8, 64))
    w = jax.random.normal(jax.random.PRNGKey(35), (64, 128))
    for prec in PRECISIONS:
        dx, dw = ops.mx_matmul_bwd_pair(g, x, w, prec)
        assert np.all(np.isfinite(np.asarray(dx))), prec
        assert np.all(np.isfinite(np.asarray(dw))), prec
        dx_u = ops.mx_matmul_fused(g, w.T, prec, prec)
        dw_u = ops.mx_matmul_fused(x.T, g, prec, prec)
        np.testing.assert_array_equal(np.asarray(dx), np.asarray(dx_u))
        np.testing.assert_array_equal(np.asarray(dw), np.asarray(dw_u))
        np.testing.assert_array_equal(np.asarray(dx)[3], np.zeros(64))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**16),
       m=st.sampled_from([1, 3, 8, 17]),
       k=st.sampled_from([8, 33, 64]),
       n=st.sampled_from([16, 48]),
       precision=st.sampled_from(PRECISIONS))
def test_bwd_pair_property_bitwise(seed, m, k, n, precision):
    """Property sweep over random shapes/precisions in whatever kernel mode
    the suite runs under (auto/ref/interpret — CI covers all three): the
    pair is ALWAYS bitwise the two-GEMM chain."""
    kg = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(kg, 3)
    g = jax.random.normal(k1, (m, n))
    x = jax.random.normal(k2, (m, k))
    w = jax.random.normal(k3, (k, n))
    dx, dw = ops.mx_matmul_bwd_pair(g, x, w, precision)
    np.testing.assert_array_equal(
        np.asarray(dx), np.asarray(ops.mx_matmul_fused(g, w.T, precision,
                                                       precision)))
    np.testing.assert_array_equal(
        np.asarray(dw), np.asarray(ops.mx_matmul_fused(x.T, g, precision,
                                                       precision)))


@pytest.mark.parametrize("mode", ["ref", "interpret"])
def test_mx_dense_vjp_through_bwd_pair(monkeypatch, mode):
    """``mx_dense``'s VJP now routes through the backward pair; its
    gradients stay bitwise the manual two-GEMM composition (the same
    contract ``test_mx_dense_vjp_matches_unfused`` pins via mx_matmul)."""
    from repro.core.mx import mx_dense

    monkeypatch.setenv("REPRO_KERNEL_MODE", mode)
    x = jax.random.normal(jax.random.PRNGKey(36), (8, 64))
    w = jax.random.normal(jax.random.PRNGKey(37), (64, 32))

    def loss(x, w):
        return jnp.sum(mx_dense(x, w, "mx6", "mx9") ** 2)

    y = ops.mx_matmul_fused(x, w, "mx6", "mx6")
    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    g2 = jnp.asarray(np.asarray(2.0 * y, np.float32))
    np.testing.assert_array_equal(
        np.asarray(gx),
        np.asarray(ops.mx_matmul_fused(g2, w.T, "mx9", "mx9")))
    np.testing.assert_array_equal(
        np.asarray(gw),
        np.asarray(ops.mx_matmul_fused(x.T, g2, "mx9", "mx9")))


# ------------------------------------- weight-resident serving (PR 9) -------
@pytest.mark.parametrize("mode", ["ref", "interpret"])
@pytest.mark.parametrize("mkn", [(8, 128, 128), (5, 33, 48)])
@pytest.mark.parametrize("prec", PRECISIONS)
def test_prequant_matches_fused_bitwise(monkeypatch, mode, mkn, prec):
    """Serving against the RESIDENT quantized weight (``mx_quantize_rhs``
    once, ``mx_matmul_prequant`` per call) is bit-identical to the fused
    GEMM that re-quantizes the weight every call — MX quantization is
    idempotent, so the stored mantissas/scales ARE what fused recomputes."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", mode)
    m, k, n = mkn
    a = jax.random.normal(jax.random.PRNGKey(40), (m, k))
    b = jax.random.normal(jax.random.PRNGKey(41), (k, n))
    qb = ops.mx_quantize_rhs(b, prec)
    out_p = np.asarray(ops.mx_matmul_prequant(a, qb, prec))
    out_f = np.asarray(ops.mx_matmul_fused(a, b, prec, prec))
    np.testing.assert_array_equal(out_p, out_f)
    assert out_p.shape == (m, n)


def test_prequant_zero_weight_quantize_ops_per_call(monkeypatch):
    """After the one-time ``mx_quantize_rhs`` fill, repeated prequant calls
    perform ZERO weight-quantization ops — kernel_stats proves it."""
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    ops.reset_kernel_stats()
    try:
        a = jax.random.normal(jax.random.PRNGKey(42), (8, 64))
        b = jax.random.normal(jax.random.PRNGKey(43), (64, 32))
        qb = ops.mx_quantize_rhs(b, "mx6")
        for _ in range(5):
            ops.mx_matmul_prequant(a, qb, "mx6")
        stats = ops.kernel_stats()
        assert stats["mx_quantize"]["interpret"] == 1, stats  # the fill
        assert stats["mx_matmul_prequant"]["interpret"] == 5, stats
        assert "ref" not in stats["mx_matmul_prequant"], stats
    finally:
        ops.reset_kernel_stats()


def test_mx_dense_prequant_matches_mx_dense_forward(monkeypatch):
    """``mx_dense_prequant`` (weight-resident serving) equals ``mx_dense``'s
    forward bitwise, including a batched >2D activation."""
    from repro.core.mx import mx_dense, mx_dense_prequant

    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    x = jax.random.normal(jax.random.PRNGKey(44), (2, 4, 64))
    w = jax.random.normal(jax.random.PRNGKey(45), (64, 32))
    qw = ops.mx_quantize_rhs(w, "mx6")
    y_p = np.asarray(mx_dense_prequant(x, qw, "mx6"))
    y_f = np.asarray(mx_dense(x, w, "mx6", "mx9"))
    np.testing.assert_array_equal(y_p, y_f)
    assert y_p.shape == (2, 4, 32)


def test_quantize_tree_mx_round_trip_bitwise():
    """The resident quantized tree (``quantize_tree_mx``) dequantizes back
    (``dequantize_tree_mx``) bit-for-bit to the legacy ``quantize_tree``
    fake-quant tree; non-weight leaves pass through by identity."""
    from repro.core.mx import (MXLeaf, dequantize_tree_mx, quantize_tree,
                               quantize_tree_mx)

    tree = {"conv": jax.random.normal(jax.random.PRNGKey(46), (3, 3, 8, 16)),
            "head": jax.random.normal(jax.random.PRNGKey(47), (48, 10)) * 3.0,
            "bias": jnp.ones((64,)), "step": jnp.zeros((), jnp.int32)}
    for prec in PRECISIONS:
        resident = quantize_tree_mx(tree, prec, min_size=256)
        assert isinstance(resident["conv"], MXLeaf)
        assert resident["conv"].q.mantissa.dtype == jnp.int8
        assert resident["bias"] is tree["bias"]
        back = dequantize_tree_mx(resident)
        legacy = quantize_tree(tree, prec, min_size=256)
        for name in tree:
            np.testing.assert_array_equal(np.asarray(back[name]),
                                          np.asarray(legacy[name]))
            assert back[name].dtype == legacy[name].dtype, (prec, name)
        assert back["step"] is tree["step"]


def test_kernel_stats_concurrent_increments():
    """The dispatch counters are process-global and, under overlapped
    shard stepping, bumped from worker threads — hammer _count from 8
    threads and require that not one increment is lost."""
    import threading

    ops.reset_kernel_stats()
    try:
        n_threads, per_thread = 8, 500
        start = threading.Barrier(n_threads)

        def worker(i):
            start.wait()
            for _ in range(per_thread):
                ops._count("mx_matmul", "interpret")
                ops._count(f"op{i % 2}", "ref")

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = ops.kernel_stats()
        assert stats["mx_matmul"]["interpret"] == n_threads * per_thread
        assert (stats["op0"]["ref"] + stats["op1"]["ref"]
                == n_threads * per_thread)
    finally:
        ops.reset_kernel_stats()
