"""MX format: kernel-vs-oracle equivalence sweeps + property invariants
(hypothesis when installed, deterministic fallback otherwise)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.kernels import ops, ref
from repro.kernels.mx_matmul import mx_matmul as mx_matmul_kernel
from repro.kernels.mx_quantize import mx_quantize as mx_quantize_kernel
from repro.kernels.ref import BLOCK, MANTISSA_BITS, MXTensor

PRECISIONS = ("mx4", "mx6", "mx9")


@pytest.mark.parametrize("precision", PRECISIONS)
@pytest.mark.parametrize("shape", [(8, 16), (32, 64), (128, 512), (16, 48)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_kernel_matches_ref(precision, shape, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(0), shape) * 5.0).astype(dtype)
    qk = mx_quantize_kernel(x.astype(jnp.float32), precision, interpret=True)
    qr = ref.mx_quantize_ref(x.astype(jnp.float32), precision)
    np.testing.assert_array_equal(qk.mantissa, qr.mantissa)
    np.testing.assert_array_equal(qk.exponent, qr.exponent)
    np.testing.assert_array_equal(qk.mx_bits, qr.mx_bits)


@pytest.mark.parametrize("precision,max_rel", [("mx4", 0.35), ("mx6", 0.09),
                                               ("mx9", 0.012)])
def test_quantization_error_bounds(precision, max_rel):
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 256)) * 3.0
    y = ref.mx_quant_dequant_ref(x, precision)
    rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
    assert rel < max_rel, (precision, rel)


@pytest.mark.parametrize("mnk", [(8, 128, 128), (128, 256, 128),
                                 (64, 512, 384)])
@pytest.mark.parametrize("pa,pb", [("mx9", "mx9"), ("mx6", "mx6"),
                                   ("mx9", "mx6")])
def test_matmul_kernel_matches_ref(mnk, pa, pb):
    m, k, n = mnk
    a = jax.random.normal(jax.random.PRNGKey(2), (m, k))
    b = jax.random.normal(jax.random.PRNGKey(3), (k, n))
    qa = ref.mx_quantize_ref(a, pa)
    qbt = ref.mx_quantize_ref(b.T, pb)
    qb = MXTensor(qbt.mantissa.T, qbt.exponent.T, qbt.mx_bits.T, pb)
    out_k = mx_matmul_kernel(qa, qb, interpret=True, bk=128)
    out_r = ref.mx_matmul_ref(qa, qbt)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-4)


def test_mx9_matmul_accuracy_vs_fp32():
    a = jax.random.normal(jax.random.PRNGKey(4), (64, 256))
    b = jax.random.normal(jax.random.PRNGKey(5), (256, 64))
    out = ref.mx_matmul_fp_ref(a, b, "mx9", "mx9")
    rel = float(jnp.linalg.norm(out - a @ b) / jnp.linalg.norm(a @ b))
    assert rel < 0.02


# ------------------------------------------------------------- properties --
@settings(max_examples=30, deadline=None)
@given(
    data=st.lists(
        st.floats(min_value=-1e4, max_value=1e4, allow_nan=False,
                  width=32),
        min_size=BLOCK, max_size=BLOCK),
    precision=st.sampled_from(PRECISIONS))
def test_dequant_error_bounded_per_block(data, precision):
    """|x - dq(q(x))| <= 2^E * 2^-(mb-1) per element: one ULP of the block
    scale. Rounding alone is half an ULP, but the sign-magnitude mantissa
    saturates at 2^mb - 1, so a block max just under 2^(E+1) clips to
    (2 - 2^-(mb-1)) * 2^E — exactly one ULP short."""
    x = jnp.asarray(data, jnp.float32)[None, :]
    q = ref.mx_quantize_ref(x, precision)
    y = ref.mx_dequantize_ref(q)
    mb = MANTISSA_BITS[precision]
    scale = jnp.exp2(q.exponent.astype(jnp.float32))  # block scale
    bound = float(scale[0, 0]) * 2.0 ** (-(mb - 1)) + 1e-6
    err = np.max(np.abs(np.asarray(y - x)))
    assert err <= bound * 1.001, (err, bound)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    scale=st.floats(min_value=1e-3, max_value=1e3),
    precision=st.sampled_from(PRECISIONS))
def test_quantize_idempotent(seed, scale, precision):
    """Quantizing an already-quantized tensor is exact (fixed point)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 32)) * scale
    y1 = ref.mx_quant_dequant_ref(x, precision)
    y2 = ref.mx_quant_dequant_ref(y1, precision)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16),
                  precision=st.sampled_from(PRECISIONS))
def test_quantize_sign_and_zero(seed, precision):
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 32))
    x = x.at[:, :4].set(0.0)
    q = ref.mx_quantize_ref(x, precision)
    y = ref.mx_dequantize_ref(q)
    assert np.all(np.asarray(y[:, :4]) == 0.0)
    nz = np.asarray(x) != 0
    assert np.all(np.sign(np.asarray(y))[nz] * np.sign(np.asarray(x))[nz]
                  >= 0)


def test_mx_dense_gradient_flows():
    from repro.core.mx import mx_dense

    x = jax.random.normal(jax.random.PRNGKey(6), (8, 32))
    w = jax.random.normal(jax.random.PRNGKey(7), (32, 16))

    def loss(w):
        return jnp.sum(mx_dense(x, w, "mx9", "mx9") ** 2)

    g = jax.grad(loss)(w)
    assert np.all(np.isfinite(np.asarray(g)))
    # Gradient should be close to the fp32 gradient (mx9 ~ 0.5% error).
    g_ref = jax.grad(lambda w: jnp.sum((x @ w) ** 2))(w)
    rel = float(jnp.linalg.norm(g - g_ref) / jnp.linalg.norm(g_ref))
    assert rel < 0.05, rel


def test_quantize_tree_only_touches_matrices():
    from repro.core.mx import quantize_tree

    params = {"w": jnp.ones((64, 64)), "b": jnp.ones((64,)),
              "step": jnp.zeros((), jnp.int32)}
    q = quantize_tree(params, "mx6", min_size=16)
    np.testing.assert_array_equal(q["b"], params["b"])
    np.testing.assert_array_equal(q["step"], params["step"])
    assert q["w"].shape == params["w"].shape
