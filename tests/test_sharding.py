"""Sharding rules, logical->spec translation, HLO analyzer units."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import configs
from repro.distributed import (
    ParamDef,
    ShardingRules,
    init_params,
    param_shapes,
    param_specs,
    stack_defs,
    use_rules,
)
from repro.launch.hlo_analysis import analyze_hlo_text, parse_hlo
from repro.launch.sharding import heads_divisible, make_rules


def _fake_mesh(shape, axes):
    devs = np.array(jax.devices() * int(np.prod(shape)))[: int(np.prod(shape))]
    return Mesh(devs.reshape(shape), axes)


def test_spec_dedups_reused_axes():
    rules = ShardingRules({"a": "model", "b": "model", "c": ("pod", "data")})
    assert rules.spec_for(("a", "b")) == P("model", None)
    assert rules.spec_for(("c", "a")) == P(("pod", "data"), "model")


def test_rules_train_vs_serve():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    arch = configs.get_arch("yi-6b")
    train = make_rules(arch, configs.get_shape("train_4k"), mesh)
    serve = make_rules(arch, configs.get_shape("decode_32k"), mesh)
    assert train["embed"] == "data"  # FSDP in training
    assert serve["embed"] is None  # replicated weights when serving
    assert serve["kv_seq"] == "model"  # sequence-sharded KV


def test_long_context_rules_shard_seq_everywhere():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    arch = configs.get_arch("jamba-v0.1-52b")
    rules = make_rules(arch, configs.get_shape("long_500k"), mesh)
    assert rules["kv_seq"] == ("data", "model")
    assert rules["kv_batch"] is None


def test_seq_parallel_attention_for_non_divisible_heads():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    gemma = configs.get_arch("gemma2-2b")  # 8 heads % 16 != 0
    yi6 = configs.get_arch("yi-6b")  # 32 heads % 16 == 0
    assert not heads_divisible(gemma, mesh)
    assert heads_divisible(yi6, mesh)
    rules_g = make_rules(gemma, configs.get_shape("train_4k"), mesh)
    rules_y = make_rules(yi6, configs.get_shape("train_4k"), mesh)
    assert rules_g.get("attn_seq") == "model"
    assert rules_y.get("attn_seq") is None


def test_param_defs_roundtrip():
    defs = {"w": ParamDef((8, 16), ("embed", "ff")),
            "b": ParamDef((16,), ("ff",), init="zeros")}
    params = init_params(defs, jax.random.PRNGKey(0))
    assert params["w"].shape == (8, 16)
    assert float(jnp.abs(params["b"]).max()) == 0.0
    shapes = param_shapes(defs)
    assert shapes["w"].shape == (8, 16)
    with use_rules(ShardingRules({"ff": "model"})):
        specs = param_specs(defs)
    assert specs["w"] == P(None, "model")
    stacked = stack_defs([defs, defs])
    assert stacked["w"].shape == (2, 8, 16)
    assert stacked["w"].logical == ("layers", "embed", "ff")


def test_expert_fission_divisibility():
    from repro.models.moe import expert_split_factor

    mesh = _fake_mesh((16, 16), ("data", "model"))
    mixtral = configs.get_arch("mixtral-8x7b")  # 8 experts
    jamba = configs.get_arch("jamba-v0.1-52b")  # 16 experts
    rules = make_rules(mixtral, configs.get_shape("train_4k"), mesh)
    with use_rules(rules, mesh):
        assert expert_split_factor(mixtral) == 2  # 8 -> 16 virtual
        assert expert_split_factor(jamba) == 1
    assert expert_split_factor(mixtral) == 1  # no mesh -> no fission


def test_moe_fission_numerically_exact():
    """r-way virtual experts == unsplit experts (same routing)."""
    import dataclasses

    from repro.models import moe

    cfg = dataclasses.replace(configs.get_arch("mixtral-8x7b").reduced(),
                              capacity_factor=16.0)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, cfg.d_model))
    params = init_params(moe.moe_defs(cfg), jax.random.PRNGKey(1))
    y_ref, aux_ref = moe.moe_forward(params, x, cfg)
    # manually split each expert into 2 virtual experts
    r = 2
    e, d, f = params["w_gate"].shape

    def split(w, axis_f):
        if axis_f == 2:  # [e, d, f] -> [e*r, d, f/r]
            return w.reshape(e, d, r, f // r).transpose(0, 2, 1, 3) \
                .reshape(e * r, d, f // r)
        return w.reshape(e, r, f // r, d).reshape(e * r, f // r, d)

    params_v = {
        "router": params["router"],
        "w_gate": split(params["w_gate"], 2),
        "w_up": split(params["w_up"], 2),
        "w_down": params["w_down"].reshape(e, r, f // r, d)
        .reshape(e * r, f // r, d),
    }
    y_v, aux_v = moe.moe_forward(params_v, x, cfg)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_v),
                               rtol=2e-4, atol=2e-5)


def test_hlo_analyzer_counts_scan_trips():
    def f(x, ws):
        def body(x, w):
            return jax.nn.relu(x @ w), None
        return jax.lax.scan(body, x, ws)[0]

    xs = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
    text = jax.jit(f).lower(xs, ws).compile().as_text()
    cost = analyze_hlo_text(text)
    expected = 6 * 2 * 32 * 64 * 64
    assert abs(cost.flops - expected) / expected < 0.01


def test_hlo_analyzer_parses_tuple_types():
    text = """
ENTRY %main (p: (f32[4,4], s32[])) -> f32[4,4] {
  %p = (f32[4,4]{1,0}, s32[]) parameter(0)
  %a = f32[4,4]{1,0} get-tuple-element(%p), index=0
  ROOT %d = f32[4,4]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    comps = parse_hlo(text)
    assert "main" in comps
    cost = analyze_hlo_text(text)
    assert cost.flops == 2 * 4 * 4 * 4
