"""Property-test shim: real hypothesis when installed, deterministic fallback
otherwise.

The tier-1 suite must collect and run in environments without ``hypothesis``
(the container image does not bake it in). When the real library is present
we re-export its ``given``/``settings``/``strategies``; otherwise a minimal
deterministic stand-in draws ``max_examples`` pseudo-random examples from a
fixed-seed generator, so the property tests still execute (reproducibly)
instead of erroring at collection.

Only the strategy combinators the suite uses are implemented: ``floats``,
``integers``, ``sampled_from``, ``lists``, ``tuples``.
"""
from __future__ import annotations


try:
    import hypothesis as _hypothesis
    import hypothesis.strategies as st

    given = _hypothesis.given
    settings = _hypothesis.settings
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import numpy as np

    HAVE_HYPOTHESIS = False
    _FALLBACK_SEED = 0xDACA90
    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            lo, hi = float(min_value), float(max_value)
            return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

        @staticmethod
        def integers(min_value=0, max_value=100):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def tuples(*elements):
            return _Strategy(
                lambda rng: tuple(e.example(rng) for e in elements))

    st = _Strategies()

    def given(**strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(_FALLBACK_SEED)
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                for _ in range(n):
                    drawn = {k: s.example(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)

            # Copy identity but NOT the signature: pytest must not mistake
            # the strategy parameters for fixtures (so no functools.wraps,
            # whose __wrapped__ would expose the original signature).
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco

    def settings(max_examples=_DEFAULT_EXAMPLES, **_):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco
