"""Two-plane decision API tests: the AllocationDecision <-> Decision
facade round trip (property-pinned), spatial-plane resolution, the kernel
plan_* accessors, plan-consuming dispatch, the engine-set drift flag, and
the pluggable FleetRowPolicy implementations."""
import dataclasses

import pytest

from _hypothesis_compat import given, settings, st
from repro.configs.dacapo_pairs import RESNET18
from repro.core.allocation import (
    AllocationDecision,
    CLHyperParams,
    FleetAllocator,
    PhaseFeedback,
    SpatiotemporalAllocator,
)
from repro.core.decision import (
    FLEET_ROW_POLICIES,
    Decision,
    DriftSurgeRowPolicy,
    FleetRowContext,
    FleetRowPolicy,
    ResolveMaxRowPolicy,
    SpatialPlan,
    TemporalPlan,
    WeightedVoteRowPolicy,
    as_decision,
    make_fleet_row_policy,
)
from repro.core.dispatch import KernelDispatcher
from repro.core.estimator import DaCapoEstimator
from repro.core.kernel import InferenceKernel, LabelingKernel, RetrainKernel
from repro.core.mx import DEFAULT_POLICY, PrecisionPolicy
from repro.models.registry import make_vision_model


# ------------------------------------------------------- facade round trip --
@settings(max_examples=60, deadline=None)
@given(
    retrain=st.integers(0, 512),
    valid=st.integers(0, 128),
    label=st.integers(1, 512),
    reset=st.sampled_from([False, True]),
    extra=st.integers(0, 384),
    rows=st.sampled_from([(None, None), (0, 16), (8, 8), (12, 4), (16, 0)]),
    pace=st.sampled_from([None, 10.0, 120.0]),
    epochs=st.sampled_from([None, 1, 3]),
    profile=st.floats(0.0, 9.0))
def test_legacy_split_roundtrip_is_identity(retrain, valid, label, reset,
                                            extra, rows, pace, epochs,
                                            profile):
    """Any legacy AllocationDecision -> .split() -> from_legacy/to_legacy
    is the identity — the facade loses nothing in either direction."""
    legacy = AllocationDecision(
        retrain_samples=retrain, valid_samples=valid, label_samples=label,
        reset_buffer=reset, extra_label_samples=extra,
        rows_tsa=rows[0], rows_bsa=rows[1],
        precisions=PrecisionPolicy(inference="mx9"),
        pace_window_s=pace, retrain_epochs=epochs, profile_cost_s=profile)
    dec = legacy.split()
    assert isinstance(dec, Decision)
    assert dec == Decision.from_legacy(legacy)
    back = dec.to_legacy()
    assert back == legacy
    assert AllocationDecision.from_decision(dec) == legacy
    # Plane fields landed where they belong.
    assert dec.spatial.rows_tsa == rows[0]
    assert dec.spatial.rows_bsa == rows[1]
    assert dec.temporal.total_label_samples == legacy.total_label_samples
    # And a second lift of the flattened form is stable.
    assert back.split() == dec


def test_as_decision_normalizes_both_surfaces():
    legacy = AllocationDecision(10, 4, 8)
    dec = legacy.split()
    assert as_decision(dec) is dec  # two-plane passthrough
    assert as_decision(legacy) == dec  # legacy lift


def test_spatial_plan_resolution_semantics():
    """None rows -> offline defaults; 0 rows -> whole-array time-share."""
    plan = SpatialPlan(rows_tsa=None, rows_bsa=None)
    assert plan.resolve(6, 10, 16) == dataclasses.replace(
        plan, rows_tsa=6, rows_bsa=10)
    # The R=0 fallback: a 0-row side time-shares all rows.
    plan = SpatialPlan(rows_tsa=0, rows_bsa=16)
    resolved = plan.resolve(None, None, 16)
    assert (resolved.rows_tsa, resolved.rows_bsa) == (16, 16)
    # Explicit rows pass through; role accessor follows the ledger names.
    resolved = SpatialPlan(rows_tsa=12, rows_bsa=4).resolve(8, 8, 16)
    assert resolved.rows_for("t_sa") == 12
    assert resolved.rows_for("b_sa") == 4
    assert resolved.refission  # default: engine may re-fission the mesh


# ------------------------------------------------------ kernel plane view --
def test_kernels_read_rows_and_precision_from_spatial_plane():
    est = DaCapoEstimator()
    hp = CLHyperParams()
    model = make_vision_model(RESNET18.reduced())
    prec = PrecisionPolicy(inference="mx4", labeling="mx6",
                           retraining="mx9")
    spatial = SpatialPlan(rows_tsa=12, rows_bsa=4, precisions=prec)
    inf = InferenceKernel(model, RESNET18, est, apply_mx=False)
    lab = LabelingKernel(model, RESNET18, est, apply_mx=False)
    ret = RetrainKernel(model, RESNET18, est, hp)
    # Each kernel picks its own rows (by role) and precision (by field).
    assert inf.plan_time_per_sample(spatial) == inf.time_per_sample(4, "mx4")
    assert lab.plan_time_per_sample(spatial) == lab.time_per_sample(12, "mx6")
    assert ret.plan_time_per_batch(spatial) == ret.time_per_batch(12, "mx9")
    # Role override: sequential dispatch charges validation inference on
    # the T-SA chain.
    assert (inf.plan_time_per_sample(spatial, role="t_sa")
            == inf.time_per_sample(12, "mx4"))
    assert inf.plan_keep_frac(spatial, 30.0) == inf.keep_frac(4, "mx4", 30.0)


# --------------------------------------------------- plan-consuming phase --
class _RecordingPipe:
    def __init__(self):
        self.hints = []

    def begin_phase(self, start, label_hint=None):
        self.hints.append(label_hint)


def test_begin_phase_derives_label_hints_from_temporal_plane():
    disp = KernelDispatcher()
    decs = [AllocationDecision(10, 4, 8, extra_label_samples=24).split(),
            AllocationDecision(10, 4, 16).split()]
    pipes = [_RecordingPipe(), _RecordingPipe()]
    plan = disp.begin_phase(0.0, pipes, decisions=decs, fps=30.0)
    assert pipes[0].hints == [(32, 30.0)]  # label + extra from the plane
    assert pipes[1].hints == [(16, 30.0)]
    assert plan.decisions == tuple(decs)  # the plan carries the intent
    # fps=None records the decisions but suppresses hinting (the
    # decision_aware_spec=False path).
    plan = disp.begin_phase(1.0, pipes, decisions=decs, fps=None)
    assert pipes[0].hints[-1] is None and pipes[1].hints[-1] is None
    assert plan.decisions == tuple(decs)
    # Explicit label_hints win over derivation (pre-plane callers).
    disp.begin_phase(2.0, pipes, label_hints=[(7, 1.0), None],
                     decisions=decs, fps=30.0)
    assert pipes[0].hints[-1] == (7, 1.0)


# ------------------------------------------------------- engine drift flag --
def test_policy_honors_engine_set_drift_flag():
    """feedback.drifted is the source of truth when present; None falls
    back to the policy's own detector (legacy paths)."""
    hp = CLHyperParams(v_thr=-0.05)
    pol = SpatiotemporalAllocator(hp)
    healthy = dict(acc_valid=0.8, acc_label=0.82, t=1.0)
    # Engine says drift despite healthy accuracies -> policy resets.
    d = pol.next_decision(PhaseFeedback(**healthy, drifted=True))
    assert d.reset_buffer and d.extra_label_samples == hp.n_ldd - hp.n_l
    # Engine says no drift despite a cliff -> no reset.
    d = pol.next_decision(PhaseFeedback(
        acc_valid=0.9, acc_label=0.2, t=2.0, drifted=False))
    assert not d.reset_buffer
    # drifted=None (legacy feedback): detector re-derives -> reset fires.
    d = pol.next_decision(PhaseFeedback(acc_valid=0.9, acc_label=0.2, t=3.0))
    assert d.reset_buffer
    # observe_drift delegates to the (swappable) detector.
    assert pol.observe_drift(0.2, 0.9, 4.0)
    assert not pol.observe_drift(0.82, 0.8, 5.0)


# -------------------------------------------------------- fleet row policies --
def _ctx(drifted, weights=None, total=16):
    n = len(drifted)
    return FleetRowContext(drifted=tuple(drifted),
                           weights=tuple(weights or [1.0 / n] * n),
                           total_rows=total)


def _spatials(rows):
    return [SpatialPlan(rows_tsa=t, rows_bsa=b, precisions=DEFAULT_POLICY)
            for t, b in rows]


def test_row_policy_registry_and_constructor_dispatch():
    for name, cls in FLEET_ROW_POLICIES.items():
        inst = FleetRowPolicy(name)
        assert isinstance(inst, cls) and inst.name == name
        assert isinstance(make_fleet_row_policy(name), cls)
    surge = FleetRowPolicy("drift-surge", surge_rows=3, hysteresis_phases=5)
    assert isinstance(surge, DriftSurgeRowPolicy)
    assert surge.surge_rows == 3 and surge.hysteresis_phases == 5
    ready = ResolveMaxRowPolicy()
    assert make_fleet_row_policy(ready) is ready
    assert isinstance(make_fleet_row_policy(WeightedVoteRowPolicy),
                      WeightedVoteRowPolicy)
    with pytest.raises(KeyError):
        FleetRowPolicy("round-rows")
    # Tuning knobs for the wrong policy are rejected, never swallowed.
    with pytest.raises(TypeError):
        FleetRowPolicy("resolve-max", surge_rows=2)


def test_resolve_max_matches_the_legacy_rule():
    pol = ResolveMaxRowPolicy()
    spatials = _spatials([(8, 8), (12, 4), (8, 8)])
    out = pol.fleet_spatial(spatials, _ctx([False, True, False]))
    assert (out.rows_tsa, out.rows_bsa) == (12, 4)  # max T-SA, min B-SA
    assert out.precisions is spatials[0].precisions


def test_drift_surge_quorum_hysteresis_and_release():
    pol = DriftSurgeRowPolicy(surge_rows=4, quorum=0.5, hysteresis_phases=2)
    pol.reset(3)
    spatials = _spatials([(8, 8)] * 3)
    # One of three lanes drifting: below quorum, no surge.
    out = pol.fleet_spatial(spatials, _ctx([True, False, False]))
    assert (out.rows_tsa, out.rows_bsa) == (8, 8)
    # Two of three drift simultaneously: surge fires.
    out = pol.fleet_spatial(spatials, _ctx([True, True, False]))
    assert (out.rows_tsa, out.rows_bsa) == (12, 4)
    # Hysteresis holds the surge with no new quorum...
    out = pol.fleet_spatial(spatials, _ctx([False, False, False]))
    assert (out.rows_tsa, out.rows_bsa) == (12, 4)
    # ...and releases once the window expires.
    out = pol.fleet_spatial(spatials, _ctx([False, False, False]))
    assert (out.rows_tsa, out.rows_bsa) == (8, 8)
    # Never drains the B-SA below one row, whatever surge_rows says.
    greedy = DriftSurgeRowPolicy(surge_rows=99)
    out = greedy.fleet_spatial(spatials, _ctx([True, True, True]))
    assert out.rows_bsa == 1 and out.rows_tsa == 15
    # Time-shared regime (rows don't sum to the array): degenerate no-op.
    ts = _spatials([(16, 16)])
    out = pol.fleet_spatial(ts, _ctx([True]))
    assert (out.rows_tsa, out.rows_bsa) == (16, 16)
    # reset() clears a held surge.
    pol.fleet_spatial(spatials, _ctx([True, True, False]))
    pol.reset(3)
    out = pol.fleet_spatial(spatials, _ctx([False, False, False]))
    assert (out.rows_tsa, out.rows_bsa) == (8, 8)


def test_weighted_vote_follows_drift_weighted_shares():
    spatials = _spatials([(8, 8)] * 3)
    # Healthy fleet: every lane votes serving rows — the fleet runs
    # healthy_relief (default: a quarter of base T-SA) below the offline
    # split, because the oversubscribed B-SA is where healthy rows pay.
    out = WeightedVoteRowPolicy().fleet_spatial(spatials, _ctx([False] * 3))
    assert (out.rows_tsa, out.rows_bsa) == (6, 10)
    # healthy_relief=0 pins the healthy-state split to resolve-max.
    pol = WeightedVoteRowPolicy(drift_boost=8, healthy_relief=0)
    out = pol.fleet_spatial(spatials, _ctx([False] * 3))
    assert (out.rows_tsa, out.rows_bsa) == (8, 8)
    # Uniform weights, one drifted lane: a third of the boost.
    out = pol.fleet_spatial(spatials, _ctx([True, False, False]))
    assert (out.rows_tsa, out.rows_bsa) == (11, 5)  # 8 + 8/3 rounded
    # Drift-weight concentrated on the drifted lane: (almost) full boost.
    out = pol.fleet_spatial(spatials,
                            _ctx([True, False, False],
                                 weights=[0.9, 0.05, 0.05]))
    assert (out.rows_tsa, out.rows_bsa) == (15, 1)
    # Clamped: both sides always keep at least one row.
    out = WeightedVoteRowPolicy(drift_boost=99).fleet_spatial(
        spatials, _ctx([True] * 3))
    assert (out.rows_tsa, out.rows_bsa) == (15, 1)
    out = WeightedVoteRowPolicy(healthy_relief=99).fleet_spatial(
        spatials, _ctx([False] * 3))
    assert (out.rows_tsa, out.rows_bsa) == (1, 15)


def test_fleet_allocator_emits_fleet_decisions():
    """The FleetAllocator's first-class protocol: N temporal planes + ONE
    fleet spatial plane from its bound row policy, with the legacy lane
    decisions riding along for records."""
    hp = CLHyperParams(n_t=64, n_l=32)
    alloc = FleetAllocator(hp, policy="dacapo-spatiotemporal",
                           mode="drift-weighted", row_policy="drift-surge")
    alloc.bind(DaCapoEstimator(), RESNET18)
    assert "drift-surge" in alloc.name
    fd = alloc.initial_fleet_decision(3)
    assert fd.n_lanes == 3 and len(fd.lane_decisions) == 3
    assert fd.spatial.rows_tsa + fd.spatial.rows_bsa \
        == DaCapoEstimator().total_rows
    for tp, lane in zip(fd.temporal, fd.lane_decisions):
        assert isinstance(tp, TemporalPlan)
        assert tp.retrain_samples == lane.retrain_samples
        assert tp.total_label_samples == lane.total_label_samples
    # per-lane views share the ONE fleet spatial plane.
    views = fd.per_lane()
    assert all(v.spatial is fd.spatial for v in views)
    # A cliff on two of three lanes surges the fleet T-SA next phase.
    healthy = PhaseFeedback(acc_valid=0.8, acc_label=0.82, t=1.0,
                            drifted=False)
    cliff = PhaseFeedback(acc_valid=0.9, acc_label=0.2, t=1.0, drifted=True)
    fd2 = alloc.next_fleet_decision([cliff, cliff, healthy])
    assert fd2.spatial.rows_tsa > fd.spatial.rows_tsa
    # Unbound allocators cannot emit fleet decisions.
    with pytest.raises(RuntimeError):
        FleetAllocator(hp).initial_fleet_decision(2)
    # ONE spatial plane means ONE PrecisionPolicy: a lane policy that
    # diverges from the fleet's precisions is refused loudly instead of
    # being silently charged at lane 0's precisions.
    alloc.policies[1].precision = PrecisionPolicy(inference="mx4")
    with pytest.raises(ValueError, match="heterogeneous"):
        alloc.next_fleet_decision([healthy, healthy, healthy])
