import os

# Smoke tests and benches see the single real CPU device; ONLY the dry-run
# launcher sets xla_force_host_platform_device_count (see launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("JAX_ENABLE_X64", "0")

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "float32")
