"""Trace & replay subsystem tests: off-by-default bit-identity, traced-run
bit-identity in both dispatch modes, bitwise-exact phase replay, JSON
round-trip, the per-role dependency DAG, kernel-path capture, calibration,
the "dacapo-replay" allocation policy, and deterministic merged manager
traces under overlapped (parallel) shard stepping."""
import dataclasses
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.dacapo_pairs import RESNET18, WIDERESNET50
from repro.core.allocation import ALLOCATORS, CLHyperParams, ReplayAllocator
from repro.core.estimator import CalibratedEstimator, DaCapoEstimator
from repro.core.fleet import FleetSpec
from repro.core.manager import ManagerSpec
from repro.core.replay import TraceReplayer
from repro.core.session import CLSystemSpec, pretrain_model
from repro.core.trace import SessionTrace, TraceEvent, TraceRecorder
from repro.data.stream import DriftStream, scenario
from repro.kernels import ops
from repro.models.registry import make_vision_model


@pytest.fixture(scope="module")
def pretrained():
    stream = DriftStream(scenario("S1", 2), seed=5, img=24)
    hp = CLHyperParams(n_t=32, n_l=16, c_b=128, epochs=1)
    rng = np.random.default_rng(0)
    tp = pretrain_model(make_vision_model(WIDERESNET50.reduced()), stream,
                        10, 32, rng)
    sp = pretrain_model(make_vision_model(RESNET18.reduced()), stream, 8,
                        32, rng, segments=stream.segments[:1], seed=8)
    return hp, tp, sp


def _run(pretrained, dispatch, trace, allocator="dacapo-spatiotemporal",
         duration=30.0, eval_fps=0.5):
    hp, tp, sp = pretrained
    stream = DriftStream(scenario("S1", 2), seed=5, img=24)
    spec = CLSystemSpec(student=RESNET18, teacher=WIDERESNET50,
                        allocator=allocator, hp=hp, apply_mx=False, seed=0,
                        eval_fps=eval_fps, dispatch=dispatch, trace=trace)
    session = spec.build()
    session.set_pretrained(tp, sp)
    res = session.run(stream, duration=duration)
    return res, session.dispatcher.recorder


@pytest.fixture(scope="module")
def traced_runs(pretrained):
    """One traced + one untraced run per dispatch mode, shared by the
    identity/replay tests below."""
    runs = {}
    for mode in ("sequential", "concurrent"):
        runs[mode, False] = _run(pretrained, mode, None)
        runs[mode, True] = _run(pretrained, mode, True)
    return runs


# ------------------------------------------------------------- off-switch
def test_trace_off_by_default(traced_runs):
    """trace=None leaves the dispatcher recorder-free: no trace objects,
    no events, nothing on the hot path."""
    for mode in ("sequential", "concurrent"):
        _, recorder = traced_runs[mode, False]
        assert recorder is None


@pytest.mark.parametrize("mode", ["sequential", "concurrent"])
def test_traced_run_bit_identical(traced_runs, mode):
    """Recording is observation-only: accuracy, ledgers and the phase log
    are bitwise identical with tracing on and off."""
    r_off, _ = traced_runs[mode, False]
    r_on, recorder = traced_runs[mode, True]
    assert recorder is not None and len(recorder) > 0
    assert r_off.avg_accuracy == r_on.avg_accuracy
    assert r_off.retrain_time == r_on.retrain_time
    assert r_off.label_time == r_on.label_time
    assert r_off.phase_log == r_on.phase_log


# ----------------------------------------------------------- exact replay
@pytest.mark.parametrize("mode", ["sequential", "concurrent"])
def test_replay_bitwise_exact(traced_runs, mode):
    """predict() with no candidate reconstructs every phase-end clock
    bit-for-bit in both dispatch semantics — including after a JSON
    round trip."""
    _, recorder = traced_runs[mode, True]
    trace = recorder.trace
    rep = TraceReplayer(trace)
    for i, ph in enumerate(trace.phases):
        assert rep.phase_time(i) == ph.end
    rep2 = TraceReplayer(SessionTrace.from_json(trace.to_json()))
    for i, ph in enumerate(trace.phases):
        assert rep2.phase_time(i) == ph.end


def test_replay_from_units_within_mape(traced_runs):
    """Histogram-priced (from_units) predictions stay within 5% MAPE of
    the recorded concurrent phase times."""
    _, recorder = traced_runs["concurrent", True]
    trace = recorder.trace
    rep = TraceReplayer(trace)
    errs = [abs(rep.predict(i, from_units=True) - ph.end) / ph.end
            for i, ph in enumerate(trace.phases) if ph.end > 0]
    assert errs
    assert 100.0 * sum(errs) / len(errs) < 5.0


def test_replay_cross_mode_what_if(traced_runs):
    """Replaying a sequential trace under mode="concurrent" predicts the
    concurrent run's first phase end exactly (virtual costs are
    deterministic, and the two runs share a history of zero phases), and
    never predicts less than the recorded sequential end for any phase:
    concurrent adds the ``start + t_BSA`` arm to the same max, while the
    sequential clock is the T-SA chain alone (seed semantics)."""
    _, rec_seq = traced_runs["sequential", True]
    _, rec_con = traced_runs["concurrent", True]
    rep = TraceReplayer(rec_seq.trace)
    assert rep.predict(0, mode="concurrent") == pytest.approx(
        rec_con.phases[0].end, rel=1e-6)
    for i, ph in enumerate(rec_seq.phases):
        assert rep.predict(i, mode="concurrent") >= ph.end


def test_replay_dag_structure(traced_runs):
    """Sequential: one serial chain. Concurrent: per-role chains joined
    at the phase-end barrier."""
    _, rec_seq = traced_runs["sequential", True]
    rep = TraceReplayer(rec_seq.trace)
    d = rep.dag(0)
    events = rec_seq.phases[0].events
    assert len(d["nodes"]) == len(events)
    for node in d["nodes"][1:]:
        assert node.deps == (node.id - 1,)
    assert d["tails"] == [len(events) - 1]

    _, rec_con = traced_runs["concurrent", True]
    rep = TraceReplayer(rec_con.trace)
    d = rep.dag(0)
    roles = {e.role for e in rec_con.phases[0].events}
    assert len(d["tails"]) == len(roles)
    for node in d["nodes"]:
        for dep in node.deps:
            assert d["nodes"][dep].event.role == node.event.role


# ------------------------------------------------------------ trace model
def test_trace_json_rejects_wrong_format():
    with pytest.raises(ValueError):
        SessionTrace.from_dict({"format": "not-a-trace", "phases": []})


def test_trace_event_round_trip():
    e = TraceEvent(kind="program", role="t_sa", label="valid", cost_s=0.25,
                   lane=3, wall_s=0.01, path="pallas", units=48.0, fan=2)
    assert TraceEvent.from_dict(e.as_dict()) == e


def test_dominant_path_capture():
    """paths_before/dominant_path bracket an issue: the kernel path whose
    counter moved is recorded (eager ref-mode op so the counter moves on
    every call, not only at jit trace time)."""
    rec = TraceRecorder()
    ops.reset_kernel_stats()
    before = rec.paths_before()
    prev = os.environ.get("REPRO_KERNEL_MODE")
    os.environ["REPRO_KERNEL_MODE"] = "ref"
    try:
        x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 32)),
                        jnp.float32)
        ops.mx_quantize(x, "mx6")
    finally:
        if prev is None:
            os.environ.pop("REPRO_KERNEL_MODE", None)
        else:
            os.environ["REPRO_KERNEL_MODE"] = prev
    assert rec.dominant_path(before) == "ref"
    # No movement -> empty path; capture_paths=False -> no snapshots.
    assert rec.dominant_path(rec.paths_before()) == ""
    assert TraceRecorder(capture_paths=False).paths_before() is None


# ------------------------------------------------------------- calibration
def test_calibrate_scales_estimator(traced_runs):
    _, recorder = traced_runs["concurrent", True]
    cal = TraceReplayer(recorder.trace).calibrate()
    assert "retrain" in cal.scales and cal.scales["retrain"] > 0
    assert cal.global_scale > 0
    assert cal.seconds("retrain", 2.0) == 2.0 * cal.scales["retrain"]
    est = cal.estimator(DaCapoEstimator())
    assert isinstance(est, CalibratedEstimator)
    base = DaCapoEstimator()
    cfg = RESNET18.reduced()
    assert est.forward_time(cfg, 8, "mx9") == pytest.approx(
        est.forward_scale * base.forward_time(cfg, 8, "mx9"))
    assert est.train_step_time(cfg, 8, "mx9", 16) == pytest.approx(
        est.train_scale * base.train_step_time(cfg, 8, "mx9", 16))
    assert est.total_rows == base.total_rows


# ----------------------------------------------------- replay-scored policy
def test_replay_allocator_registered():
    assert ALLOCATORS["dacapo-replay"] is ReplayAllocator
    assert ReplayAllocator.needs_trace


def test_replay_allocator_runs_and_charges_profile(pretrained):
    """dacapo-replay auto-enables the recorder, scores candidates by
    replay, and charges the measured replay wall to profile_cost_s."""
    res, recorder = _run(pretrained, "concurrent", None,
                         allocator="dacapo-replay", eval_fps=2.0)
    assert recorder is not None  # needs_trace flipped the default on
    assert len(recorder) > 0
    costs = [ph.decisions[0].get("profile_cost_s")
             for ph in recorder.phases if ph.decisions]
    assert any(c and c > 0 for c in costs[1:])
    assert res.avg_accuracy >= 0.0


# ----------------------------------------- manager merged-trace determinism
def _manager_trace(pretrained, workers):
    hp, tp, sp = pretrained
    fleet = FleetSpec(student=RESNET18, teacher=WIDERESNET50, hp=hp,
                      fleet_mode="drift-weighted", apply_mx=False, seed=0,
                      eval_fps=0.5, dispatch="concurrent")
    mgr = ManagerSpec(fleet=fleet, n_shards=3, placement="static",
                      migration=False, parallel_shards=workers,
                      trace=True).build()
    mgr.set_pretrained(tp, sp)
    streams = [DriftStream(scenario(name, 2), seed=seed, img=24)
               for name, seed in [("S1", 5), ("S3", 6), ("ES1", 7)]]
    result = mgr.run(streams, duration=40.0)
    return result, mgr.trace


def test_manager_parallel_trace_deterministic(pretrained):
    """Under parallel_shards the merged manager trace is drained at the
    round barrier in shard-index order: identical — phase for phase,
    event for event, shard stamp for shard stamp — to serial stepping,
    and the traced parallel run stays bit-identical to the untraced
    serial result."""
    res_serial, tr_serial = _manager_trace(pretrained, workers=0)
    res_par, tr_par = _manager_trace(pretrained, workers=3)
    assert res_par.parallel_rounds > 0
    assert res_serial.fleet_avg_accuracy == res_par.fleet_avg_accuracy
    assert res_serial.ledger == res_par.ledger
    assert len(tr_serial.phases) == len(tr_par.phases) > 0
    for a, b in zip(tr_serial.phases, tr_par.phases):
        assert a.shard == b.shard
        assert a.start == b.start and a.end == b.end
        assert len(a.events) == len(b.events)
        for ea, eb in zip(a.events, b.events):
            # wall_s is measured host time — everything else is virtual
            # and must be bitwise identical across stepping modes.
            assert dataclasses.replace(ea, wall_s=0.0) \
                == dataclasses.replace(eb, wall_s=0.0)
    assert {ph.shard for ph in tr_par.phases} == {0, 1, 2}
