"""End-to-end continuous-learning integration (small budget)."""
import numpy as np
import pytest

from repro.configs.dacapo_pairs import RESNET18, WIDERESNET50
from repro.core.allocation import CLHyperParams
from repro.core.cl_system import ContinuousLearningSystem, pretrain_model
from repro.data.stream import DriftStream, Segment, scenario


@pytest.fixture(scope="module")
def small_setup():
    stream = DriftStream(scenario("S1", 4), seed=0, img=24)
    hp = CLHyperParams(n_t=64, n_l=32, c_b=256, epochs=1)
    sys_ = ContinuousLearningSystem(
        RESNET18, WIDERESNET50, hp=hp, apply_mx_numerics=False,
        eval_fps=0.5)
    rng = np.random.default_rng(0)
    t_params = pretrain_model(sys_.teacher, stream, steps=40, batch=32,
                              rng=rng)
    s_params = pretrain_model(sys_.student, stream, steps=25, batch=32,
                              rng=rng, segments=stream.segments[:1], seed=8)
    return stream, hp, t_params, s_params


def _make(stream, hp, t_params, s_params, allocator):
    sys_ = ContinuousLearningSystem(
        RESNET18, WIDERESNET50, hp=hp, allocator=allocator,
        apply_mx_numerics=False, eval_fps=0.5)
    sys_.set_pretrained(t_params, s_params)
    return sys_


def test_cl_system_runs_and_improves(small_setup):
    stream, hp, tp, sp = small_setup
    sys_ = _make(stream, hp, tp, sp, "dacapo-spatiotemporal")
    res = sys_.run(stream, duration=120.0)
    assert res.avg_accuracy > 0.3  # far above random (1/8)
    assert len(res.phase_log) >= 2
    assert res.retrain_time > 0 and res.label_time > 0
    # timeline is monotone in t
    ts = [t for t, _ in res.accuracy_timeline]
    assert ts == sorted(ts)


def test_spatial_allocation_sized_for_fps(small_setup):
    stream, hp, tp, sp = small_setup
    sys_ = _make(stream, hp, tp, sp, "dacapo-spatial")
    assert 1 <= sys_.r_bsa < sys_.estimator.total_rows
    assert sys_.r_tsa + sys_.r_bsa == sys_.estimator.total_rows


def test_drift_detection_fires_on_hard_drift(small_setup):
    stream, hp, tp, sp = small_setup
    sys_ = _make(stream, hp, tp, sp, "dacapo-spatiotemporal")
    res = sys_.run(stream, duration=150.0)
    # S1 flips label distribution every 60 s; at least one drift should fire.
    assert res.drift_events >= 1


def test_spatiotemporal_labels_more_than_spatial_on_drift(small_setup):
    stream, hp, tp, sp = small_setup
    st_res = _make(stream, hp, tp, sp, "dacapo-spatiotemporal").run(
        stream, duration=150.0)
    s_res = _make(stream, hp, tp, sp, "dacapo-spatial").run(
        stream, duration=150.0)
    if st_res.drift_events:
        # drift -> boosted labeling (N_ldd) shifts the time breakdown
        st_frac = st_res.label_time / max(
            st_res.label_time + st_res.retrain_time, 1e-9)
        s_frac = s_res.label_time / max(
            s_res.label_time + s_res.retrain_time, 1e-9)
        assert st_frac >= s_frac - 0.05


def test_all_schedulers_run(small_setup):
    stream, hp, tp, sp = small_setup
    for name in ("ekya", "eomu"):
        res = _make(stream, hp, tp, sp, name).run(stream, duration=90.0)
        assert res.avg_accuracy > 0.15, name


def test_mx_numerics_path(small_setup):
    """MX6 serving quantization runs end-to-end (short)."""
    stream, hp, tp, sp = small_setup
    sys_ = ContinuousLearningSystem(
        RESNET18, WIDERESNET50, hp=hp, apply_mx_numerics=True, eval_fps=0.5)
    sys_.set_pretrained(tp, sp)
    res = sys_.run(stream, duration=45.0)
    assert res.avg_accuracy > 0.15
