"""Kernel/Session API tests: golden equivalence vs. the seed monolith,
per-kernel unit tests, the AllocationPolicy contract over all four
allocators, observer delivery, and engine-driven mesh partitioning.

The GOLDEN constants below were captured by running the pre-refactor
``ContinuousLearningSystem.run()`` (the ~110-line monolithic loop) on this
exact fixture before the decomposition; the compat wrapper and the new
``CLSession`` must reproduce them to 1e-6.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.dacapo_pairs import RESNET18, WIDERESNET50
from repro.core.allocation import (
    ALLOCATORS,
    AllocationDecision,
    CLHyperParams,
    PhaseFeedback,
)
from repro.core.cl_system import ContinuousLearningSystem
from repro.core.estimator import DaCapoEstimator
from repro.core.kernel import (
    InferenceKernel,
    Kernel,
    LabelingKernel,
    RetrainKernel,
    ServingParamsCache,
)
from repro.core.session import CLSession, CLSystemSpec, pretrain_model
from repro.data.stream import DriftStream, scenario
from repro.models.registry import make_vision_model

# Seed-capture: scenario("S1", 3) seed=5 img=24; hp(48, 24, c_b=192);
# pretrain rng(0), teacher 25x32, student 15x32 on segments[:1] seed=8;
# duration 90 s; apply_mx False; eval_fps 0.5.
GOLDEN = {
    "dacapo-spatiotemporal": dict(
        avg_accuracy=0.32608695652173914, phases=23, drifts=9,
        retrain_time=54.54179220000003, label_time=36.060292799999985),
    "ekya": dict(avg_accuracy=0.6704545454545454, phases=1, drifts=0),
    "eomu": dict(avg_accuracy=0.42857142857142855, phases=9, drifts=0),
}
GOLDEN_MX_ST_45S = 0.4166666666666667


@pytest.fixture(scope="module")
def golden_setup():
    stream = DriftStream(scenario("S1", 3), seed=5, img=24)
    hp = CLHyperParams(n_t=48, n_l=24, c_b=192, epochs=1)
    rng = np.random.default_rng(0)
    teacher_model = make_vision_model(WIDERESNET50.reduced())
    student_model = make_vision_model(RESNET18.reduced())
    tp = pretrain_model(teacher_model, stream, 25, 32, rng)
    sp = pretrain_model(student_model, stream, 15, 32, rng,
                        segments=stream.segments[:1], seed=8)
    return stream, hp, tp, sp


def _build(hp, allocator, apply_mx=False, mesh=None) -> CLSession:
    return CLSystemSpec(
        student=RESNET18, teacher=WIDERESNET50, allocator=allocator,
        hp=hp, apply_mx=apply_mx, seed=0, eval_fps=0.5, mesh=mesh).build()


# ------------------------------------------------------------------ golden
@pytest.mark.parametrize("allocator", sorted(GOLDEN))
def test_golden_equivalence_via_spec(golden_setup, allocator):
    """CLSession reproduces the seed monolith bit-for-bit (1e-6)."""
    stream, hp, tp, sp = golden_setup
    session = _build(hp, allocator)
    session.set_pretrained(tp, sp)
    res = session.run(stream, duration=90.0)
    gold = GOLDEN[allocator]
    assert abs(res.avg_accuracy - gold["avg_accuracy"]) < 1e-6
    assert len(res.phase_log) == gold["phases"]
    assert res.drift_events == gold["drifts"]
    if "retrain_time" in gold:
        assert abs(res.retrain_time - gold["retrain_time"]) < 1e-6
        assert abs(res.label_time - gold["label_time"]) < 1e-6


def test_golden_equivalence_compat_wrapper(golden_setup):
    """The legacy ContinuousLearningSystem facade hits the same goldens."""
    stream, hp, tp, sp = golden_setup
    sys_ = ContinuousLearningSystem(
        RESNET18, WIDERESNET50, hp=hp, allocator="dacapo-spatiotemporal",
        apply_mx_numerics=False, seed=0, eval_fps=0.5)
    sys_.set_pretrained(tp, sp)
    res = sys_.run(stream, duration=90.0)
    gold = GOLDEN["dacapo-spatiotemporal"]
    assert abs(res.avg_accuracy - gold["avg_accuracy"]) < 1e-6
    assert res.drift_events == gold["drifts"]
    # Legacy attribute surface still reachable through the facade.
    assert sys_.r_tsa + sys_.r_bsa == sys_.estimator.total_rows
    assert sys_.scheduler.name == "dacapo-spatiotemporal"


def test_golden_equivalence_mx_numerics(golden_setup):
    """The MX6-serving quantization path also matches the seed capture."""
    stream, hp, tp, sp = golden_setup
    session = _build(hp, "dacapo-spatiotemporal", apply_mx=True)
    session.set_pretrained(tp, sp)
    res = session.run(stream, duration=45.0)
    assert abs(res.avg_accuracy - GOLDEN_MX_ST_45S) < 1e-6


# ----------------------------------------------------------------- kernels
@pytest.fixture(scope="module")
def kernel_setup():
    est = DaCapoEstimator()
    hp = CLHyperParams(n_t=32, n_l=16, sgd_batch=8, epochs=1)
    model = make_vision_model(RESNET18.reduced())
    params = model.init(jax.random.PRNGKey(0))
    x = np.asarray(
        jax.random.normal(jax.random.PRNGKey(1), (12, 24, 24, 3)),
        np.float32)
    return est, hp, model, params, x


def test_inference_kernel(kernel_setup):
    est, hp, model, params, x = kernel_setup
    k = InferenceKernel(model, RESNET18, est, apply_mx=False)
    assert isinstance(k, Kernel) and k.role == "b_sa"
    pred = k.predict(params, x)
    assert pred.shape == (12,)
    assert np.all((0 <= pred) & (pred < RESNET18.reduced().num_classes))
    # Cost comes straight from the estimator; fewer rows -> slower.
    assert k.time_per_sample(4, "mx6") == est.forward_time(
        RESNET18, 4, "mx6", batch=1)
    assert k.time_per_sample(2, "mx6") > k.time_per_sample(8, "mx6")
    assert 0.0 < k.keep_frac(1, "mx6", target_fps=30.0) <= 1.0
    assert k.keep_frac(est.total_rows, "mx4", target_fps=1e-6) == 1.0
    # No MX -> serving params pass through untouched.
    assert k.serving_params(params, "mx6") is params
    # MX -> same tree structure, weights fake-quantized.
    kq = InferenceKernel(model, RESNET18, est, apply_mx=True)
    q = kq.serving_params(params, "mx6")
    assert (jax.tree_util.tree_structure(q)
            == jax.tree_util.tree_structure(params))


def test_labeling_kernel(kernel_setup):
    est, hp, model, params, x = kernel_setup
    k = LabelingKernel(model, WIDERESNET50, est, apply_mx=False)
    assert isinstance(k, Kernel) and k.role == "t_sa"
    y = k.label(params, x, "mx6")
    assert y.shape == (12,) and y.dtype.kind == "i"
    # Labeling cost uses the teacher's (bigger) GEMM list.
    k_small = LabelingKernel(model, RESNET18, est, apply_mx=False)
    assert k.time_per_sample(8, "mx6") > k_small.time_per_sample(8, "mx6")


def test_retrain_kernel(kernel_setup):
    est, hp, model, params, x = kernel_setup
    k = RetrainKernel(model, RESNET18, est, hp)
    assert isinstance(k, Kernel) and k.role == "t_sa"
    opt = k.init_state(params)
    y = np.zeros((12,), np.int32)
    rng = np.random.default_rng(0)
    new_params, new_opt, n_batches = k.fit(params, opt, x, y, rng)
    # Charged batches == executed batches (see test_dispatch for the
    # sub-batch D_t case, which executes — and charges — zero steps).
    assert n_batches == (len(x) // hp.sgd_batch) * hp.epochs
    # Parameters actually moved and stayed finite.
    leaves_before = jax.tree_util.tree_leaves(params)
    leaves_after = jax.tree_util.tree_leaves(new_params)
    assert any(not np.allclose(a, b)
               for a, b in zip(leaves_before, leaves_after))
    assert all(np.all(np.isfinite(np.asarray(leaf)))
               for leaf in leaves_after)
    # Training costs 3x a forward per sample (fwd + dX + dW GEMMs).
    assert k.time_per_batch(8, "mx9") == pytest.approx(
        3.0 * est.forward_time(RESNET18, 8, "mx9", hp.sgd_batch))


# --------------------------------------------- serving-copy cache (PR 7) --
def test_serving_cache_hits_and_misses(kernel_setup):
    est, hp, model, params, x = kernel_setup
    k = InferenceKernel(model, RESNET18, est, apply_mx=True)
    q1 = k.serving_params(params, "mx6")
    assert k.serving_cache.stats() == {"hits": 0, "misses": 1, "entries": 1}
    # Same tree, same precision -> hit, SAME quantized object.
    q2 = k.serving_params(params, "mx6")
    assert q2 is q1
    assert k.serving_cache.stats() == {"hits": 1, "misses": 1, "entries": 1}
    # Same tree, other precision -> miss, shares the entry.
    k.serving_params(params, "mx9")
    assert k.serving_cache.stats() == {"hits": 1, "misses": 2, "entries": 1}
    # A fresh tree (what fit returns) -> miss under a new entry.
    params2 = jax.tree_util.tree_map(lambda p: p + 0, params)
    k.serving_params(params2, "mx6")
    assert k.serving_cache.stats() == {"hits": 1, "misses": 3, "entries": 2}
    # apply_mx=False bypasses the cache entirely.
    k_raw = InferenceKernel(model, RESNET18, est, apply_mx=False)
    assert k_raw.serving_params(params, "mx6") is params
    assert k_raw.serving_cache.stats()["misses"] == 0


def test_serving_cache_maxsize_zero_disables(kernel_setup):
    from repro.core.kernel import ServingParamsCache

    est, hp, model, params, x = kernel_setup
    cache = ServingParamsCache(maxsize=0)
    q1 = cache.get(params, "mx6")
    q2 = cache.get(params, "mx6")
    assert q1 is not q2  # re-quantized every call
    assert cache.stats() == {"hits": 0, "misses": 2, "entries": 0}
    # LRU eviction at maxsize=1: the older tree's entry is dropped.
    small = ServingParamsCache(maxsize=1)
    params2 = jax.tree_util.tree_map(lambda p: p + 0, params)
    small.get(params, "mx6")
    small.get(params2, "mx6")
    assert len(small) == 1
    small.get(params, "mx6")
    assert small.stats()["misses"] == 3  # evicted -> re-quantize


def test_serving_cache_concurrent_gets_count_exactly(kernel_setup):
    """Under overlapped shard stepping the cache is shared process-global
    state: 8 threads hammering the same (tree, precision) must lose no
    counter increments, and — because each slot carries its own fill
    guard — quantize the tree exactly once, even though the cache-wide
    lock is no longer held across the fill."""
    import threading

    est, hp, model, params, x = kernel_setup
    cache = ServingParamsCache(maxsize=8)
    n_threads, per_thread = 8, 50
    start = threading.Barrier(n_threads)
    fills = []

    def fake_quantize(tree, precision):
        fills.append(precision)
        return {"q": precision}

    def worker():
        start.wait()
        for _ in range(per_thread):
            cache.get(params, "mx9", quantize=fake_quantize)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stats = cache.stats()
    assert stats["hits"] + stats["misses"] == n_threads * per_thread
    assert stats["misses"] == 1 and len(fills) == 1
    assert stats == {"hits": 399, "misses": 1, "entries": 1}


def test_serving_cache_fill_not_under_cache_lock(kernel_setup):
    """PR 9 regression: a slow fill of one tree must NOT serialize lookups
    of a different tree. The old cache quantized under its RLock, so lane
    B's first serving request waited on lane A's whole-tree quantization;
    now only the per-slot guard is held across the fill."""
    import threading

    est, hp, model, params, x = kernel_setup
    params_b = jax.tree_util.tree_map(lambda p: p + 0, params)
    cache = ServingParamsCache(maxsize=8)
    entered = threading.Event()
    release = threading.Event()
    order = []

    def slow_quantize(tree, precision):
        entered.set()
        release.wait(timeout=10.0)
        order.append("a")
        return {"tree": "a"}

    def fast_quantize(tree, precision):
        order.append("b")
        return {"tree": "b"}

    t = threading.Thread(
        target=lambda: cache.get(params, "mx6", quantize=slow_quantize))
    t.start()
    assert entered.wait(timeout=10.0)
    # Lane A's fill is in flight. Under the old lock-across-fill design
    # this get would deadlock until the release below; now it completes
    # immediately on its own slot.
    got_b = cache.get(params_b, "mx6", quantize=fast_quantize)
    assert got_b == {"tree": "b"}
    assert order == ["b"]
    release.set()
    t.join(timeout=10.0)
    assert not t.is_alive()
    assert order == ["b", "a"]
    assert cache.stats() == {"hits": 0, "misses": 2, "entries": 2}
    assert cache.fills == 2
    # Both slots memoized: repeat gets are hits on the same objects.
    assert cache.get(params, "mx6", quantize=slow_quantize) == {"tree": "a"}
    assert cache.stats()["hits"] == 1


def test_serving_cache_resident_quantized_storage(kernel_setup):
    """The default fill stores the RESIDENT quantized rep (MXLeaf weight
    leaves); `get` lazily dequantizes — once — to a tree bit-identical to
    the legacy ``quantize_tree`` output, and ``get_quantized`` hands the
    resident copy out without ever dequantizing."""
    from repro.core import mx as mx_lib

    est, hp, model, params, x = kernel_setup
    cache = ServingParamsCache(maxsize=8)
    value = cache.get(params, "mx6")
    legacy = mx_lib.quantize_tree(params, "mx6")
    for v, l in zip(jax.tree_util.tree_leaves(value),
                    jax.tree_util.tree_leaves(legacy)):
        np.testing.assert_array_equal(np.asarray(v), np.asarray(l))
    # The resident copy shares the slot: no second whole-tree quantize.
    resident = cache.get_quantized(params, "mx6")
    assert any(isinstance(leaf, mx_lib.MXLeaf)
               for leaf in jax.tree_util.tree_leaves(
                   resident, is_leaf=lambda p: isinstance(p, mx_lib.MXLeaf)))
    assert cache.fills == 1
    assert cache.stats() == {"hits": 1, "misses": 1, "entries": 1}
    # Repeat gets return the SAME memoized dequantized tree.
    assert cache.get(params, "mx6") is value
    assert cache.fills == 1


def test_inference_serving_prequant_matches_fake_quant(kernel_setup):
    """Prequant serving == fake-quant serving bit-for-bit: predictions off
    the cache's lazily-dequantized resident copy equal predictions off a
    fresh ``quantize_tree`` tree, and the resident copy's head weight
    round-trips to exactly the served fake-quant head."""
    from repro.core import mx as mx_lib
    from repro.kernels import ops

    est, hp, model, params, x = kernel_setup
    k = InferenceKernel(model, RESNET18, est, apply_mx=True)
    serving = k.serving_params(params, "mx6")
    legacy = mx_lib.quantize_tree(params, "mx6")
    np.testing.assert_array_equal(k.predict(serving, x),
                                  k.predict(legacy, x))
    resident = k.serving_quantized(params, "mx6")
    back = mx_lib.dequantize_tree_mx(resident)
    for b, l in zip(jax.tree_util.tree_leaves(back),
                    jax.tree_util.tree_leaves(legacy)):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(l))
    # One fill total: serving_params and serving_quantized share the slot.
    assert k.serving_cache.fills == 1


def test_labeling_cache_repeated_bursts_hit(kernel_setup):
    est, hp, model, params, x = kernel_setup
    k = LabelingKernel(model, WIDERESNET50, est, apply_mx=True)
    y1 = k.label(params, x, "mx6")
    y2 = k.label(params, x, "mx6")
    np.testing.assert_array_equal(y1, y2)
    # One quantize for N bursts: the teacher tree never changes.
    assert k.serving_cache.stats() == {"hits": 1, "misses": 1, "entries": 1}


def test_retrain_fit_invalidates_serving_caches(kernel_setup):
    est, hp, model, params, x = kernel_setup
    inf = InferenceKernel(model, RESNET18, est, apply_mx=True)
    ret = RetrainKernel(model, RESNET18, est, hp)
    ret.invalidates = (inf.serving_cache,)
    inf.serving_params(params, "mx6")
    assert len(inf.serving_cache) == 1
    y = np.zeros((12,), np.int32)
    new_params, _, _ = ret.fit(params, ret.init_state(params), x, y,
                               np.random.default_rng(0))
    # The superseded tree's entry is reclaimed; the new tree misses fresh.
    assert len(inf.serving_cache) == 0
    inf.serving_params(new_params, "mx6")
    assert inf.serving_cache.stats()["misses"] == 2
    assert inf.serving_cache.stats()["hits"] == 0


def test_session_wires_retrain_invalidation(golden_setup):
    stream, hp, tp, sp = golden_setup
    session = _build(hp, "dacapo-spatiotemporal", apply_mx=True)
    assert session.inference.serving_cache in session.retrain.invalidates


# ------------------------------------------------------- policy contract --
@pytest.mark.parametrize("name", sorted(ALLOCATORS))
def test_allocation_policy_contract(name):
    """Every allocator: binds, emits complete decisions, stays in bounds."""
    hp = CLHyperParams(n_t=64, n_l=32, v_thr=-0.05)
    est = DaCapoEstimator()
    pol = ALLOCATORS[name](hp).bind(est, RESNET18)
    assert pol.name == name
    decisions = [pol.initial_decision()]
    # A healthy stretch, a drift-y cliff, then recovery.
    feedback = [(0.8, 0.82), (0.8, 0.81), (0.9, 0.3), (0.5, 0.55),
                (0.6, 0.62)]
    for i, (av, al) in enumerate(feedback):
        decisions.append(pol.next_decision(
            PhaseFeedback(acc_valid=av, acc_label=al, t=float(i))))
    for d in decisions:
        assert isinstance(d, AllocationDecision)
        # Spatial rows: bound policies always carry a full split.
        assert d.rows_tsa is not None and d.rows_bsa is not None
        assert d.rows_tsa + d.rows_bsa == est.total_rows
        # Temporal budgets within Table I bounds.
        assert 0 <= d.retrain_samples <= hp.n_t
        assert d.valid_samples == hp.n_v
        assert hp.n_l <= d.total_label_samples <= hp.n_ldd
        # Per-kernel precisions travel on the decision.
        assert d.precisions.inference == "mx6"
        assert d.precisions.retraining == "mx9"
    resets = [d.reset_buffer for d in decisions]
    # dacapo-replay is DC-ST plus replay-scored boosts, so it shares the
    # drift-reactive contract: the cliff must flush the merged buffer.
    if name.startswith("dacapo-spatiotemporal") or name == "dacapo-replay":
        assert any(resets)  # the cliff at (0.9, 0.3) must fire
    else:
        assert not any(resets)


def test_all_allocators_run_through_session(golden_setup):
    """Acceptance: all four allocators execute via CLSystemSpec/CLSession."""
    stream, hp, tp, sp = golden_setup
    for name in sorted(ALLOCATORS):
        session = _build(hp, name)
        assert isinstance(session, CLSession)
        session.set_pretrained(tp, sp)
        res = session.run(stream, duration=30.0)
        assert res.name == name
        assert res.avg_accuracy > 0.0
        ts = [t for t, _ in res.accuracy_timeline]
        assert ts == sorted(ts)


# -------------------------------------------------------------- observers --
def test_observers_receive_structured_records(golden_setup):
    stream, hp, tp, sp = golden_setup
    session = _build(hp, "dacapo-spatiotemporal")
    session.set_pretrained(tp, sp)
    seen = []
    session.add_observer(seen.append)
    extra = []
    res = session.run(stream, duration=30.0, observers=(extra.append,))
    assert len(seen) == len(res.phase_log) == len(extra) == len(res.records)
    for i, rec in enumerate(seen):
        assert rec.index == i
        assert isinstance(rec.decision, AllocationDecision)
        assert rec.as_log_entry() == res.phase_log[i]
        assert 0.0 <= rec.acc_label <= 1.0


# ------------------------------------------------------------ mesh wiring --
def test_engine_partitions_fake_mesh(golden_setup):
    """partition_mesh is invoked by the engine: a fake 2-row mesh is
    fissioned into T-SA/B-SA sub-meshes and each kernel is bound to its
    sub-accelerator; the run still reproduces sane results."""
    from jax.sharding import Mesh

    stream, hp, tp, sp = golden_setup
    devs = np.array(jax.devices() * 2).reshape(2, 1)  # fake 2-row mesh
    mesh = Mesh(devs, ("data", "model"))
    session = _build(hp, "dacapo-spatiotemporal", mesh=mesh)
    assert not session.partition.time_shared
    assert session.partition.t_sa.devices.shape == (1, 1)
    assert session.partition.b_sa.devices.shape == (1, 1)
    # Kernel placement follows the roles.
    assert session.inference.submesh is session.partition.b_sa
    assert session.labeling.submesh is session.partition.t_sa
    assert session.retrain.submesh is session.partition.t_sa
    session.set_pretrained(tp, sp)
    res = session.run(stream, duration=30.0)
    assert res.avg_accuracy > 0.0
    # Single-device sessions degenerate to time-sharing (no sub-meshes).
    flat = _build(hp, "dacapo-spatiotemporal")
    assert flat.partition.time_shared
    assert flat.inference.submesh is None


def test_spec_is_declarative_and_replaceable(golden_setup):
    """Benchmark-style partial specs are completed via dataclasses.replace."""
    stream, hp, tp, sp = golden_setup
    partial = CLSystemSpec(allocator="eomu", apply_mx=False)
    with pytest.raises(ValueError):
        partial.build()
    spec = dataclasses.replace(partial, student=RESNET18,
                               teacher=WIDERESNET50, hp=hp, eval_fps=0.5)
    session = spec.build()
    assert session.allocator.name == "eomu"
    assert session.allocator.pace_window_s == 10.0
