"""Logical-axis sharding utilities (MaxText-style logical→mesh rules).

Model code annotates arrays with *logical* axis names; a ``ShardingRules``
mapping (installed via ``use_rules``) translates them to mesh axes. Outside a
mesh context everything is a no-op, so the same model code runs on 1 CPU
device (smoke tests) and on a 512-chip multi-pod mesh (dry-run / production).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


class ShardingRules(dict):
    """Maps logical axis name -> mesh axis (or tuple of axes, or None)."""

    def spec_for(self, logical_axes: Sequence[Optional[str]]) -> P:
        out = []
        used: set = set()
        for name in logical_axes:
            axes = self.get(name) if name is not None else None
            # Drop mesh axes already consumed by an earlier dim (JAX forbids
            # reusing a mesh axis across dims of one array).
            if isinstance(axes, (tuple, list)):
                axes = tuple(a for a in axes if a not in used)
                used.update(axes)
                axes = axes if axes else None
                if isinstance(axes, tuple) and len(axes) == 1:
                    axes = axes[0]
            elif isinstance(axes, str):
                if axes in used:
                    axes = None
                else:
                    used.add(axes)
            out.append(axes)
        return P(*out)


_STATE = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_STATE, "rules", None)


def current_mesh() -> Optional[Mesh]:
    mesh = getattr(_STATE, "mesh", None)
    if mesh is not None:
        return mesh
    # Fall back to the ambient `with mesh:` context if one is active.
    env = jax.sharding.get_abstract_mesh() if hasattr(jax.sharding, "get_abstract_mesh") else None
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules], mesh: Optional[Mesh] = None):
    prev_rules = getattr(_STATE, "rules", None)
    prev_mesh = getattr(_STATE, "mesh", None)
    _STATE.rules, _STATE.mesh = rules, mesh
    try:
        yield
    finally:
        _STATE.rules, _STATE.mesh = prev_rules, prev_mesh


def logical_spec(*logical_axes: Optional[str]) -> P:
    rules = current_rules()
    if rules is None:
        return P()
    return rules.spec_for(logical_axes)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without rules/mesh."""
    rules = current_rules()
    mesh = getattr(_STATE, "mesh", None)
    if rules is None or mesh is None:
        return x
    if x.ndim != len(logical_axes):
        raise ValueError(f"rank {x.ndim} vs logical axes {logical_axes}")
    spec = rules.spec_for(logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, *logical_axes: Optional[str]) -> NamedSharding:
    rules = current_rules()
    spec = rules.spec_for(logical_axes) if rules else P()
    return NamedSharding(mesh, spec)


def mesh_axis_size(mesh: Optional[Mesh], axes: MeshAxes) -> int:
    if mesh is None or axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


# ---------------------------------------------------------------------------
# Param spec system: declarative parameter trees that can be initialized,
# shape-evaluated (dry-run) and sharded without duplication.
# ---------------------------------------------------------------------------
class ParamDef:
    """Declares one parameter: shape, logical axes, initializer."""

    __slots__ = ("shape", "logical", "init", "dtype", "scale")

    def __init__(self, shape, logical, init="normal", dtype=jnp.float32, scale=None):
        assert len(shape) == len(logical), (shape, logical)
        self.shape = tuple(int(s) for s in shape)
        self.logical = tuple(logical)
        self.init = init
        self.dtype = dtype
        self.scale = scale

    def initialize(self, key) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "const":
            return jnp.full(self.shape, self.scale, self.dtype)
        fan_in = self.shape[0] if len(self.shape) > 1 else max(self.shape[0], 1)
        scale = self.scale if self.scale is not None else fan_in ** -0.5
        return (jax.random.normal(key, self.shape, jnp.float32) * scale).astype(self.dtype)

    def shape_struct(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def is_param_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(defs, key):
    """Materialize a ParamDef tree into arrays (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=is_param_def)
    keys = jax.random.split(key, len(leaves))
    arrs = [d.initialize(k) for d, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def param_shapes(defs):
    return jax.tree_util.tree_map(
        lambda d: d.shape_struct(), defs, is_leaf=is_param_def)


def param_specs(defs) -> object:
    """PartitionSpec tree for a ParamDef tree under the current rules."""
    rules = current_rules() or ShardingRules()
    return jax.tree_util.tree_map(
        lambda d: rules.spec_for(d.logical), defs, is_leaf=is_param_def)


def param_shardings(defs, mesh: Mesh):
    specs = param_specs(defs)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def stack_defs(defs_list):
    """Stack N same-structure ParamDef trees along a new leading 'layers' axis."""
    n = len(defs_list)

    def _stack(*ds: ParamDef) -> ParamDef:
        d0 = ds[0]
        return ParamDef((n,) + d0.shape, ("layers",) + d0.logical,
                        d0.init, d0.dtype, d0.scale)

    return jax.tree_util.tree_map(_stack, *defs_list, is_leaf=is_param_def)
