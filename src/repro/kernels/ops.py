"""Jit'd public wrappers for the Pallas kernels.

On TPU backends the kernels lower natively; everywhere else (this CPU
container, the dry-run host platform) they execute in ``interpret=True`` mode
or use the pure-jnp oracle — selected automatically, overridable via
``REPRO_KERNEL_MODE`` in {"pallas", "interpret", "ref"}.

Two hot-path properties this layer guarantees (PR 7):

* **No silent fallbacks.** Odd shapes used to drop quietly onto the ref
  oracle (``m % 8 or n % 128 or k % 128``); now every Pallas entry pads
  M/N/K up to its tile alignment with zeros and slices the result back —
  exact, because all-zero 16-blocks quantize to zero mantissas and add
  nothing to the dot product. ``kernel_stats()`` records which path served
  every call so benches/tests can assert the dispatch.
* **A fused entry.** ``mx_matmul_fused`` runs the whole quantize→matmul
  chain as ONE program — the fused Pallas kernel (mx_fused.py: MX data
  never leaves VMEM) on pallas/interpret, the single-jit fused oracle
  (ref.mx_matmul_fused_ref) on ref — bit-identical to
  ``mx_quantize``→``mx_matmul`` in every mode.

Two more land in PR 9, closing the remaining hot-path round trips:

* **The backward pair.** ``mx_matmul_bwd_pair`` emits BOTH gradients of
  ``y = x @ w`` — ``dX = q(g) @ q(W^T)`` and ``dW = q(X^T) @ q(g)`` — as
  ONE program (one Pallas launch / one jit), the cotangent resident in
  VMEM across both consumers, bit-identical to the two independent fused
  GEMMs in every mode. ``core/mx.py::_mx_dense_bwd`` routes through it.
* **Weight-resident serving.** ``mx_quantize_rhs`` stores a weight in the
  rhs layout the matmul kernels consume (quantized along the contraction
  axis); ``mx_matmul_prequant`` multiplies against that resident copy with
  ZERO weight-quantization work per call — bit-identical to
  ``mx_matmul_fused`` on the original weight, because MX quantization is
  idempotent. ``ServingParamsCache`` (core/kernel.py) keeps the resident
  copies across serving windows and labeling bursts.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import mx_fused as _mf
from repro.kernels import mx_matmul as _mm
from repro.kernels import mx_quantize as _mq
from repro.kernels import ref as _ref
from repro.kernels.ref import BLOCK, EXP_MIN, MANTISSA_BITS, MXTensor

# Pallas tile alignments: fp32 rows to the 8-sublane tile, matmul N/K to
# the 128-lane tile.
ROW_ALIGN = 8
LANE_ALIGN = 128

# The dispatch counters are process-global state shared by every session
# — under overlapped shard stepping (FleetManager(parallel_shards=N))
# kernels on different worker threads count into them concurrently, so
# every read-modify-write below holds this lock: increments are never
# lost and kernel_stats() snapshots are consistent.
_stats_lock = threading.Lock()
_kernel_stats: Dict[str, Dict[str, int]] = {}


def _count(op: str, path: str) -> None:
    with _stats_lock:
        by_path = _kernel_stats.setdefault(op, {})
        by_path[path] = by_path.get(path, 0) + 1


def kernel_stats() -> Dict[str, Dict[str, int]]:
    """Per-op dispatch counters since the last reset: ``{op: {path: n}}``
    where ``path`` is the mode that actually served the call ("pallas",
    "interpret", "ref"). Lets benches/tests assert which path ran."""
    with _stats_lock:
        return {op: dict(paths) for op, paths in _kernel_stats.items()}


def reset_kernel_stats() -> None:
    with _stats_lock:
        _kernel_stats.clear()


def kernel_mode() -> str:
    mode = os.environ.get("REPRO_KERNEL_MODE", "auto")
    if mode != "auto":
        return mode
    return "pallas" if jax.default_backend() == "tpu" else "interpret"


def _pad_last(x, multiple):
    k = x.shape[-1]
    pad = (-k) % multiple
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, pad


def _pad_dim(x, axis, multiple):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    return x


def _row_tile(m: int) -> int:
    """Largest ≤128 row tile dividing ``m`` (``m % 8 == 0`` after padding).
    Keeps the historical tile for shapes the kernels already served, so
    their accumulation pattern — and bit pattern — is unchanged."""
    t = min(128, m)
    return t if m % t == 0 else ROW_ALIGN


def _k_tile(k: int) -> int:
    """Contraction tile for the matmul grids (``k % 128 == 0`` after
    padding): the historical min(512, k) when it divides, else the largest
    power-of-two tile that does."""
    t = min(_mm.DEFAULT_BK, k)
    if k % t == 0:
        return t
    return 256 if k % 256 == 0 else LANE_ALIGN


def _quant_k_tile(k: int) -> int:
    """Contraction tile for the quantize grid (K padded to 16 only)."""
    if k <= _mq.DEFAULT_BK:
        return k
    for t in (512, 256, 128, 64, 32, 16):
        if k % t == 0:
            return t
    return BLOCK


def mx_quantize(x: jax.Array, precision: str) -> MXTensor:
    """Quantize along the last axis (auto-padded to a multiple of 16).

    The Pallas path pads the flattened row count up to the 8-row sublane
    alignment and slices the result back — odd batch sizes no longer fall
    back silently to the ref oracle."""
    mode = kernel_mode()
    orig_shape = x.shape
    x2 = x.reshape(-1, orig_shape[-1])
    x2, _ = _pad_last(x2, BLOCK)
    if mode == "ref":
        _count("mx_quantize", "ref")
        return _ref.mx_quantize_ref(x2, precision)
    rows = x2.shape[0]
    x2p = _pad_dim(x2, 0, ROW_ALIGN)
    q = _mq.mx_quantize(x2p, precision, bm=_row_tile(x2p.shape[0]),
                        bk=_quant_k_tile(x2p.shape[1]),
                        interpret=(mode == "interpret"))
    _count("mx_quantize", mode)
    if x2p.shape[0] != rows:
        q = MXTensor(q.mantissa[:rows], q.exponent[:rows],
                     q.mx_bits[:rows], q.precision)
    return q


def mx_dequantize(q: MXTensor) -> jax.Array:
    return _ref.mx_dequantize_ref(q)


def mx_quant_dequant(x: jax.Array, precision: str) -> jax.Array:
    """Fake-quant round trip (used by the MX training autodiff wrapper)."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    x2p, pad = _pad_last(x2, BLOCK)
    y = mx_dequantize(mx_quantize(x2p, precision))
    if pad:
        y = y[:, : shape[-1]]
    return y.reshape(shape).astype(x.dtype)


def _pad_matmul_operands(a: jax.Array, b: jax.Array):
    """Zero-pad a [M, K] / b [K, N] to the Pallas matmul tile alignments.
    Exact: zero rows/columns only produce output entries that are sliced
    off, and all-zero K-blocks quantize to zero mantissas, contributing
    nothing to the kept dot products."""
    a = _pad_dim(_pad_dim(a, 0, ROW_ALIGN), 1, LANE_ALIGN)
    b = _pad_dim(_pad_dim(b, 0, LANE_ALIGN), 1, LANE_ALIGN)
    return a, b


def mx_matmul(a: jax.Array, b: jax.Array, precision_a: str = "mx6",
              precision_b: str = "mx6") -> jax.Array:
    """a [M, K] @ b [K, N] with both operands MX-quantized along K — the
    UNFUSED pipeline: quantized operands materialize as ``MXTensor``s
    between the quantize and matmul programs. Prefer :func:`mx_matmul_fused`
    on the hot path."""
    mode = kernel_mode()
    if mode == "ref":
        # Pad K to a block multiple exactly like the kernel path does
        # (zero pads quantize to zero and add nothing to the dot product).
        _count("mx_matmul", "ref")
        a, pad = _pad_last(a, BLOCK)
        if pad:
            b = jnp.pad(b, [(0, pad), (0, 0)])
        return _ref.mx_matmul_fp_ref(a, b, precision_a, precision_b)
    m, n = a.shape[0], b.shape[1]
    ap, bp = _pad_matmul_operands(a, b)
    qa = mx_quantize(ap, precision_a)
    qb_t = mx_quantize(bp.T, precision_b)
    qb = MXTensor(qb_t.mantissa.T, qb_t.exponent.T, qb_t.mx_bits.T,
                  qb_t.precision)
    out = _mm.mx_matmul(qa, qb, bm=_row_tile(ap.shape[0]),
                        bn=_row_tile(bp.shape[1]), bk=_k_tile(ap.shape[1]),
                        interpret=(mode == "interpret"))
    _count("mx_matmul", mode)
    if out.shape[0] != m or out.shape[1] != n:
        out = out[:m, :n]
    return out


def mx_matmul_fused(a: jax.Array, b: jax.Array, precision_a: str = "mx6",
                    precision_b: str = "mx6") -> jax.Array:
    """Fused quantize→matmul: a [M, K] fp32/bf16 @ b [K, N] → fp32 [M, N],
    both operands quantized per-16-block along K *inside* the matmul — ONE
    program for the whole chain (mx_fused.py in pallas/interpret modes, the
    single-jit ``mx_matmul_fused_ref`` oracle in ref mode). Bit-identical
    to ``mx_quantize`` → ``mx_matmul`` in every kernel mode."""
    mode = kernel_mode()
    if mode == "ref":
        _count("mx_matmul_fused", "ref")
        a, pad = _pad_last(a, BLOCK)
        if pad:
            b = jnp.pad(b, [(0, pad), (0, 0)])
        return _ref.mx_matmul_fused_ref(a, b, precision_a, precision_b)
    m, n = a.shape[0], b.shape[1]
    ap, bp = _pad_matmul_operands(a, b)
    out = _mf.mx_matmul_fused(ap, bp, precision_a, precision_b,
                              bm=_row_tile(ap.shape[0]),
                              bn=_row_tile(bp.shape[1]),
                              bk=_k_tile(ap.shape[1]),
                              interpret=(mode == "interpret"))
    _count("mx_matmul_fused", mode)
    if out.shape[0] != m or out.shape[1] != n:
        out = out[:m, :n]
    return out


def mx_matmul_bwd_pair(g: jax.Array, x: jax.Array, w: jax.Array,
                       precision: str = "mx9"):
    """Both gradients of ``y = x @ w`` in ONE program: the backward pair
    of the paper's §V-C precision-conversion unit, which produces the
    transposed MX blocks so both gradient GEMMs consume the same resident
    cotangent. ``g [M, N]`` (cotangent), ``x [M, K]`` (saved input),
    ``w [K, N]`` (weight) → ``(dx [M, K], dw [K, N])`` fp32.

    Bit-identical in every kernel mode to the unfused chain

        dx = mx_matmul_fused(g, w.T, precision, precision)
        dw = mx_matmul_fused(x.T, g, precision, precision)

    each phase of the pair kernel replays exactly the padding, tiling and
    k-inner accumulation the standalone launch would use (the two GEMMs
    quantize g along different contraction axes — N for dX, M for dW — so
    each phase quantizes its own per-16-block view, as the standalone
    launches do)."""
    mode = kernel_mode()
    m, n = g.shape
    k = w.shape[0]
    assert x.shape == (m, k), (x.shape, (m, k))
    assert w.shape[1] == n, (w.shape, n)
    if mode == "ref":
        _count("mx_matmul_bwd_pair", "ref")
        g1, padn = _pad_last(g, BLOCK)
        wt = w.T
        if padn:
            wt = jnp.pad(wt, [(0, padn), (0, 0)])
        xt, padm = _pad_last(x.T, BLOCK)
        g2 = jnp.pad(g, [(0, padm), (0, 0)]) if padm else g
        return _ref.mx_matmul_bwd_pair_ref(g1, wt, xt, g2, precision)
    g1, wtp = _pad_matmul_operands(g, w.T)
    xtp, g2p = _pad_matmul_operands(x.T, g)
    dx, dw = _mf.mx_matmul_bwd_pair(
        g1, wtp, xtp, g2p, precision,
        bm1=_row_tile(g1.shape[0]), bn1=_row_tile(wtp.shape[1]),
        bk1=_k_tile(g1.shape[1]),
        bm2=_row_tile(xtp.shape[0]), bn2=_row_tile(g2p.shape[1]),
        bk2=_k_tile(xtp.shape[1]),
        interpret=(mode == "interpret"))
    _count("mx_matmul_bwd_pair", mode)
    if dx.shape != (m, k):
        dx = dx[:m, :k]
    if dw.shape != (k, n):
        dw = dw[:k, :n]
    return dx, dw


def mx_quantize_rhs(b: jax.Array, precision: str) -> MXTensor:
    """Quantize ``b [K, N]`` along K — the contraction axis — into the rhs
    layout the matmul kernels stream (mantissa [K', N] with exponents /
    micro-exponent bits [K'/16, N]; K' = K padded up to a 16 multiple).
    This is the RESIDENT serving format: quantize a weight once, then feed
    :func:`mx_matmul_prequant` every window with zero per-call weight
    quantization work."""
    q = mx_quantize(b.T, precision)
    return MXTensor(q.mantissa.T, q.exponent.T, q.mx_bits.T, q.precision)


def mx_matmul_prequant(a: jax.Array, qb: MXTensor,
                       precision_a: str = "mx6") -> jax.Array:
    """``a [M, K]`` @ an ALREADY-QUANTIZED weight ``qb`` (rhs layout, from
    :func:`mx_quantize_rhs`) → fp32 [M, N]. The activations are quantized
    on the fly inside the program; the weight operand is consumed straight
    from its stored MX representation — no weight quantization per call.

    Bit-identical to ``mx_matmul_fused(a, b, precision_a, qb.precision)``
    for ``qb = mx_quantize_rhs(b, ...)``: MX quantization is idempotent,
    so the stored mantissas and scales ARE what the fused kernel would
    recompute from ``b`` (tests/test_mx.py pins this). Zero-padding the
    resident operand's K'/N up to kernel tile alignment uses (mantissa 0,
    exponent EXP_MIN, bits 0) — exactly what the fused kernel's in-flight
    quantization produces for zero-padded regions."""
    mode = kernel_mode()
    m, k = a.shape
    kq, n = qb.mantissa.shape
    assert kq % BLOCK == 0 and k <= kq < k + BLOCK, (k, kq)
    if mode == "ref":
        _count("mx_matmul_prequant", "ref")
        ap, _ = _pad_last(a, BLOCK)
        return _ref.mx_matmul_prequant_ref(ap, qb, precision_a)
    ap = _pad_dim(_pad_dim(a, 0, ROW_ALIGN), 1, LANE_ALIGN)
    padk, padn = ap.shape[1] - kq, (-n) % LANE_ALIGN
    rm, re, rx = qb.mantissa, qb.exponent, qb.mx_bits
    if padk or padn:
        rm = jnp.pad(rm, [(0, padk), (0, padn)])
        re = jnp.pad(re, [(0, padk // BLOCK), (0, padn)],
                     constant_values=EXP_MIN)
        rx = jnp.pad(rx, [(0, padk // BLOCK), (0, padn)])
    out = _mf.mx_matmul_prequant(
        ap, rm, re, rx, precision_a, MANTISSA_BITS[qb.precision],
        bm=_row_tile(ap.shape[0]), bn=_row_tile(rm.shape[1]),
        bk=_k_tile(ap.shape[1]), interpret=(mode == "interpret"))
    _count("mx_matmul_prequant", mode)
    if out.shape[0] != m or out.shape[1] != n:
        out = out[:m, :n]
    return out


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    q_offset: int = 0) -> jax.Array:
    """Flash attention; q [B,Sq,H,D], k/v [B,Skv,Kv,D]."""
    mode = kernel_mode()
    if mode == "ref":
        _count("flash_attention", "ref")
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                        softcap=softcap, scale=scale)
    _count("flash_attention", mode)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale, q_offset=q_offset,
                               interpret=(mode == "interpret"))
