"""Jit'd public wrappers for the Pallas kernels.

On TPU backends the kernels lower natively; everywhere else (this CPU
container, the dry-run host platform) they execute in ``interpret=True`` mode
or fall back to the pure-jnp oracle — selected automatically, overridable via
``REPRO_KERNEL_MODE`` in {"pallas", "interpret", "ref"}.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import mx_matmul as _mm
from repro.kernels import mx_quantize as _mq
from repro.kernels import ref as _ref
from repro.kernels.ref import BLOCK, MXTensor


def kernel_mode() -> str:
    mode = os.environ.get("REPRO_KERNEL_MODE", "auto")
    if mode != "auto":
        return mode
    return "pallas" if jax.default_backend() == "tpu" else "interpret"


def _pad_last(x, multiple):
    k = x.shape[-1]
    pad = (-k) % multiple
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, pad


def mx_quantize(x: jax.Array, precision: str) -> MXTensor:
    """Quantize along the last axis (auto-padded to a multiple of 16)."""
    mode = kernel_mode()
    orig_shape = x.shape
    x2 = x.reshape(-1, orig_shape[-1])
    x2, pad = _pad_last(x2, BLOCK)
    if mode == "ref" or x2.shape[0] % 8:
        q = _ref.mx_quantize_ref(x2, precision)
    else:
        q = _mq.mx_quantize(x2, precision, interpret=(mode == "interpret"))
    return q


def mx_dequantize(q: MXTensor) -> jax.Array:
    return _ref.mx_dequantize_ref(q)


def mx_quant_dequant(x: jax.Array, precision: str) -> jax.Array:
    """Fake-quant round trip (used by the MX training autodiff wrapper)."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    x2p, pad = _pad_last(x2, BLOCK)
    y = mx_dequantize(mx_quantize(x2p, precision))
    if pad:
        y = y[:, : shape[-1]]
    return y.reshape(shape).astype(x.dtype)


def mx_matmul(a: jax.Array, b: jax.Array, precision_a: str = "mx6",
              precision_b: str = "mx6") -> jax.Array:
    """a [M, K] @ b [K, N] with both operands MX-quantized along K."""
    mode = kernel_mode()
    if mode == "ref":
        # Pad K to a block multiple exactly like the kernel path does
        # (zero pads quantize to zero and add nothing to the dot product).
        a, pad = _pad_last(a, BLOCK)
        if pad:
            b = jnp.pad(b, [(0, pad), (0, 0)])
        return _ref.mx_matmul_fp_ref(a, b, precision_a, precision_b)
    qa = mx_quantize(a, precision_a)
    qb_t = mx_quantize(b.T, precision_b)
    qb = MXTensor(qb_t.mantissa.T, qb_t.exponent.T, qb_t.mx_bits.T,
                  qb_t.precision)
    m, k = qa.mantissa.shape
    n = qb.mantissa.shape[1]
    if m % 8 or n % 128 or k % 128:
        return _ref.mx_matmul_ref(qa, MXTensor(
            qb.mantissa.T, qb.exponent.T, qb.mx_bits.T, qb.precision))
    return _mm.mx_matmul(qa, qb, interpret=(mode == "interpret"))


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    q_offset: int = 0) -> jax.Array:
    """Flash attention; q [B,Sq,H,D], k/v [B,Skv,Kv,D]."""
    mode = kernel_mode()
    if mode == "ref":
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                        softcap=softcap, scale=scale)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale, q_offset=q_offset,
                               interpret=(mode == "interpret"))
