# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# This repo's hot-spots (DaCapo's MX pipeline + attention):
#   mx_quantize.py / mx_matmul.py — the unfused MX kernels (quantize to
#     MXTensor in K-last layout, matmul over MXTensors; the matmul's rhs
#     streams the K-first "rhs layout" — also the weight-RESIDENT serving
#     format ops.mx_quantize_rhs stores)
#   mx_fused.py — the fused entries: mx_matmul_fused (both operands
#     quantized per-16-block in VMEM inside the matmul grid, ONE program
#     per GEMM), mx_matmul_bwd_pair (BOTH gradient GEMMs of a dense layer
#     in ONE program — the cotangent quantized in VMEM and consumed by dX
#     and dW without a second launch), and mx_matmul_prequant (serving
#     GEMM against an already-quantized resident weight: activations
#     quantized on the fly, zero weight-quantization work per call) — all
#     bit-identical to their unfused chains
#   flash_attention.py — chunked online-softmax attention
#   ref.py — pure-jnp oracles (bit-exact ground truth for all of the
#     above; also the serving path under REPRO_KERNEL_MODE=ref)
#   ops.py — the only public entry: mode routing (pallas/interpret/ref),
#     tile-alignment padding (no silent ref fallbacks), kernel_stats().
