# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# This repo's hot-spots (DaCapo's MX pipeline + attention):
#   mx_quantize.py / mx_matmul.py — the unfused MX kernels (quantize to
#     MXTensor, matmul over MXTensors)
#   mx_fused.py — the fused quantize→matmul kernel: both operands
#     quantized per-16-block in VMEM inside the matmul grid, ONE program
#     per GEMM, bit-identical to the unfused chain
#   flash_attention.py — chunked online-softmax attention
#   ref.py — pure-jnp oracles (bit-exact ground truth for all of the
#     above; also the serving path under REPRO_KERNEL_MODE=ref)
#   ops.py — the only public entry: mode routing (pallas/interpret/ref),
#     tile-alignment padding (no silent ref fallbacks), kernel_stats().
