"""Pure-jnp oracles for every Pallas kernel.

MX (micro-exponent block floating point) semantics, faithful to the paper's
§V-B / the MX paper [19]:
  - blocks of 16 address-adjacent values along the contraction axis share an
    8-bit exponent E = max exponent in the block;
  - sub-blocks of 2 values carry a 1-bit micro-exponent, set when *both*
    exponents are < E (shifting the sub-block scale down by 1, recovering one
    mantissa bit of precision);
  - mantissas are sign-magnitude with 2 (MX4), 4 (MX6) or 7 (MX9) bits.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 16
SUBBLOCK = 2
MANTISSA_BITS = {"mx4": 2, "mx6": 4, "mx9": 7}
EXP_MIN = -126


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MXTensor:
    """Quantized tensor: blocks of 16 along the LAST axis."""

    mantissa: jax.Array  # int8, same shape as source [..., K]
    exponent: jax.Array  # int8, [..., K//16] (shared, unbiased)
    mx_bits: jax.Array  # uint8, [..., K//16] (bit i = sub-block i flag)
    precision: str = dataclasses.field(metadata={"static": True})


def _exponent(x: jax.Array) -> jax.Array:
    """Unbiased fp32 exponent, elementwise (denormals flush to EXP_MIN)."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    e = ((bits >> 23) & 0xFF).astype(jnp.int32) - 127
    return jnp.where(x == 0.0, EXP_MIN, e)


def mx_quantize_ref(x: jax.Array, precision: str) -> MXTensor:
    """Quantize along the last axis (must be divisible by 16)."""
    mb = MANTISSA_BITS[precision]
    *lead, k = x.shape
    assert k % BLOCK == 0, f"last dim {k} not divisible by {BLOCK}"
    xb = x.astype(jnp.float32).reshape(*lead, k // BLOCK, BLOCK)
    e = _exponent(xb)
    e_shared = jnp.max(e, axis=-1)  # [..., k/16]
    e_sub = jnp.max(e.reshape(*lead, k // BLOCK, BLOCK // SUBBLOCK, SUBBLOCK),
                    axis=-1)  # [..., k/16, 8]
    mx = (e_sub < e_shared[..., None]).astype(jnp.uint8)
    mx_packed = jnp.sum(mx.astype(jnp.uint32)
                        * (1 << jnp.arange(BLOCK // SUBBLOCK, dtype=jnp.uint32)),
                        axis=-1).astype(jnp.uint8)
    e_eff = e_shared[..., None, None] - mx[..., None].astype(jnp.int32)
    scale = jnp.exp2((mb - 1) - e_eff.astype(jnp.float32))
    xs = xb.reshape(*lead, k // BLOCK, BLOCK // SUBBLOCK, SUBBLOCK)
    m = jnp.clip(jnp.round(jnp.abs(xs) * scale), 0, 2 ** mb - 1)
    m = (m * jnp.sign(xs)).astype(jnp.int8).reshape(*lead, k)
    return MXTensor(m, e_shared.astype(jnp.int8), mx_packed, precision)


def mx_dequantize_ref(q: MXTensor) -> jax.Array:
    mb = MANTISSA_BITS[q.precision]
    *lead, k = q.mantissa.shape
    m = q.mantissa.astype(jnp.float32).reshape(
        *lead, k // BLOCK, BLOCK // SUBBLOCK, SUBBLOCK)
    sub = jnp.arange(BLOCK // SUBBLOCK, dtype=jnp.uint8)
    mx = ((q.mx_bits[..., None] >> sub) & 1).astype(jnp.int32)  # [...,k/16,8]
    e_eff = q.exponent.astype(jnp.int32)[..., None] - mx
    x = m * jnp.exp2(e_eff[..., None].astype(jnp.float32) - (mb - 1))
    return x.reshape(*lead, k)


def mx_quant_dequant_ref(x: jax.Array, precision: str) -> jax.Array:
    """Fake-quant: the numerical effect of storing x in MX."""
    return mx_dequantize_ref(mx_quantize_ref(x, precision)).astype(x.dtype)


def mx_matmul_ref(lhs: MXTensor, rhs: MXTensor) -> jax.Array:
    """[M, K] @ [N, K]^T -> [M, N] fp32 (both quantized along K)."""
    a = mx_dequantize_ref(lhs)
    b = mx_dequantize_ref(rhs)
    return jnp.einsum("mk,nk->mn", a, b, preferred_element_type=jnp.float32)


def mx_matmul_fp_ref(a: jax.Array, b: jax.Array, precision_a: str,
                     precision_b: str) -> jax.Array:
    """fp inputs a [M,K], b [K,N] -> quantize both along K, matmul fp32."""
    qa = mx_quantize_ref(a, precision_a)
    qb = mx_quantize_ref(b.T, precision_b)
    return mx_matmul_ref(qa, qb)


@functools.partial(jax.jit, static_argnames=("precision_a", "precision_b"))
def mx_matmul_fused_ref(a: jax.Array, b: jax.Array, precision_a: str,
                        precision_b: str) -> jax.Array:
    """Single-jit fused quantize→matmul for CPU/interpret hosts: the whole
    quantize-both-operands-then-matmul chain compiles (and dispatches) as
    ONE program, mirroring the fused Pallas kernel (mx_fused.py) where MX
    data never leaves VMEM. Numerically it IS ``mx_matmul_fp_ref`` — the
    ops are elementwise-exact (bitcast exponents, power-of-two scales,
    round/clip, int8 casts) plus one dot, so jitting changes nothing."""
    return mx_matmul_fp_ref(a, b, precision_a, precision_b)


@functools.partial(jax.jit, static_argnames=("precision",))
def mx_matmul_bwd_pair_ref(g1: jax.Array, wt: jax.Array, xt: jax.Array,
                           g2: jax.Array,
                           precision: str) -> Tuple[jax.Array, jax.Array]:
    """Single-jit oracle for the BACKWARD PAIR (mx_fused.py's
    ``mx_matmul_bwd_pair``): both gradient GEMMs of an MX dense layer —
    ``dX = q(g) @ q(W^T)`` and ``dW = q(X^T) @ q(g)`` — compile (and
    dispatch) as ONE program, so the cotangent makes one trip through the
    precision-conversion math per consumer instead of one per launched
    program. ``g1``/``g2`` are the cotangent padded for each GEMM's
    contraction axis (N for dX, M for dW); numerically each output IS the
    corresponding ``mx_matmul_fp_ref``, so jitting them together changes
    nothing."""
    return (mx_matmul_fp_ref(g1, wt, precision, precision),
            mx_matmul_fp_ref(xt, g2, precision, precision))


@functools.partial(jax.jit, static_argnames=("precision_a",))
def mx_matmul_prequant_ref(a: jax.Array, qb: MXTensor,
                           precision_a: str) -> jax.Array:
    """Single-jit oracle for the WEIGHT-RESIDENT serving GEMM: the lhs is
    quantized on the fly, the rhs arrives ALREADY quantized (rhs layout:
    mantissa [K, N], exponents [K/16, N] — quantized along the contraction
    axis K) and is only dequantized. Bit-identical to
    ``mx_matmul_fp_ref(a, b, ...)`` for ``qb`` = the quantization of ``b``:
    MX quantization is idempotent, so skipping the weight re-quantization
    changes nothing but the work."""
    qa = mx_quantize_ref(a, precision_a)
    qb_t = MXTensor(qb.mantissa.T, qb.exponent.T, qb.mx_bits.T, qb.precision)
    return mx_matmul_ref(qa, qb_t)


# -------------------------------------------------------- flash attention ---
def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                        scale=None):
    """Naive masked attention oracle. q [B,Sq,H,D], k/v [B,Skv,Kv,D]."""
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = d ** -0.5 if scale is None else scale
    qg = q.reshape(b, sq, kvh, g, d)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq)[:, None] + (skv - sq)
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d).astype(q.dtype)
