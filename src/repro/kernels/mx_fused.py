"""Pallas TPU kernel: FUSED MX quantize→matmul (precision-conversion unit
feeding the DPE arrays directly, paper §V-C → §V-B).

The unfused pipeline materializes ``MXTensor``s in HBM between the quantize
kernel (mx_quantize.py) and the matmul kernel (mx_matmul.py). This kernel
takes the fp32/bf16 operands themselves: each [bm, bk] / [bk, bn] tile is
quantized per-16-block *in VMEM inside the matmul grid* — shared exponents,
micro-exponent bits and sign-magnitude mantissas are computed, applied and
discarded on-chip — and the dequantized tiles hit the MXU as fp32 dot
products with fp32 accumulation in a VMEM scratch accumulator. MX mantissas
and scales never touch HBM.

Bit-identity contract: the quantize math below is element-for-element the
``_quantize_kernel`` of mx_quantize.py (including the float→int8→float
mantissa round trip, which zero-blocks rely on), the dequant scales are the
same integer effective exponents, and the k-grid accumulation order matches
``_matmul_kernel`` of mx_matmul.py for equal tile sizes — so the fused
output is bitwise equal to quantize→matmul (tests/test_mx.py pins this).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.mx_matmul import _dequant_rhs
from repro.kernels.ref import BLOCK, EXP_MIN, MANTISSA_BITS, SUBBLOCK

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _exponent(x):
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    e = ((bits >> 23) & 0xFF).astype(jnp.int32) - 127
    return jnp.where(x == 0.0, EXP_MIN, e)


def _quant_dequant_lhs(x, mb: int):
    """Fake-quant a [bm, bk] tile per 16-block along the LAST axis, fully
    in registers/VMEM — the values the unfused dequant would reload."""
    bm, bk = x.shape
    nb = bk // BLOCK
    xb = x.reshape(bm, nb, BLOCK)
    e = _exponent(xb)
    e_shared = jnp.max(e, axis=-1)  # [bm, nb]
    e_sub = jnp.max(e.reshape(bm, nb, BLOCK // SUBBLOCK, SUBBLOCK), axis=-1)
    mx = (e_sub < e_shared[..., None]).astype(jnp.int32)  # [bm, nb, 8]
    e_eff = e_shared[..., None] - mx
    qscale = jnp.exp2(jnp.float32(mb - 1) - e_eff.astype(jnp.float32))
    xs = xb.reshape(bm, nb, BLOCK // SUBBLOCK, SUBBLOCK)
    m = jnp.clip(jnp.round(jnp.abs(xs) * qscale[..., None]), 0, 2 ** mb - 1)
    # int8 round trip: NOT redundant — all-zero blocks produce an inf
    # quantize scale whose 0*inf=nan mantissa the int cast flushes to 0,
    # exactly as the unfused quantize kernel stores it.
    m = (m * jnp.sign(xs)).astype(jnp.int8).astype(jnp.float32)
    dscale = jnp.exp2(e_eff.astype(jnp.float32) - (mb - 1))
    return (m * dscale[..., None]).reshape(bm, bk)


def _quant_dequant_rhs(x, mb: int):
    """Fake-quant a [bk, bn] tile per 16-block along the FIRST axis (the
    contraction axis of the rhs) without transposing the tile."""
    bk, bn = x.shape
    nb = bk // BLOCK
    xb = x.reshape(nb, BLOCK, bn)
    e = _exponent(xb)
    e_shared = jnp.max(e, axis=1)  # [nb, bn]
    e_sub = jnp.max(e.reshape(nb, BLOCK // SUBBLOCK, SUBBLOCK, bn), axis=2)
    mx = (e_sub < e_shared[:, None, :]).astype(jnp.int32)  # [nb, 8, bn]
    e_eff = e_shared[:, None, :] - mx
    qscale = jnp.exp2(jnp.float32(mb - 1) - e_eff.astype(jnp.float32))
    xs = xb.reshape(nb, BLOCK // SUBBLOCK, SUBBLOCK, bn)
    m = jnp.clip(jnp.round(jnp.abs(xs) * qscale[:, :, None, :]),
                 0, 2 ** mb - 1)
    m = (m * jnp.sign(xs)).astype(jnp.int8).astype(jnp.float32)
    dscale = jnp.exp2(e_eff.astype(jnp.float32) - (mb - 1))
    return (m * dscale[:, :, None, :]).reshape(bk, bn)


def _fused_kernel(a_ref, b_ref, out_ref, acc_ref, *, mb_lhs: int,
                  mb_rhs: int, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = _quant_dequant_lhs(a_ref[...].astype(jnp.float32), mb_lhs)
    b = _quant_dequant_rhs(b_ref[...].astype(jnp.float32), mb_rhs)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("precision_a", "precision_b",
                                             "bm", "bn", "bk", "interpret"))
def mx_matmul_fused(a: jax.Array, b: jax.Array, precision_a: str = "mx6",
                    precision_b: str = "mx6", *, bm: int = DEFAULT_BM,
                    bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                    interpret: bool = False) -> jax.Array:
    """a [M, K] fp32/bf16 @ b [K, N] → fp32 [M, N], both operands quantized
    per-16-block on the fly inside the matmul grid. ONE program for the
    whole quantize→matmul chain."""
    m_dim, k_dim = a.shape
    k2, n_dim = b.shape
    assert k_dim == k2, (k_dim, k2)
    bm, bn, bk = min(bm, m_dim), min(bn, n_dim), min(bk, k_dim)
    assert m_dim % bm == 0 and n_dim % bn == 0 and k_dim % bk == 0
    assert bk % BLOCK == 0
    nk = k_dim // bk
    grid = (m_dim // bm, n_dim // bn, nk)
    kernel = functools.partial(
        _fused_kernel, mb_lhs=MANTISSA_BITS[precision_a],
        mb_rhs=MANTISSA_BITS[precision_b], nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, n_dim), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)


# ----------------------------------------------------------- backward pair ---
def _bwd_pair_kernel(g1_ref, wt_ref, xt_ref, g2_ref, dx_ref, dw_ref,
                     acc1_ref, acc2_ref, *, mb: int, dims):
    """Both gradient GEMMs of an MX dense layer in ONE program.

    The 1-D grid covers ``S1 + S2`` steps: the first ``S1`` run GEMM 1
    (``dX = q(g) @ q(W^T)``), the rest run GEMM 2 (``dW = q(X^T) @ q(g)``).
    Each phase replays exactly the per-step quantize/dot/accumulate sequence
    the standalone ``_fused_kernel`` would execute over its own 3-D grid —
    same tiles, same k-inner order — so both outputs are bitwise equal to
    the two independent fused launches. ``pl.when`` keeps only the active
    phase's compute live on any step; the inactive phase's operand/output
    index maps are clamped (see ``mx_matmul_bwd_pair``), so its blocks just
    round-trip unmodified.
    """
    nm1, nn1, nk1, nm2, nn2, nk2 = dims
    s1 = nm1 * nn1 * nk1
    s = pl.program_id(0)
    phase1 = s < s1
    k1 = s % nk1
    k2 = (s - s1) % nk2

    @pl.when(phase1 & (k1 == 0))
    def _init1():
        acc1_ref[...] = jnp.zeros_like(acc1_ref)

    @pl.when(phase1)
    def _acc1():
        a = _quant_dequant_lhs(g1_ref[...].astype(jnp.float32), mb)
        b = _quant_dequant_rhs(wt_ref[...].astype(jnp.float32), mb)
        acc1_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(phase1 & (k1 == nk1 - 1))
    def _flush1():
        dx_ref[...] = acc1_ref[...]

    phase2 = jnp.logical_not(phase1)

    @pl.when(phase2 & (k2 == 0))
    def _init2():
        acc2_ref[...] = jnp.zeros_like(acc2_ref)

    @pl.when(phase2)
    def _acc2():
        a = _quant_dequant_lhs(xt_ref[...].astype(jnp.float32), mb)
        b = _quant_dequant_rhs(g2_ref[...].astype(jnp.float32), mb)
        acc2_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(phase2 & (k2 == nk2 - 1))
    def _flush2():
        dw_ref[...] = acc2_ref[...]


@functools.partial(jax.jit, static_argnames=(
    "precision", "bm1", "bn1", "bk1", "bm2", "bn2", "bk2", "interpret"))
def mx_matmul_bwd_pair(g1: jax.Array, wt: jax.Array, xt: jax.Array,
                       g2: jax.Array, precision: str = "mx9", *,
                       bm1: int, bn1: int, bk1: int,
                       bm2: int, bn2: int, bk2: int,
                       interpret: bool = False):
    """ONE Pallas program emitting both gradients of ``y = x @ w``:
    ``dX = q(g1) @ q(wt)`` over grid 1 and ``dW = q(xt) @ q(g2)`` over
    grid 2, fused into a single 1-D grid of ``S1 + S2`` steps. The
    cotangent stays resident in VMEM across both consumers instead of
    being re-streamed (and its quantization pipeline re-launched) by a
    second program.

    ``g1``/``g2`` are the same cotangent padded for each GEMM's role
    (g1: dX's lhs [M, N]; g2: dW's rhs [M', N]) — the two GEMMs contract
    g along different axes (N for dX, M for dW), so each consumer
    quantizes its own per-16-block view in-program, exactly as the
    standalone fused launches would. Outputs: ``dx [M, K]``,
    ``dw [K', N]`` fp32.
    """
    m_dim, n1 = g1.shape
    n1b, k_dim = wt.shape
    assert n1 == n1b, (n1, n1b)
    k2_dim, m2 = xt.shape
    m2b, n2 = g2.shape
    assert m2 == m2b, (m2, m2b)
    assert m_dim % bm1 == 0 and k_dim % bn1 == 0 and n1 % bk1 == 0
    assert k2_dim % bm2 == 0 and n2 % bn2 == 0 and m2 % bk2 == 0
    assert bk1 % BLOCK == 0 and bk2 % BLOCK == 0
    nm1, nn1, nk1 = m_dim // bm1, k_dim // bn1, n1 // bk1
    nm2, nn2, nk2 = k2_dim // bm2, n2 // bn2, m2 // bk2
    s1, s2 = nm1 * nn1 * nk1, nm2 * nn2 * nk2
    c1, c2 = nn1 * nk1, nn2 * nk2
    kernel = functools.partial(
        _bwd_pair_kernel, mb=MANTISSA_BITS[precision],
        dims=(nm1, nn1, nk1, nm2, nn2, nk2))

    # Phase-aware block index maps, derived from the flat step s. During
    # the OTHER phase each map clamps to a block that is never again
    # flushed (GEMM 1 operands/output park on their last block, GEMM 2 on
    # their first), so the inactive output block round-trips unchanged.
    def g1_map(s):
        p1 = s < s1
        return (jnp.where(p1, s // c1, nm1 - 1),
                jnp.where(p1, s % nk1, nk1 - 1))

    def wt_map(s):
        p1 = s < s1
        return (jnp.where(p1, s % nk1, nk1 - 1),
                jnp.where(p1, (s // nk1) % nn1, nn1 - 1))

    def dx_map(s):
        p1 = s < s1
        return (jnp.where(p1, s // c1, nm1 - 1),
                jnp.where(p1, (s // nk1) % nn1, nn1 - 1))

    def xt_map(s):
        t = jnp.maximum(s - s1, 0)
        return t // c2, t % nk2

    def g2_map(s):
        t = jnp.maximum(s - s1, 0)
        return t % nk2, (t // nk2) % nn2

    def dw_map(s):
        t = jnp.maximum(s - s1, 0)
        return t // c2, (t // nk2) % nn2

    return pl.pallas_call(
        kernel,
        grid=(s1 + s2,),
        in_specs=[
            pl.BlockSpec((bm1, bk1), g1_map),
            pl.BlockSpec((bk1, bn1), wt_map),
            pl.BlockSpec((bm2, bk2), xt_map),
            pl.BlockSpec((bk2, bn2), g2_map),
        ],
        out_specs=[
            pl.BlockSpec((bm1, bn1), dx_map),
            pl.BlockSpec((bm2, bn2), dw_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_dim, k_dim), jnp.float32),
            jax.ShapeDtypeStruct((k2_dim, n2), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bm1, bn1), jnp.float32),
                        pltpu.VMEM((bm2, bn2), jnp.float32)],
        interpret=interpret,
    )(g1, wt, xt, g2)


# ----------------------------------------------------- weight-resident GEMM --
def _prequant_kernel(a_ref, rm_ref, re_ref, rx_ref, out_ref, acc_ref, *,
                     mb_lhs: int, mb_rhs: int, nk: int):
    """Serving GEMM with a RESIDENT quantized rhs: the activation tile is
    quantized on the fly (same math as ``_fused_kernel``'s lhs), the weight
    tile arrives as stored MX mantissas/exponents and is only dequantized
    (``mx_matmul.py``'s rhs dequant) — zero weight-quantization work."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = _quant_dequant_lhs(a_ref[...].astype(jnp.float32), mb_lhs)
    b = _dequant_rhs(rm_ref[...], re_ref[...], rx_ref[...], mb_rhs)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("precision_a", "mb_rhs",
                                             "bm", "bn", "bk", "interpret"))
def mx_matmul_prequant(a: jax.Array, rm: jax.Array, re: jax.Array,
                       rx: jax.Array, precision_a: str = "mx6",
                       mb_rhs: int = 4, *, bm: int = DEFAULT_BM,
                       bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                       interpret: bool = False) -> jax.Array:
    """a [M, K] fp32/bf16 @ an ALREADY-QUANTIZED rhs in rhs layout
    (mantissa ``rm`` [K, N] int8, exponents ``re`` / micro-exponent bits
    ``rx`` [K/16, N]) → fp32 [M, N]. Bit-identical to ``mx_matmul_fused``
    on the dequantized weight because MX quantization is idempotent: the
    stored mantissas/scales ARE what the fused kernel would recompute."""
    m_dim, k_dim = a.shape
    k2, n_dim = rm.shape
    assert k_dim == k2, (k_dim, k2)
    bm, bn, bk = min(bm, m_dim), min(bn, n_dim), min(bk, k_dim)
    assert m_dim % bm == 0 and n_dim % bn == 0 and k_dim % bk == 0
    assert bk % BLOCK == 0
    nk = k_dim // bk
    grid = (m_dim // bm, n_dim // bn, nk)
    kernel = functools.partial(
        _prequant_kernel, mb_lhs=MANTISSA_BITS[precision_a],
        mb_rhs=mb_rhs, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // BLOCK, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // BLOCK, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, n_dim), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, rm, re, rx)
