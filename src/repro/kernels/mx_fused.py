"""Pallas TPU kernel: FUSED MX quantize→matmul (precision-conversion unit
feeding the DPE arrays directly, paper §V-C → §V-B).

The unfused pipeline materializes ``MXTensor``s in HBM between the quantize
kernel (mx_quantize.py) and the matmul kernel (mx_matmul.py). This kernel
takes the fp32/bf16 operands themselves: each [bm, bk] / [bk, bn] tile is
quantized per-16-block *in VMEM inside the matmul grid* — shared exponents,
micro-exponent bits and sign-magnitude mantissas are computed, applied and
discarded on-chip — and the dequantized tiles hit the MXU as fp32 dot
products with fp32 accumulation in a VMEM scratch accumulator. MX mantissas
and scales never touch HBM.

Bit-identity contract: the quantize math below is element-for-element the
``_quantize_kernel`` of mx_quantize.py (including the float→int8→float
mantissa round trip, which zero-blocks rely on), the dequant scales are the
same integer effective exponents, and the k-grid accumulation order matches
``_matmul_kernel`` of mx_matmul.py for equal tile sizes — so the fused
output is bitwise equal to quantize→matmul (tests/test_mx.py pins this).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import BLOCK, EXP_MIN, MANTISSA_BITS, SUBBLOCK

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _exponent(x):
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    e = ((bits >> 23) & 0xFF).astype(jnp.int32) - 127
    return jnp.where(x == 0.0, EXP_MIN, e)


def _quant_dequant_lhs(x, mb: int):
    """Fake-quant a [bm, bk] tile per 16-block along the LAST axis, fully
    in registers/VMEM — the values the unfused dequant would reload."""
    bm, bk = x.shape
    nb = bk // BLOCK
    xb = x.reshape(bm, nb, BLOCK)
    e = _exponent(xb)
    e_shared = jnp.max(e, axis=-1)  # [bm, nb]
    e_sub = jnp.max(e.reshape(bm, nb, BLOCK // SUBBLOCK, SUBBLOCK), axis=-1)
    mx = (e_sub < e_shared[..., None]).astype(jnp.int32)  # [bm, nb, 8]
    e_eff = e_shared[..., None] - mx
    qscale = jnp.exp2(jnp.float32(mb - 1) - e_eff.astype(jnp.float32))
    xs = xb.reshape(bm, nb, BLOCK // SUBBLOCK, SUBBLOCK)
    m = jnp.clip(jnp.round(jnp.abs(xs) * qscale[..., None]), 0, 2 ** mb - 1)
    # int8 round trip: NOT redundant — all-zero blocks produce an inf
    # quantize scale whose 0*inf=nan mantissa the int cast flushes to 0,
    # exactly as the unfused quantize kernel stores it.
    m = (m * jnp.sign(xs)).astype(jnp.int8).astype(jnp.float32)
    dscale = jnp.exp2(e_eff.astype(jnp.float32) - (mb - 1))
    return (m * dscale[..., None]).reshape(bm, bk)


def _quant_dequant_rhs(x, mb: int):
    """Fake-quant a [bk, bn] tile per 16-block along the FIRST axis (the
    contraction axis of the rhs) without transposing the tile."""
    bk, bn = x.shape
    nb = bk // BLOCK
    xb = x.reshape(nb, BLOCK, bn)
    e = _exponent(xb)
    e_shared = jnp.max(e, axis=1)  # [nb, bn]
    e_sub = jnp.max(e.reshape(nb, BLOCK // SUBBLOCK, SUBBLOCK, bn), axis=2)
    mx = (e_sub < e_shared[:, None, :]).astype(jnp.int32)  # [nb, 8, bn]
    e_eff = e_shared[:, None, :] - mx
    qscale = jnp.exp2(jnp.float32(mb - 1) - e_eff.astype(jnp.float32))
    xs = xb.reshape(nb, BLOCK // SUBBLOCK, SUBBLOCK, bn)
    m = jnp.clip(jnp.round(jnp.abs(xs) * qscale[:, :, None, :]),
                 0, 2 ** mb - 1)
    m = (m * jnp.sign(xs)).astype(jnp.int8).astype(jnp.float32)
    dscale = jnp.exp2(e_eff.astype(jnp.float32) - (mb - 1))
    return (m * dscale[:, :, None, :]).reshape(bk, bn)


def _fused_kernel(a_ref, b_ref, out_ref, acc_ref, *, mb_lhs: int,
                  mb_rhs: int, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = _quant_dequant_lhs(a_ref[...].astype(jnp.float32), mb_lhs)
    b = _quant_dequant_rhs(b_ref[...].astype(jnp.float32), mb_rhs)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("precision_a", "precision_b",
                                             "bm", "bn", "bk", "interpret"))
def mx_matmul_fused(a: jax.Array, b: jax.Array, precision_a: str = "mx6",
                    precision_b: str = "mx6", *, bm: int = DEFAULT_BM,
                    bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                    interpret: bool = False) -> jax.Array:
    """a [M, K] fp32/bf16 @ b [K, N] → fp32 [M, N], both operands quantized
    per-16-block on the fly inside the matmul grid. ONE program for the
    whole quantize→matmul chain."""
    m_dim, k_dim = a.shape
    k2, n_dim = b.shape
    assert k_dim == k2, (k_dim, k2)
    bm, bn, bk = min(bm, m_dim), min(bn, n_dim), min(bk, k_dim)
    assert m_dim % bm == 0 and n_dim % bn == 0 and k_dim % bk == 0
    assert bk % BLOCK == 0
    nk = k_dim // bk
    grid = (m_dim // bm, n_dim // bn, nk)
    kernel = functools.partial(
        _fused_kernel, mb_lhs=MANTISSA_BITS[precision_a],
        mb_rhs=MANTISSA_BITS[precision_b], nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, n_dim), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
