"""Pallas TPU kernel: MX-quantized matmul (the DPE array of the paper, §V-B,
adapted to the MXU).

Mantissas (int8) and per-block scales stream HBM->VMEM in MXU-aligned
[128-multiple] tiles; blocks are dequantized in VMEM and hit the MXU as fp32
dot products with fp32 accumulation in a VMEM scratch accumulator. Storage &
bandwidth see MX compression; compute runs at MXU rates — the TPU-native
equivalent of the paper's 2/4/8-bit DPE trees (see DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import BLOCK, MANTISSA_BITS, MXTensor

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _unpack_scales_k_last(e, mx, bk: int):
    """e [bm, bk/16] int8, mx [bm, bk/16] uint8 -> eff exp [bm, bk] int32."""
    bm = e.shape[0]
    nb = bk // BLOCK
    sub = jnp.arange(BLOCK // SUBBLOCK_SAFE, dtype=jnp.uint8)
    bits = ((mx[..., None] >> sub) & 1).astype(jnp.int32)  # [bm, nb, 8]
    eff = e.astype(jnp.int32)[..., None] - bits  # [bm, nb, 8]
    eff = jnp.broadcast_to(eff[..., None], (bm, nb, BLOCK // 2, 2))
    return eff.reshape(bm, bk)


SUBBLOCK_SAFE = 2


def _dequant_lhs(m, e, mx, mb: int):
    eff = _unpack_scales_k_last(e, mx, m.shape[1])
    scale = jnp.exp2(eff.astype(jnp.float32) - (mb - 1))
    return m.astype(jnp.float32) * scale


def _dequant_rhs(m, e, mx, mb: int):
    """m [bk, bn]; e/mx [bk/16, bn] -> fp32 [bk, bn]."""
    bk, bn = m.shape
    nb = bk // BLOCK
    row = jax.lax.broadcasted_iota(jnp.int32, (bk, bn), 0)
    sub_idx = ((row % BLOCK) // 2).astype(jnp.uint8)
    e_rep = jnp.repeat(e.astype(jnp.int32), BLOCK, axis=0)
    mx_rep = jnp.repeat(mx, BLOCK, axis=0)
    bits = ((mx_rep >> sub_idx) & 1).astype(jnp.int32)
    eff = e_rep - bits
    scale = jnp.exp2(eff.astype(jnp.float32) - (mb - 1))
    return m.astype(jnp.float32) * scale


def _matmul_kernel(lm, le, lx, rm, re, rx, out_ref, acc_ref, *,
                   mb_lhs: int, mb_rhs: int, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = _dequant_lhs(lm[...], le[...], lx[...], mb_lhs)  # [bm, bk]
    b = _dequant_rhs(rm[...], re[...], rx[...], mb_rhs)  # [bk, bn]
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def mx_matmul(lhs: MXTensor, rhs: MXTensor, *, bm: int = DEFAULT_BM,
              bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
              interpret: bool = False) -> jax.Array:
    """lhs [M, K] (quantized along K), rhs [K, N] (quantized along K, i.e.
    rhs.mantissa is [K, N] with exponents [K/16, N]) -> fp32 [M, N]."""
    m_dim, k_dim = lhs.mantissa.shape
    k2, n_dim = rhs.mantissa.shape
    assert k_dim == k2, (k_dim, k2)
    bm, bn, bk = min(bm, m_dim), min(bn, n_dim), min(bk, k_dim)
    assert m_dim % bm == 0 and n_dim % bn == 0 and k_dim % bk == 0
    assert bk % BLOCK == 0
    nk = k_dim // bk
    grid = (m_dim // bm, n_dim // bn, nk)
    kernel = functools.partial(
        _matmul_kernel, mb_lhs=MANTISSA_BITS[lhs.precision],
        mb_rhs=MANTISSA_BITS[rhs.precision], nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bk // BLOCK), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bk // BLOCK), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // BLOCK, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // BLOCK, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, n_dim), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(lhs.mantissa, lhs.exponent, lhs.mx_bits,
      rhs.mantissa, rhs.exponent, rhs.mx_bits)
