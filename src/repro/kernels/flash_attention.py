"""Pallas TPU kernel: forward flash attention (causal / sliding-window /
logit-softcap), GQA-aware.

Grid (B*H, nQ, nKV): the kv axis is innermost (sequential on TPU), with the
online-softmax state (m, l, acc) living in VMEM scratch across kv steps.
Fully-masked (q_block, kv_block) pairs are skipped via pl.when — causal
attention does ~S^2/2 work and sliding-window ~S*W, matching the ideal FLOP
counts (this is the TPU answer to masked-rectangle waste; see EXPERIMENTS.md
§Perf).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_QB = 128
DEFAULT_KVB = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: Optional[int],
                 softcap: Optional[float], q_offset: int, nkv: int,
                 kvb: int, qb: int):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_blk = pl.program_id(1)
    q_start = q_blk * qb + q_offset
    kv_start = kv_idx * kvb

    # Static-shape masks from block coordinates.
    qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (qb, kvb), 0)
    kpos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (qb, kvb), 1)
    needed = True
    if causal:
        needed = jnp.logical_and(needed, kv_start <= q_start + qb - 1)
    if window is not None:
        needed = jnp.logical_and(needed, kv_start + kvb - 1 > q_start - window)

    @pl.when(needed if not isinstance(needed, bool) else True)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # [qb, d]
        k = k_ref[0].astype(jnp.float32)  # [kvb, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((qb, kvb), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(kv_idx == nkv - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "q_offset", "qb", "kvb",
    "interpret"))
def flash_attention(
    q: jax.Array,  # [B, Sq, H, D]
    k: jax.Array,  # [B, Skv, Kv, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
    qb: int = DEFAULT_QB,
    kvb: int = DEFAULT_KVB,
    interpret: bool = False,
) -> jax.Array:
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = d ** -0.5 if scale is None else float(scale)
    qb = min(qb, sq)
    kvb = min(kvb, skv)
    assert sq % qb == 0 and skv % kvb == 0
    nq, nkv = sq // qb, skv // kvb

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, q_offset=q_offset, nkv=nkv, kvb=kvb, qb=qb)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, qb, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, kvb, d), lambda bh, i, j, g=g: (bh // g, j, 0)),
            pl.BlockSpec((1, kvb, d), lambda bh, i, j, g=g: (bh // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
