"""Pallas TPU kernel: FP32/BF16 -> MX quantization (the precision-conversion
unit of the paper, §V-C).

Tiles [bm, bk] HBM->VMEM; per 16-element block along the contraction (last)
axis computes the shared exponent (max-tree), per-2 sub-block micro-exponent
bits, and sign-magnitude mantissas.

Two MXTensor layouts flow out of this math (same bits, different axes):

* **K-last (lhs) layout** — what this kernel emits: mantissa [M, K],
  exponents [M, K/16]; quantized along the LAST axis. The matmul lhs.
* **K-first (rhs) layout** — mantissa [K, N], exponents [K/16, N];
  quantized along the FIRST axis. What ``mx_matmul.py`` streams for the
  rhs, produced by quantizing the transpose and transposing the fields
  back (``ops.mx_quantize_rhs``). Since PR 9 this doubles as the
  weight-RESIDENT serving format: ``ops.mx_matmul_prequant`` consumes it
  directly, so a cached weight is quantized once and served forever.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import BLOCK, EXP_MIN, MANTISSA_BITS, MXTensor, SUBBLOCK

DEFAULT_BM = 128
DEFAULT_BK = 512


def _exponent(x):
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    e = ((bits >> 23) & 0xFF).astype(jnp.int32) - 127
    return jnp.where(x == 0.0, EXP_MIN, e)


def _quantize_kernel(x_ref, mant_ref, exp_ref, mx_ref, *, mb: int):
    x = x_ref[...].astype(jnp.float32)  # [bm, bk]
    bm, bk = x.shape
    nb = bk // BLOCK
    xb = x.reshape(bm, nb, BLOCK)
    e = _exponent(xb)
    e_shared = jnp.max(e, axis=-1)  # [bm, nb]
    e_sub = jnp.max(e.reshape(bm, nb, BLOCK // SUBBLOCK, SUBBLOCK), axis=-1)
    mx = (e_sub < e_shared[..., None]).astype(jnp.uint32)  # [bm, nb, 8]
    weights = (1 << jnp.arange(BLOCK // SUBBLOCK, dtype=jnp.uint32))
    mx_packed = jnp.sum(mx * weights, axis=-1).astype(jnp.uint8)
    e_eff = (e_shared[..., None] - mx.astype(jnp.int32))  # [bm, nb, 8]
    scale = jnp.exp2(jnp.float32(mb - 1) - e_eff.astype(jnp.float32))
    xs = xb.reshape(bm, nb, BLOCK // SUBBLOCK, SUBBLOCK)
    m = jnp.clip(jnp.round(jnp.abs(xs) * scale[..., None]), 0, 2 ** mb - 1)
    m = m * jnp.sign(xs)
    mant_ref[...] = m.reshape(bm, bk).astype(jnp.int8)
    exp_ref[...] = e_shared.astype(jnp.int8)
    mx_ref[...] = mx_packed


@functools.partial(jax.jit, static_argnames=("precision", "bm", "bk",
                                             "interpret"))
def mx_quantize(x: jax.Array, precision: str, *, bm: int = DEFAULT_BM,
                bk: int = DEFAULT_BK, interpret: bool = False) -> MXTensor:
    """x [M, K] (K % 16 == 0) -> MXTensor quantized along K."""
    m_dim, k_dim = x.shape
    bm = min(bm, m_dim)
    bk = min(bk, k_dim)
    assert k_dim % BLOCK == 0 and k_dim % bk == 0 and m_dim % bm == 0
    grid = (m_dim // bm, k_dim // bk)
    mant, exp, mx = pl.pallas_call(
        functools.partial(_quantize_kernel, mb=MANTISSA_BITS[precision]),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk // BLOCK), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk // BLOCK), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_dim, k_dim), jnp.int8),
            jax.ShapeDtypeStruct((m_dim, k_dim // BLOCK), jnp.int8),
            jax.ShapeDtypeStruct((m_dim, k_dim // BLOCK), jnp.uint8),
        ],
        interpret=interpret,
    )(x)
    return MXTensor(mant, exp, mx, precision)
