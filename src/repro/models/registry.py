"""Unified handles for vision (CL pairs) and LM (assigned archs) models."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.configs.base import ArchConfig
from repro.configs.dacapo_pairs import VisionConfig
from repro.models import resnet as resnet_lib
from repro.models import vit as vit_lib
from repro.models.transformer import LMModel


@dataclasses.dataclass(frozen=True)
class VisionModel:
    cfg: VisionConfig

    def init(self, key):
        if self.cfg.kind == "resnet":
            return resnet_lib.init_resnet(key, self.cfg)
        return vit_lib.init_vit(key, self.cfg)

    def apply(self, params, images):
        if self.cfg.kind == "resnet":
            return resnet_lib.resnet_forward(params, images, self.cfg)
        return vit_lib.vit_forward(params, images, self.cfg)

    def flops(self) -> float:
        if self.cfg.kind == "resnet":
            return resnet_lib.resnet_flops(self.cfg)
        return vit_lib.vit_flops(self.cfg)

    def param_count(self, params) -> int:
        return sum(p.size for p in jax.tree_util.tree_leaves(params))


def make_vision_model(cfg: VisionConfig) -> VisionModel:
    return VisionModel(cfg)


def make_lm_model(cfg: ArchConfig) -> LMModel:
    return LMModel(cfg)
