"""Top-k Mixture-of-Experts with grouped capacity dispatch and expert
fission (virtual experts).

Two scale mechanisms (DESIGN.md §5):

* **Grouped routing** — tokens route within their batch row (group = row),
  so the dispatch/combine einsums cost ~e*c/(6*f*k) ≈ 6% of the expert FFN
  FLOPs instead of scaling with the global token count.
* **Expert fission** — when the expert-parallel axis doesn't divide the
  expert count (mixtral: 8 experts on a 16-wide axis), each expert is split
  along d_ff into r virtual experts (exact for SwiGLU: gate/up/down split
  along f and the down-projections sum). This is the paper's row-granular
  fission idea applied to experts; it keeps every device busy instead of
  leaving half the axis idle under padded sharding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed import (
    ParamDef,
    _STATE,
    constrain,
    current_rules,
    mesh_axis_size,
)


def expert_split_factor(cfg: ArchConfig) -> int:
    """Smallest r with (num_experts * r) divisible by the EP axis size."""
    rules = current_rules()
    mesh = getattr(_STATE, "mesh", None)
    ep = mesh_axis_size(mesh, rules.get("expert")) if (rules and mesh) else 1
    r = 1
    while (cfg.num_experts * r) % ep or cfg.d_ff % r:
        r += 1
        if r > ep:
            return 1
    return r


def moe_defs(cfg: ArchConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    r = expert_split_factor(cfg)
    ev, fv = e * r, f // r
    dt = jnp.dtype(cfg.dtype)
    # Experts shard over the tensor axis ("expert"->model), their d_model dim
    # over the FSDP axis ("expert_in"->data) — batch parallelism stays intact
    # through dispatch (no batch<->expert axis conflict).
    return {
        "router": ParamDef((d, e), ("embed", None), dtype=jnp.float32),
        "w_gate": ParamDef((ev, d, fv), ("expert", "expert_in", "expert_ff"),
                           dtype=dt),
        "w_up": ParamDef((ev, d, fv), ("expert", "expert_in", "expert_ff"),
                         dtype=dt),
        "w_down": ParamDef((ev, fv, d), ("expert", "expert_ff", "expert_in"),
                           dtype=dt),
    }


MOE_GROUP = 512  # tokens per routing group (dispatch cost ∝ group size)


def moe_forward(params, x: jax.Array, cfg: ArchConfig, *,
                no_drop: bool = False):
    """x [B, S, D] -> (y [B, S, D], aux_loss). Routing groups are
    MOE_GROUP-token slices of each row: the dispatch/combine einsums cost
    s*c*d with c ∝ group size, so grouping keeps them a few % of the expert
    FFN FLOPs at 4k-32k sequence lengths."""
    b_orig, s_orig, d = x.shape
    gs = MOE_GROUP if (s_orig % MOE_GROUP == 0 and not no_drop) else s_orig
    b, s = b_orig * (s_orig // gs), gs
    x = x.reshape(b, s, d)
    e, k = cfg.num_experts, cfg.top_k
    ev = params["w_gate"].shape[0]
    r = ev // e

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # [b,s,k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Switch-style load-balancing aux loss (over all tokens).
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(gate_idx, e, dtype=jnp.float32),
                          axis=2), axis=(0, 1))
    aux = e * jnp.sum(me * ce) * cfg.router_aux_coef

    capacity = s if no_drop else max(1, int(cfg.capacity_factor * s * k / e))
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # [b,s,k,e]
    flat = onehot.reshape(b, s * k, e)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(b, s, k, e)
    pos = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)  # [b,s,k]
    keep = pos < capacity
    gate_vals = gate_vals * keep

    pos_oh = jax.nn.one_hot(pos, capacity, dtype=x.dtype) * keep[..., None]
    # dispatch/combine [b, s, e, c]
    dispatch = jnp.einsum("bske,bskc->bsec", onehot.astype(x.dtype), pos_oh)
    combine = jnp.einsum("bsk,bske,bskc->bsec", gate_vals.astype(x.dtype),
                         onehot.astype(x.dtype), pos_oh)
    if r > 1:  # expert fission: each logical expert -> r virtual experts
        dispatch = jnp.repeat(dispatch, r, axis=2)
        combine = jnp.repeat(combine, r, axis=2)

    xe = jnp.einsum("bsd,bsec->becd", x, dispatch)  # [b, ev, c, d]
    # batch stays data-parallel; experts shard over the tensor axis.
    xe = constrain(xe, "act_batch", "expert", None, None)
    g = jnp.einsum("becd,edf->becf", xe, params["w_gate"])
    u = jnp.einsum("becd,edf->becf", xe, params["w_up"])
    h = jax.nn.silu(g) * u
    h = constrain(h, "act_batch", "expert", None, "expert_ff")
    ye = jnp.einsum("becf,efd->becd", h, params["w_down"])
    ye = constrain(ye, "act_batch", "expert", None, None)
    y = jnp.einsum("becd,bsec->bsd", ye, combine)
    return y.reshape(b_orig, s_orig, d), aux
