"""ViT-B/32 and ViT-B/16 (paper Table III) — compact functional ViT."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.dacapo_pairs import VisionConfig


def _dense_def(key, cin, cout):
    return {"w": jax.random.normal(key, (cin, cout)) * cin ** -0.5,
            "b": jnp.zeros((cout,))}


def _dense(x, p):
    return x @ p["w"] + p["b"]


def _ln(x, p):
    mean = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + 1e-6) * p["scale"] + p["bias"]


def _ln_def(d):
    return {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))}


def init_vit(key, cfg: VisionConfig) -> Dict[str, Any]:
    d = cfg.d_model
    n_patches = (cfg.img_size // cfg.patch) ** 2
    keys = iter(jax.random.split(key, 16 + 8 * cfg.num_layers))
    params: Dict[str, Any] = {
        "patch": _dense_def(next(keys), cfg.patch * cfg.patch * 3, d),
        "cls": jax.random.normal(next(keys), (1, 1, d)) * 0.02,
        "pos": jax.random.normal(next(keys), (1, n_patches + 1, d)) * 0.02,
        "final_ln": _ln_def(d),
        "head": _dense_def(next(keys), d, cfg.num_classes),
    }
    blocks = []
    for _ in range(cfg.num_layers):
        blocks.append({
            "ln1": _ln_def(d),
            "qkv": _dense_def(next(keys), d, 3 * d),
            "proj": _dense_def(next(keys), d, d),
            "ln2": _ln_def(d),
            "fc1": _dense_def(next(keys), d, cfg.d_ff),
            "fc2": _dense_def(next(keys), cfg.d_ff, d),
        })
    params["blocks"] = blocks
    return params


def vit_forward(params, images, cfg: VisionConfig):
    """images [B,H,W,3] -> logits [B,C]."""
    b, h, w, _ = images.shape
    p = cfg.patch
    x = images.reshape(b, h // p, p, w // p, p, 3).transpose(0, 1, 3, 2, 4, 5)
    x = x.reshape(b, (h // p) * (w // p), p * p * 3)
    x = _dense(x, params["patch"])
    x = jnp.concatenate([jnp.tile(params["cls"], (b, 1, 1)), x], axis=1)
    x = x + params["pos"][:, : x.shape[1]]
    nh = cfg.num_heads
    dh = cfg.d_model // nh
    for bp in params["blocks"]:
        y = _ln(x, bp["ln1"])
        qkv = _dense(y, bp["qkv"]).reshape(b, -1, 3, nh, dh)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / dh ** 0.5
        a = jax.nn.softmax(s, axis=-1)
        y = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(b, -1, cfg.d_model)
        x = x + _dense(y, bp["proj"])
        y = _ln(x, bp["ln2"])
        x = x + _dense(jax.nn.gelu(_dense(y, bp["fc1"])), bp["fc2"])
    x = _ln(x, params["final_ln"])
    return _dense(x[:, 0], params["head"])


def vit_flops(cfg: VisionConfig) -> float:
    n = (cfg.img_size // cfg.patch) ** 2 + 1
    d, f = cfg.d_model, cfg.d_ff
    per_layer = 2 * n * (4 * d * d + 2 * d * f) + 2 * 2 * n * n * d
    total = cfg.num_layers * per_layer
    total += 2 * n * cfg.patch * cfg.patch * 3 * d
    total += 2 * d * cfg.num_classes
    return total


def vit_param_count(params) -> int:
    return sum(p.size for p in jax.tree_util.tree_leaves(params))
