"""Composable decoder LM: Block(mixer, mlp) stacks with scan-over-layers.

The layer stack is grouped by the config's repeating pattern period (dense=1,
gemma2=2, xlstm=6, jamba=8); parameters for each period position are stacked
[n_groups, ...] and the model scans over groups, keeping HLO size O(period)
instead of O(num_layers). Remat is applied per group in training.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ArchConfig,
    MIXER_ATTENTION,
    MIXER_MAMBA,
    MIXER_MLSTM,
    MIXER_SLSTM,
)
from repro.distributed import (
    ParamDef,
    constrain,
    init_params,
    param_shapes,
    param_specs,
    stack_defs,
)
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.layers import (
    apply_norm,
    mlp_defs,
    mlp_forward,
    norm_defs,
    sincos_positions,
    softcap,
)

CE_CHUNK = 1024


# ----------------------------------------------------------------- param defs
def _block_defs(cfg: ArchConfig, pos: int) -> Dict[str, Any]:
    mixer = cfg.mixer_for_layer(pos)
    defs: Dict[str, Any] = {"norm1": norm_defs(cfg, cfg.d_model)}
    if mixer == MIXER_ATTENTION:
        defs["mixer"] = attn.attn_defs(cfg)
    elif mixer == MIXER_MAMBA:
        defs["mixer"] = ssm_lib.mamba_defs(cfg)
    elif mixer == MIXER_MLSTM:
        defs["mixer"] = xlstm_lib.mlstm_defs(cfg)
    elif mixer == MIXER_SLSTM:
        defs["mixer"] = xlstm_lib.slstm_defs(cfg)
    if cfg.post_block_norm:
        defs["post_norm1"] = norm_defs(cfg, cfg.d_model)
    if cfg.mlp != "none" and cfg.d_ff > 0:
        defs["norm2"] = norm_defs(cfg, cfg.d_model)
        defs["ffn"] = (moe_lib.moe_defs(cfg) if cfg.is_moe_layer(pos)
                       else mlp_defs(cfg))
        if cfg.post_block_norm:
            defs["post_norm2"] = norm_defs(cfg, cfg.d_model)
    return defs


def _block_forward(bp, x, cfg: ArchConfig, pos: int, *, mode: str,
                   positions, cache):
    mixer = cfg.mixer_for_layer(pos)
    h = apply_norm(bp["norm1"], x, cfg)
    if mixer == MIXER_ATTENTION:
        y, new_cache = attn.attention_forward(
            bp["mixer"], h, cfg, pos, positions=positions, mode=mode,
            cache=cache)
    elif mixer == MIXER_MAMBA:
        y, new_cache = ssm_lib.mamba_forward(bp["mixer"], h, cfg, mode=mode,
                                             cache=cache)
    elif mixer == MIXER_MLSTM:
        y, new_cache = xlstm_lib.mlstm_forward(bp["mixer"], h, cfg, mode=mode,
                                               cache=cache)
    else:
        y, new_cache = xlstm_lib.slstm_forward(bp["mixer"], h, cfg, mode=mode,
                                               cache=cache)
    if cfg.post_block_norm:
        y = apply_norm(bp["post_norm1"], y, cfg)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in bp:
        h = apply_norm(bp["norm2"], x, cfg)
        if cfg.is_moe_layer(pos):
            y, aux = moe_lib.moe_forward(bp["ffn"], h, cfg,
                                         no_drop=(mode == "decode"))
        else:
            y = mlp_forward(bp["ffn"], h, cfg)
        if cfg.post_block_norm:
            y = apply_norm(bp["post_norm2"], y, cfg)
        x = x + y
    x = constrain(x, "act_batch", "act_seq", "act_embed")
    return x, new_cache, aux


# ---------------------------------------------------------------------- model
@dataclasses.dataclass
class LMModel:
    cfg: ArchConfig

    @property
    def period(self) -> int:
        return self.cfg.pattern_period()

    @property
    def n_groups(self) -> int:
        return self.cfg.num_layers // self.period

    # ----------------------------------------------------------------- params
    def param_defs(self):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        defs: Dict[str, Any] = {
            "embed": ParamDef((cfg.vocab_size, cfg.d_model),
                              ("vocab", "embed"), dtype=dt, scale=1.0),
            "final_norm": norm_defs(cfg, cfg.d_model),
        }
        if cfg.pos == "learned":
            defs["pos_embed"] = ParamDef(
                (cfg.max_position_embeddings, cfg.d_model), (None, "embed"),
                dtype=dt, scale=0.02)
        if not cfg.tie_embeddings:
            defs["head"] = ParamDef(
                (cfg.num_output_heads, cfg.d_model, cfg.vocab_size),
                (None, "embed", "vocab"), dtype=dt)
        blocks = []
        for pos in range(self.period):
            blocks.append(stack_defs([_block_defs(self.cfg, pos)]
                                     * self.n_groups))
        defs["blocks"] = tuple(blocks)
        return defs

    def init(self, key):
        return init_params(self.param_defs(), key)

    def param_shapes(self):
        return param_shapes(self.param_defs())

    def param_specs(self):
        return param_specs(self.param_defs())

    # ----------------------------------------------------------------- embeds
    def embed(self, params, inputs, positions, mode: str):
        cfg = self.cfg
        if cfg.input_mode == "embeddings":
            x = inputs.astype(jnp.dtype(cfg.dtype))
        else:
            x = jnp.take(params["embed"], inputs, axis=0)
        if cfg.embed_scale:
            x = x * math.sqrt(cfg.d_model)
        if cfg.pos == "learned":
            if mode == "decode":
                pe = jax.lax.dynamic_index_in_dim(
                    params["pos_embed"], positions, keepdims=True)[None]
            else:
                pe = params["pos_embed"][positions][None]
            x = x + pe
        elif cfg.pos == "sincos":
            pos_arr = positions[None] if jnp.ndim(positions) == 0 \
                else positions
            x = x + sincos_positions(pos_arr, cfg.d_model)[None].astype(x.dtype)
        return x

    # ---------------------------------------------------------------- forward
    def hidden(self, params, inputs, *, mode: str, positions,
               caches=None, remat: bool = True):
        """inputs: tokens [B,S] / embeds [B,S,D]; decode: [B,1]/[B,1,D]."""
        cfg = self.cfg
        x = self.embed(params, inputs, positions, mode)
        x = constrain(x, "act_batch", "act_seq", "act_embed")
        period = self.period

        def group_body(x, xs):
            group_params, group_caches = xs
            new_caches: List[Any] = []
            aux_sum = jnp.zeros((), jnp.float32)
            for pos in range(period):
                cache_p = None if group_caches is None else group_caches[pos]
                x, nc, aux = _block_forward(
                    group_params[pos], x, cfg, pos, mode=mode,
                    positions=positions, cache=cache_p)
                new_caches.append(nc)
                aux_sum = aux_sum + aux
            if all(c is None for c in new_caches):
                return x, (aux_sum,)
            return x, (aux_sum, tuple(new_caches))

        body = group_body
        if remat and mode == "train":
            body = jax.checkpoint(
                group_body, policy=jax.checkpoint_policies.nothing_saveable)
        xs = (params["blocks"], caches)
        x, ys = jax.lax.scan(body, x, xs)
        aux_total = jnp.sum(ys[0])
        new_caches = ys[1] if len(ys) > 1 else None
        x = apply_norm(params["final_norm"], x, cfg)
        return x, new_caches, aux_total

    def head_matrix(self, params):
        cfg = self.cfg
        if cfg.tie_embeddings:
            return params["embed"].T[None]  # [1, D, V]
        return params["head"]  # [nH, D, V]

    def logits(self, params, x):
        """x [B,S,D] -> [B,S,nH,V] (nH==1 squeezed to [B,S,V])."""
        cfg = self.cfg
        w = self.head_matrix(params)
        out = jnp.einsum("bsd,hdv->bshv", x, w)
        out = softcap(out.astype(jnp.float32), cfg.final_softcap)
        if cfg.num_output_heads == 1:
            out = out[:, :, 0]
        return out

    # ------------------------------------------------------------------ steps
    def loss(self, params, batch, *, remat: bool = True):
        """batch: inputs [B,S](tokens)/[B,S,D](embeds), labels [B,S] or
        [B,S,nH], optional mask [B,S]. Chunked-vocab CE (never materializes
        [B,S,V] logits)."""
        cfg = self.cfg
        inputs, labels = batch["inputs"], batch["labels"]
        b, s = labels.shape[:2]
        positions = jnp.arange(s)
        x, _, aux = self.hidden(params, inputs, mode="train",
                                positions=positions, remat=remat)
        w = self.head_matrix(params)  # [nH, D, V]
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones((b, s), jnp.float32)
        if labels.ndim == 2:
            labels = labels[..., None]  # [B,S,1]

        csz = CE_CHUNK if s % CE_CHUNK == 0 else s
        n_chunks = s // csz

        def to_chunks(t):
            return t.reshape((b, n_chunks, csz) + t.shape[2:]).swapaxes(0, 1)

        def ce_chunk(carry, xs):
            xc, lc, mc = xs  # [B,csz,D], [B,csz,nH], [B,csz]
            logits = jnp.einsum("bsd,hdv->bshv", xc, w).astype(jnp.float32)
            logits = softcap(logits, cfg.final_softcap)
            logits = constrain(logits, "act_batch", "act_seq", None, "vocab")
            lse = jax.nn.logsumexp(logits, axis=-1)  # [B,csz,nH]
            picked = jnp.take_along_axis(logits, lc[..., None],
                                         axis=-1)[..., 0]
            nll = (lse - picked).mean(axis=-1) * mc  # [B,csz]
            correct = (jnp.argmax(logits, axis=-1) == lc).all(-1) * mc
            return (carry[0] + nll.sum(), carry[1] + correct.sum()), None

        (nll_sum, correct), _ = jax.lax.scan(
            ce_chunk, (jnp.zeros(()), jnp.zeros(())),
            (to_chunks(x), to_chunks(labels), to_chunks(mask)))
        denom = jnp.maximum(mask.sum(), 1.0)
        loss = nll_sum / denom + aux
        metrics = {"loss": loss, "nll": nll_sum / denom, "aux": aux,
                   "accuracy": correct / denom}
        return loss, metrics

    def prefill(self, params, inputs, *, cache_capacity: int):
        """Run prefill; returns (last_logits [B,(nH,)V], caches)."""
        s = inputs.shape[1]
        positions = jnp.arange(s)
        x, caches, _ = self.hidden(
            params, inputs, mode="prefill", positions=positions,
            caches=self.init_caches(inputs.shape[0], cache_capacity),
            remat=False)
        return self.logits(params, x[:, -1:])[:, 0], caches

    def decode_step(self, params, inputs, t, caches):
        """One token: inputs [B,1] / [B,1,D]; t scalar position."""
        x, new_caches, _ = self.hidden(
            params, inputs, mode="decode", positions=t, caches=caches,
            remat=False)
        return self.logits(params, x)[:, 0], new_caches

    # ------------------------------------------------------------------ cache
    def cache_defs(self, batch: int, capacity: int):
        caches = []
        for pos in range(self.period):
            mixer = self.cfg.mixer_for_layer(pos)
            if mixer == MIXER_ATTENTION:
                cd = attn.attn_cache_defs(self.cfg, pos, batch, capacity)
            elif mixer == MIXER_MAMBA:
                cd = ssm_lib.mamba_cache_defs(self.cfg, batch)
            elif mixer == MIXER_MLSTM:
                cd = xlstm_lib.mlstm_cache_defs(self.cfg, batch)
            else:
                cd = xlstm_lib.slstm_cache_defs(self.cfg, batch)
            caches.append(stack_defs([cd] * self.n_groups))
        return tuple(caches)

    def init_caches(self, batch: int, capacity: int):
        return init_params(self.cache_defs(batch, capacity),
                           jax.random.PRNGKey(0))


def make_model(cfg: ArchConfig) -> LMModel:
    return LMModel(cfg)


def init_cache_defs(cfg: ArchConfig, batch: int, capacity: int):
    return LMModel(cfg).cache_defs(batch, capacity)
