from repro.models.transformer import (  # noqa: F401
    LMModel,
    init_cache_defs,
    make_model,
)
