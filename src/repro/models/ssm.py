"""Mamba (S6) mixer — selective state-space layer in JAX.

Training/prefill use a chunked scan: an outer ``lax.scan`` over sequence
chunks carrying the SSM state (checkpointed so the backward pass recomputes
within-chunk intermediates instead of saving [B,S,di,ds] tensors), with an
associative scan inside each chunk for intra-chunk parallelism on the VPU.
Decode is a single recurrent step.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed import ParamDef, constrain

MAMBA_CHUNK = 32


def _dt_rank(cfg: ArchConfig) -> int:
    return math.ceil(cfg.d_model / 16)


def mamba_defs(cfg: ArchConfig):
    d = cfg.d_model
    di = cfg.mamba_expand * d
    ds = cfg.mamba_d_state
    dc = cfg.mamba_d_conv
    dtr = _dt_rank(cfg)
    dt = jnp.dtype(cfg.dtype)
    return {
        "w_in_x": ParamDef((d, di), ("embed", "ff"), dtype=dt),
        "w_in_z": ParamDef((d, di), ("embed", "ff"), dtype=dt),
        "conv_w": ParamDef((dc, di), (None, "ff"), dtype=dt, scale=0.5),
        "conv_b": ParamDef((di,), ("ff",), init="zeros", dtype=dt),
        "w_bc": ParamDef((di, 2 * ds), ("ff", None), dtype=dt),
        "w_dt_down": ParamDef((di, dtr), ("ff", None), dtype=dt),
        "w_dt_up": ParamDef((dtr, di), (None, "ff"), dtype=dt),
        "dt_bias": ParamDef((di,), ("ff",), init="const", scale=-4.0,
                            dtype=jnp.float32),
        "a_log": ParamDef((di, ds), ("ff", None), init="const", scale=0.0,
                          dtype=jnp.float32),
        "d_skip": ParamDef((di,), ("ff",), init="ones", dtype=jnp.float32),
        "w_out": ParamDef((di, d), ("ff", "embed"), dtype=dt),
    }


def _causal_conv(x, w, b, conv_state: Optional[jax.Array] = None):
    """Depthwise causal conv over seq. x [B,S,di], w [dc,di]."""
    dc = w.shape[0]
    if conv_state is not None:  # decode: state [B, dc-1, di]
        xx = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    else:
        xx = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    s = x.shape[1]
    y = sum(xx[:, i:i + s] * w[i] for i in range(dc))
    new_state = xx[:, -(dc - 1):] if dc > 1 else None
    return y + b, new_state


def _ssm_inputs(params, xc, cfg: ArchConfig):
    """xc [B,S,di] -> (dA [B,S,di,ds], dBx [B,S,di,ds], y_skip)."""
    ds = cfg.mamba_d_state
    bc = jnp.einsum("bsd,dn->bsn", xc, params["w_bc"]).astype(jnp.float32)
    b_in, c_in = jnp.split(bc, 2, axis=-1)  # [B,S,ds]
    dt_low = jnp.einsum("bsd,dr->bsr", xc, params["w_dt_down"])
    dt = jnp.einsum("bsr,rd->bsd", dt_low, params["w_dt_up"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + params["dt_bias"])  # [B,S,di]
    a = -jnp.exp(params["a_log"])  # [di,ds]
    dA = jnp.exp(dt[..., None] * a)  # [B,S,di,ds]
    dBx = dt[..., None] * b_in[:, :, None, :] * xc.astype(jnp.float32)[..., None]
    return dA, dBx, c_in


def _chunk_scan(h0, dA, dBx, c_in, xc, d_skip):
    """One chunk: associative scan over S_chunk. h0 [B,di,ds]."""
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    a_cum, b_cum = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    h = a_cum * h0[:, None] + b_cum  # [B,S,di,ds]
    y = jnp.einsum("bsdn,bsn->bsd", h, c_in)
    y = y + xc.astype(jnp.float32) * d_skip
    return h[:, -1], y


def mamba_forward(params, x, cfg: ArchConfig, *, mode: str,
                  cache: Optional[dict] = None):
    """x [B,S,D] -> (y [B,S,D], new_cache)."""
    b, s, d = x.shape
    di = cfg.mamba_expand * d
    ds = cfg.mamba_d_state

    xi = jnp.einsum("bsd,de->bse", x, params["w_in_x"])
    z = jnp.einsum("bsd,de->bse", x, params["w_in_z"])
    xi = constrain(xi, "act_batch", "act_seq", "ff")

    if mode == "decode":
        xc, conv_state = _causal_conv(xi, params["conv_w"], params["conv_b"],
                                      cache["conv"])
        xc = jax.nn.silu(xc)
        dA, dBx, c_in = _ssm_inputs(params, xc, cfg)
        h = dA[:, 0] * cache["ssm"] + dBx[:, 0]  # [B,di,ds]
        y = jnp.einsum("bdn,bn->bd", h, c_in[:, 0])[:, None]
        y = y + xc.astype(jnp.float32) * params["d_skip"]
        new_cache = {"conv": conv_state.astype(cache["conv"].dtype), "ssm": h}
    else:
        xc, _ = _causal_conv(xi, params["conv_w"], params["conv_b"])
        xc = jax.nn.silu(xc)
        csz = MAMBA_CHUNK if s % MAMBA_CHUNK == 0 else s
        n_chunks = s // csz

        def body(h, xc_c):
            # [B,csz,di,ds] intermediates live only inside this (rematted)
            # chunk body — never [B,S,di,ds].
            dA_c, dBx_c, cin_c = _ssm_inputs(params, xc_c, cfg)
            return _chunk_scan(h, dA_c, dBx_c, cin_c, xc_c, params["d_skip"])

        xc_chunks = xc.reshape(b, n_chunks, csz, di).swapaxes(0, 1)
        h0 = jnp.zeros((b, di, ds), jnp.float32)
        h_last, ys = jax.lax.scan(jax.checkpoint(body), h0, xc_chunks)
        y = ys.swapaxes(0, 1).reshape(b, s, di)
        new_cache = None
        if mode == "prefill":
            dc = cfg.mamba_d_conv
            pad = jnp.pad(xi, ((0, 0), (dc - 1, 0), (0, 0)))[:, -(dc - 1):]
            new_cache = {"conv": pad.astype(jnp.dtype(cfg.dtype)),
                         "ssm": h_last}

    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = constrain(y, "act_batch", "act_seq", "ff")
    return jnp.einsum("bse,ed->bsd", y, params["w_out"]), new_cache


def mamba_cache_defs(cfg: ArchConfig, batch: int):
    di = cfg.mamba_expand * cfg.d_model
    return {
        "conv": ParamDef((batch, cfg.mamba_d_conv - 1, di),
                         ("kv_batch", None, "ff"), init="zeros",
                         dtype=jnp.dtype(cfg.dtype)),
        "ssm": ParamDef((batch, di, cfg.mamba_d_state),
                        ("kv_batch", "ff", None), init="zeros",
                        dtype=jnp.float32),
    }
