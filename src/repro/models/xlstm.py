"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

TPU adaptation notes (documented in DESIGN.md): the mLSTM runs in a
*chunkwise-parallel* form — inter-chunk state passing plus intra-chunk
masked-matmul attention-like computation — so the MXU sees dense matmuls
instead of a length-S elementwise recurrence. Numerical safety comes from a
tanh softcap on the input-gate preactivation (exp(i)<=e^8) and sigmoid forget
gates whose log-cumsums are <=0, replacing the paper's running-max stabilizer
(equivalent up to gate saturation, and chunk-parallelizable).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed import ParamDef, constrain

MLSTM_CHUNK = 256
IGATE_CAP = 8.0


def _heads(cfg: ArchConfig):
    return cfg.num_heads


# ------------------------------------------------------------------ mLSTM ---
def mlstm_defs(cfg: ArchConfig):
    d = cfg.d_model
    di = int(cfg.mlstm_proj_factor * d)
    h = _heads(cfg)
    dc = 4
    dt = jnp.dtype(cfg.dtype)
    return {
        "w_in_x": ParamDef((d, di), ("embed", "ff"), dtype=dt),
        "w_in_z": ParamDef((d, di), ("embed", "ff"), dtype=dt),
        "conv_w": ParamDef((dc, di), (None, "ff"), dtype=dt, scale=0.5),
        "conv_b": ParamDef((di,), ("ff",), init="zeros", dtype=dt),
        "w_q": ParamDef((di, di), ("ff", "ff2"), dtype=dt),
        "w_k": ParamDef((di, di), ("ff", "ff2"), dtype=dt),
        "w_v": ParamDef((di, di), ("ff", "ff2"), dtype=dt),
        "w_i": ParamDef((di, h), ("ff", None), dtype=jnp.float32),
        "b_i": ParamDef((h,), (None,), init="zeros", dtype=jnp.float32),
        "w_f": ParamDef((di, h), ("ff", None), dtype=jnp.float32),
        "b_f": ParamDef((h,), (None,), init="const", scale=3.0,
                        dtype=jnp.float32),
        "gn_scale": ParamDef((di,), ("ff",), init="ones", dtype=jnp.float32),
        "w_out": ParamDef((di, d), ("ff", "embed"), dtype=dt),
    }


def _mlstm_chunk(carry, qkvif):
    """Chunkwise-parallel mLSTM. carry: (C [B,H,dv,dk], n [B,H,dk]).
    q,k,v [B,L,H,dh] fp32; lf (log forget) / li (log input) [B,L,H]."""
    C, n = carry
    q, k, v, lf, li = qkvif
    b_cum = jnp.cumsum(lf, axis=1)  # [B,L,H], <= 0, decreasing
    w_in = jnp.exp(b_cum)  # decay from chunk start
    # A[t,s] = (q_t . k_s) * exp(b_t - b_s + li_s) for s <= t.
    L = q.shape[1]
    mask = jnp.tril(jnp.ones((L, L), bool))
    decay = b_cum[:, :, None, :] - b_cum[:, None, :, :] + li[:, None, :, :]
    decay = jnp.where(mask[None, :, :, None], decay, -jnp.inf)
    d_mat = jnp.exp(decay)  # [B,t,s,H] <= e^IGATE_CAP
    qk = jnp.einsum("bthd,bshd->btsh", q, k)
    a_mat = qk * d_mat
    h_intra = jnp.einsum("btsh,bshd->bthd", a_mat, v)
    h_inter = jnp.einsum("bthk,bhvk->bthv", q * w_in[..., None], C)
    # Normalizer n_t = exp(b_t) n_prev + sum_{s<=t} exp(b_t-b_s+li_s) k_s.
    n_intra = jnp.einsum("btsh,bshd->bthd", d_mat, k)
    n_t = w_in[..., None] * n[:, None] + n_intra  # [B,L,H,dk]
    denom = jnp.maximum(
        jnp.abs(jnp.einsum("bthd,bthd->bth", n_t, q)), 1.0)
    h = (h_intra + h_inter) / denom[..., None]
    # State to next chunk.
    w_end = jnp.exp(b_cum[:, -1:] - b_cum + li)  # [B,L,H]
    C_new = jnp.exp(b_cum[:, -1])[:, :, None, None] * C + jnp.einsum(
        "blh,blhv,blhk->bhvk", w_end, v, k)
    n_new = jnp.exp(b_cum[:, -1])[..., None] * n + jnp.einsum(
        "blh,blhk->bhk", w_end, k)
    return (C_new, n_new), h


def _mlstm_step(C, n, q, k, v, lf, li):
    """Single decode step. q,k,v [B,H,dh]; lf/li [B,H]."""
    f = jnp.exp(lf)[..., None, None]
    i = jnp.exp(li)[..., None, None]
    C_new = f * C + i * jnp.einsum("bhv,bhk->bhvk", v, k)
    n_new = f[..., 0] * n + i[..., 0] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q)), 1.0)
    h = jnp.einsum("bhvk,bhk->bhv", C_new, q) / denom[..., None]
    return C_new, n_new, h


def _group_rms(h, scale, nh):
    """Per-head RMS norm (GroupNorm stand-in). h [..., di]."""
    shp = h.shape
    hh = h.reshape(shp[:-1] + (nh, shp[-1] // nh))
    var = jnp.mean(jnp.square(hh), axis=-1, keepdims=True)
    hh = hh * jax.lax.rsqrt(var + 1e-6)
    return hh.reshape(shp) * scale


def mlstm_forward(params, x, cfg: ArchConfig, *, mode: str,
                  cache: Optional[dict] = None):
    from repro.models.ssm import _causal_conv

    b, s, d = x.shape
    di = int(cfg.mlstm_proj_factor * d)
    nh = _heads(cfg)
    dh = di // nh

    xi = jnp.einsum("bsd,de->bse", x, params["w_in_x"])
    z = jnp.einsum("bsd,de->bse", x, params["w_in_z"])
    xi = constrain(xi, "act_batch", "act_seq", "ff")
    conv_state = cache["conv"] if mode == "decode" else None
    xc, new_conv = _causal_conv(xi, params["conv_w"], params["conv_b"],
                                conv_state)
    xc = jax.nn.silu(xc)

    def proj(w, src):
        return jnp.einsum("bse,ef->bsf", src, w).reshape(b, -1, nh, dh)

    q = proj(params["w_q"], xc).astype(jnp.float32)
    k = (proj(params["w_k"], xc) / math.sqrt(dh)).astype(jnp.float32)
    v = proj(params["w_v"], xi).astype(jnp.float32)
    xc32 = xc.astype(jnp.float32)
    lf = jax.nn.log_sigmoid(
        jnp.einsum("bse,eh->bsh", xc32, params["w_f"]) + params["b_f"])
    li = IGATE_CAP * jnp.tanh(
        (jnp.einsum("bse,eh->bsh", xc32, params["w_i"]) + params["b_i"])
        / IGATE_CAP)

    if mode == "decode":
        C, n, hh = _mlstm_step(cache["C"], cache["n"], q[:, 0], k[:, 0],
                               v[:, 0], lf[:, 0], li[:, 0])
        h = hh[:, None]  # [B,1,H,dh]
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "C": C, "n": n}
    else:
        csz = MLSTM_CHUNK if s % MLSTM_CHUNK == 0 else s
        nchunk = s // csz

        def to_chunks(t):
            return t.reshape((b, nchunk, csz) + t.shape[2:]).swapaxes(0, 1)

        C0 = jnp.zeros((b, nh, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, nh, dh), jnp.float32)
        (C, n), hs = jax.lax.scan(
            jax.checkpoint(_mlstm_chunk), (C0, n0),
            (to_chunks(q), to_chunks(k), to_chunks(v), to_chunks(lf),
             to_chunks(li)))
        h = hs.swapaxes(0, 1).reshape(b, s, nh, dh)
        new_cache = None
        if mode == "prefill":
            dc = params["conv_w"].shape[0]
            pad = jnp.pad(xi, ((0, 0), (dc - 1, 0), (0, 0)))[:, -(dc - 1):]
            new_cache = {"conv": pad.astype(jnp.dtype(cfg.dtype)),
                         "C": C, "n": n}

    h = h.reshape(b, -1, di)
    h = _group_rms(h, params["gn_scale"], nh)
    y = (h * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", y, params["w_out"]), new_cache


def mlstm_cache_defs(cfg: ArchConfig, batch: int):
    d = cfg.d_model
    di = int(cfg.mlstm_proj_factor * d)
    nh = _heads(cfg)
    dh = di // nh
    return {
        "conv": ParamDef((batch, 3, di), ("kv_batch", None, "ff"),
                         init="zeros", dtype=jnp.dtype(cfg.dtype)),
        "C": ParamDef((batch, nh, dh, dh), ("kv_batch", None, None, None),
                      init="zeros", dtype=jnp.float32),
        "n": ParamDef((batch, nh, dh), ("kv_batch", None, None),
                      init="zeros", dtype=jnp.float32),
    }


# ------------------------------------------------------------------ sLSTM ---
def slstm_defs(cfg: ArchConfig):
    d = cfg.d_model
    h = _heads(cfg)
    dh = d // h
    pf = cfg.slstm_proj_factor
    du = int(pf * d)
    dt = jnp.dtype(cfg.dtype)
    defs = {}
    for g in ("z", "i", "f", "o"):
        defs[f"w_{g}"] = ParamDef((d, d), ("embed", "ff2"), dtype=dt)
        defs[f"r_{g}"] = ParamDef((h, dh, dh), (None, None, None),
                                  dtype=jnp.float32, scale=dh ** -0.5)
        defs[f"b_{g}"] = ParamDef(
            (d,), (None,), init="const" if g == "f" else "zeros",
            scale=3.0 if g == "f" else None, dtype=jnp.float32)
    defs["gn_scale"] = ParamDef((d,), (None,), init="ones", dtype=jnp.float32)
    defs["w_up1"] = ParamDef((d, du), ("embed", "ff"), dtype=dt)
    defs["w_up2"] = ParamDef((d, du), ("embed", "ff"), dtype=dt)
    defs["w_down"] = ParamDef((du, d), ("ff", "embed"), dtype=dt)
    return defs


def _slstm_step(params, state, gates_x, nh):
    """state: (c, n, h, m) each [B, H, dh]; gates_x: zx/ix/fx/ox [B,H,dh]."""
    c, n, h, m = state
    zx, ix, fx, ox = gates_x

    def rec(name, prev_h):
        return jnp.einsum("bhd,hde->bhe", prev_h, params[f"r_{name}"])

    z = jnp.tanh(zx + rec("z", h))
    it = ix + rec("i", h)
    ft = fx + rec("f", h)
    o = jax.nn.sigmoid(ox + rec("o", h))
    lf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(lf + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(lf + m - m_new)
    c_new = f_p * c + i_p * z
    n_new = jnp.maximum(f_p * n + i_p, 1e-6)
    h_new = o * c_new / n_new
    return (c_new, n_new, h_new, m_new)


def slstm_forward(params, x, cfg: ArchConfig, *, mode: str,
                  cache: Optional[dict] = None):
    b, s, d = x.shape
    nh = _heads(cfg)
    dh = d // nh
    x32 = x.astype(jnp.float32)

    def gate_in(name):
        g = jnp.einsum("bsd,de->bse", x, params[f"w_{name}"]).astype(
            jnp.float32) + params[f"b_{name}"]
        return g.reshape(b, s, nh, dh)

    zx, ix, fx, ox = (gate_in(g) for g in ("z", "i", "f", "o"))

    if mode == "decode":
        state = (cache["c"], cache["n"], cache["h"], cache["m"])
        state = _slstm_step(params, state,
                            (zx[:, 0], ix[:, 0], fx[:, 0], ox[:, 0]), nh)
        hs = state[2][:, None]  # [B,1,H,dh]
        new_cache = dict(zip(("c", "n", "h", "m"), state))
    else:
        zeros = jnp.zeros((b, nh, dh), jnp.float32)
        state0 = (zeros, zeros, zeros, jnp.full((b, nh, dh), -1e9))

        def step(state, g):
            new = _slstm_step(params, state, g, nh)
            return new, new[2]

        state, hs = jax.lax.scan(
            step, state0,
            (zx.swapaxes(0, 1), ix.swapaxes(0, 1), fx.swapaxes(0, 1),
             ox.swapaxes(0, 1)))
        hs = hs.swapaxes(0, 1)  # [B,S,H,dh]
        new_cache = dict(zip(("c", "n", "h", "m"), state)) \
            if mode == "prefill" else None

    h = hs.reshape(b, -1, d)
    h = _group_rms(h, params["gn_scale"], nh)
    h = h.astype(x.dtype)
    # Post up/down projection (GeGLU, factor 4/3).
    u1 = jnp.einsum("bsd,de->bse", h, params["w_up1"])
    u2 = jnp.einsum("bsd,de->bse", h, params["w_up2"])
    y = jax.nn.gelu(u1) * u2
    y = constrain(y, "act_batch", "act_seq", "ff")
    return jnp.einsum("bse,ed->bsd", y, params["w_down"]), new_cache


def slstm_cache_defs(cfg: ArchConfig, batch: int):
    nh = _heads(cfg)
    dh = cfg.d_model // nh
    def sdef(init="zeros", scale=None):
        return ParamDef((batch, nh, dh), ("kv_batch", None, None),
                        init=init, scale=scale, dtype=jnp.float32)
    return {"c": sdef(), "n": sdef(), "h": sdef(),
            "m": sdef(init="const", scale=-1e9)}
