"""ResNet / WideResNet (paper Table III students & teachers) in pure JAX.

GroupNorm replaces BatchNorm (no mutable running stats in the functional CL
loop; equivalent behaviour at these scales — noted in DESIGN.md). Params are
pure-array pytrees; the static block plan is derived from the config.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.dacapo_pairs import VisionConfig

_STAGES = {
    18: ((2, 2, 2, 2), "basic"),
    34: ((3, 4, 6, 3), "basic"),
    50: ((3, 4, 6, 3), "bottleneck"),
    101: ((3, 4, 23, 3), "bottleneck"),
}


def block_plan(cfg: VisionConfig) -> List[Tuple[str, int, int, int, int]]:
    """[(kind, cin, mid, cout, stride), ...] — static, derived from config."""
    stages, kind = _STAGES[cfg.depth]
    plan = []
    cin = cfg.base
    for stage, n_blocks in enumerate(stages):
        base = cfg.base * (2 ** stage)
        if kind == "bottleneck":
            mid, cout = base * cfg.width_mult, base * 4
        else:
            mid, cout = base * cfg.width_mult, base * cfg.width_mult
        for b in range(n_blocks):
            stride = 2 if (b == 0 and stage > 0) else 1
            plan.append((kind, cin, mid, cout, stride))
            cin = cout
    return plan


def _conv_def(key, cin, cout, ksize):
    scale = (ksize * ksize * cin) ** -0.5
    return jax.random.normal(key, (ksize, ksize, cin, cout)) * scale


def _gn_def(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _gn(x, p, groups=8):
    c = x.shape[-1]
    g = min(groups, c)
    while c % g:
        g -= 1
    xg = x.reshape(x.shape[:-1] + (g, c // g))
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + 1e-5)
    return xg.reshape(x.shape) * p["scale"] + p["bias"]


def init_resnet(key, cfg: VisionConfig) -> Dict[str, Any]:
    plan = block_plan(cfg)
    keys = iter(jax.random.split(key, 8 + 4 * len(plan)))
    params: Dict[str, Any] = {
        "stem": _conv_def(next(keys), 3, cfg.base,
                          7 if cfg.img_size > 64 else 3),
        "stem_gn": _gn_def(cfg.base),
    }
    blocks: List[Dict[str, Any]] = []
    for kind, cin, mid, cout, stride in plan:
        bp: Dict[str, Any] = {}
        if kind == "basic":
            bp["conv1"] = _conv_def(next(keys), cin, mid, 3)
            bp["gn1"] = _gn_def(mid)
            bp["conv2"] = _conv_def(next(keys), mid, cout, 3)
            bp["gn2"] = _gn_def(cout)
        else:
            bp["conv1"] = _conv_def(next(keys), cin, mid, 1)
            bp["gn1"] = _gn_def(mid)
            bp["conv2"] = _conv_def(next(keys), mid, mid, 3)
            bp["gn2"] = _gn_def(mid)
            bp["conv3"] = _conv_def(next(keys), mid, cout, 1)
            bp["gn3"] = _gn_def(cout)
        if stride != 1 or cin != cout:
            bp["proj"] = _conv_def(next(keys), cin, cout, 1)
            bp["proj_gn"] = _gn_def(cout)
        blocks.append(bp)
    params["blocks"] = blocks
    cfinal = plan[-1][3]
    params["head_w"] = jax.random.normal(
        next(keys), (cfinal, cfg.num_classes)) * cfinal ** -0.5
    params["head_b"] = jnp.zeros((cfg.num_classes,))
    return params


def resnet_forward(params, images, cfg: VisionConfig):
    """images [B,H,W,3] -> logits [B,C]."""
    x = _conv(images, params["stem"], stride=2 if images.shape[1] > 64 else 1)
    x = jax.nn.relu(_gn(x, params["stem_gn"]))
    if images.shape[1] > 64:
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME")
    for bp, (kind, cin, mid, cout, stride) in zip(params["blocks"],
                                                  block_plan(cfg)):
        resid = x
        if kind == "basic":
            y = jax.nn.relu(_gn(_conv(x, bp["conv1"], stride), bp["gn1"]))
            y = _gn(_conv(y, bp["conv2"]), bp["gn2"])
        else:
            y = jax.nn.relu(_gn(_conv(x, bp["conv1"]), bp["gn1"]))
            y = jax.nn.relu(_gn(_conv(y, bp["conv2"], stride), bp["gn2"]))
            y = _gn(_conv(y, bp["conv3"]), bp["gn3"])
        if "proj" in bp:
            resid = _gn(_conv(x, bp["proj"], stride), bp["proj_gn"])
        x = jax.nn.relu(resid + y)
    x = x.mean(axis=(1, 2))
    return x @ params["head_w"] + params["head_b"]


def resnet_flops(cfg: VisionConfig) -> float:
    """Forward-pass MACs*2 at cfg.img_size (conv + fc terms)."""
    h = w = cfg.img_size
    total = 0.0
    stem_k = 7 if cfg.img_size > 64 else 3
    stride0 = 2 if cfg.img_size > 64 else 1
    h, w = h // stride0, w // stride0
    total += 2 * stem_k * stem_k * 3 * cfg.base * h * w
    if cfg.img_size > 64:
        h, w = h // 2, w // 2
    for kind, cin, mid, cout, stride in block_plan(cfg):
        h2, w2 = h // stride, w // stride
        if kind == "basic":
            total += 2 * 9 * cin * mid * h2 * w2
            total += 2 * 9 * mid * cout * h2 * w2
        else:
            total += 2 * cin * mid * h * w
            total += 2 * 9 * mid * mid * h2 * w2
            total += 2 * mid * cout * h2 * w2
        if stride != 1 or cin != cout:
            total += 2 * cin * cout * h2 * w2
        h, w = h2, w2
    total += 2 * block_plan(cfg)[-1][3] * cfg.num_classes
    return total


def resnet_param_count(params) -> int:
    return sum(p.size for p in jax.tree_util.tree_leaves(params))
