"""GQA attention: chunked (online-softmax) flash for train/prefill, sliding
window / local-global variants, logit softcapping, and a sequence-sharded
flash-decode (shard_map + psum combine) for serving.

Pure-jnp implementations here double as the oracles for the Pallas kernels in
``repro.kernels`` and as the CPU-lowerable dry-run path.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed import ParamDef, constrain, current_rules, _STATE
from repro.models.layers import apply_rope, rope_freqs, softcap

NEG_INF = -1e30


# --------------------------------------------------------------------- params
def attn_defs(cfg: ArchConfig):
    """QKV/O weights stored with FUSED (heads*head_dim) output dims: the
    fused dim is always divisible by the TP axis, so odd head counts
    (yi-34b 56H, musicgen 24H, gemma2 8H) still shard their projection
    weights & compute 16-ways instead of replicating (the un-fused layout
    left 14 GiB of yi-34b attention weights replicated per device)."""
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, \
        cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    return {
        "wq": ParamDef((d, h * dh), ("embed", "heads_fused"), dtype=dt),
        "wk": ParamDef((d, kv * dh), ("embed", "kv_fused"), dtype=dt),
        "wv": ParamDef((d, kv * dh), ("embed", "kv_fused"), dtype=dt),
        "wo": ParamDef((h * dh, d), ("heads_fused", "embed"), dtype=dt),
    }


def effective_window(cfg: ArchConfig, layer_idx: int) -> Optional[int]:
    if cfg.local_global_period and cfg.is_local_layer(layer_idx):
        return cfg.local_window
    return cfg.sliding_window


def _qscale(cfg: ArchConfig) -> float:
    return cfg.query_scale or cfg.resolved_head_dim ** -0.5


# ----------------------------------------------------- chunked flash attention
def _attend_block(q, k, v, mask, scale, cap):
    """q [B,Kv,G,qb,D], k/v [B,Kv,T,D], mask [B,1,1,qb,T] -> (o, m, l) fp32."""
    s = jnp.einsum("bkgqd,bktd->bkgqt", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    s = softcap(s, cap)
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)  # [B,Kv,G,qb]
    p = jnp.exp(s - m[..., None])
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgqt,bktd->bkgqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o, m, l


def flash_attention(
    q: jax.Array,  # [B, Sq, H, D]
    k: jax.Array,  # [B, Skv, Kv, D]
    v: jax.Array,  # [B, Skv, Kv, D]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
    q_block: int = 512,
    kv_block: int = 1024,
) -> jax.Array:
    """Chunked online-softmax attention, O(S·window) FLOPs for windowed layers.

    ``q_offset``: absolute position of q[0] relative to k[0] (for decode-append
    this is Skv - Sq).
    """
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    scale = d ** -0.5 if scale is None else scale
    qb = min(q_block, sq)
    n_q = math.ceil(sq / qb)
    pad_q = n_q * qb - sq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    # [nQ, B, Kv, G, qb, D]
    qr = q.reshape(b, n_q, qb, kvh, g, d).transpose(1, 0, 3, 4, 2, 5)
    kt = k.transpose(0, 2, 1, 3)  # [B, Kv, Skv, D]
    vt = v.transpose(0, 2, 1, 3)
    kv_pos = jnp.arange(skv)

    if window is not None:
        # Static-length slice per q block: positions [i*qb - wpad, i*qb + qb).
        wpad = window
        kt_p = jnp.pad(kt, ((0, 0), (0, 0), (wpad, 0), (0, 0)))
        vt_p = jnp.pad(vt, ((0, 0), (0, 0), (wpad, 0), (0, 0)))
        pos_p = jnp.pad(kv_pos, (wpad, 0), constant_values=-10**9)

        def q_step(carry, qi):
            i, qblk = qi
            start = i * qb  # in padded coords == i*qb - wpad in real coords
            kblk = jax.lax.dynamic_slice_in_dim(kt_p, start, wpad + qb, axis=2)
            vblk = jax.lax.dynamic_slice_in_dim(vt_p, start, wpad + qb, axis=2)
            pblk = jax.lax.dynamic_slice_in_dim(pos_p, start, wpad + qb, axis=0)
            qpos = q_offset + i * qb + jnp.arange(qb)
            mask = (pblk[None, :] <= qpos[:, None]) & (
                pblk[None, :] > qpos[:, None] - window)
            mask = mask[None, None, None]
            o, m, l = _attend_block(qblk, kblk, vblk, mask, scale, logit_softcap)
            out = o / jnp.maximum(l[..., None], 1e-30)
            return carry, out.astype(q.dtype)

        _, outs = jax.lax.scan(
            q_step, None, (jnp.arange(n_q), qr))
    else:
        # Two-level flash: outer scan over q blocks, inner scan over kv
        # blocks with online-softmax carries — peak logits memory is
        # [B,Kv,G,qb,kv_block] regardless of sequence length.
        kvb = min(kv_block, skv)
        n_kv = math.ceil(skv / kvb)
        pad_kv = n_kv * kvb - skv
        kt_p = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        vt_p = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        kr = kt_p.reshape(b, kvh, n_kv, kvb, d).transpose(2, 0, 1, 3, 4)
        vr = vt_p.reshape(b, kvh, n_kv, kvb, d).transpose(2, 0, 1, 3, 4)

        def q_step(carry, qi):
            i, qblk = qi
            qpos = q_offset + i * qb + jnp.arange(qb)

            def kv_step(acc, kj):
                j, kblk, vblk = kj
                o_acc, m_acc, l_acc = acc
                kpos = j * kvb + jnp.arange(kvb)
                mask = kpos[None, :] < skv
                if causal:
                    mask &= kpos[None, :] <= qpos[:, None]
                else:
                    mask = jnp.broadcast_to(mask, (qb, kvb))
                mask = mask[None, None, None]
                o, m, l = _attend_block(qblk, kblk, vblk, mask, scale,
                                        logit_softcap)
                m_new = jnp.maximum(m_acc, m)
                alpha = jnp.exp(m_acc - m_new)
                beta = jnp.exp(m - m_new)
                o_acc = o_acc * alpha[..., None] + o * beta[..., None]
                l_acc = l_acc * alpha + l * beta
                return (o_acc, m_new, l_acc), None

            o0 = jnp.zeros((b, kvh, g, qb, d), jnp.float32)
            m0 = jnp.full((b, kvh, g, qb), NEG_INF)
            l0 = jnp.zeros((b, kvh, g, qb))
            (o_acc, m_acc, l_acc), _ = jax.lax.scan(
                kv_step, (o0, m0, l0), (jnp.arange(n_kv), kr, vr))
            out = o_acc / jnp.maximum(l_acc[..., None], 1e-30)
            return carry, out.astype(q.dtype)

        _, outs = jax.lax.scan(q_step, None, (jnp.arange(n_q), qr))

    # outs: [nQ, B, Kv, G, qb, D] -> [B, S, H, D]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, n_q * qb, h, d)
    return out[:, :sq]


# ------------------------------------------------------------- decode (1 tok)
def _local_decode(q, k, v, valid, scale, cap):
    """q [B,Kv,G,D]; k/v [B,Kv,L,D] (head-major cache layout: the attention
    einsums consume it directly, no per-layer [L,Kv]->[Kv,L] transposes) ->
    partial (o, m, l) fp32.

    No explicit .astype on k/v: a materialized fp32 copy of the KV cache
    (and XLA convert chains around the cache update) tripled decode traffic;
    fp32 accumulation comes from preferred_element_type alone."""
    s = jnp.einsum("bkgd,bkld->bkgl", q, k,
                   preferred_element_type=jnp.float32)
    s = s * scale
    s = softcap(s, cap)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.where(valid[:, None, None, :], jnp.exp(s - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgl,bkld->bkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o, m, l


def flash_decode(
    q: jax.Array,  # [B, H, D] (one new token)
    k_cache: jax.Array,  # [B, Kv, L, D] (L possibly sharded over axis_names)
    v_cache: jax.Array,
    kv_pos: jax.Array,  # [L] int32; -1 = empty slot
    t,  # scalar int32: current position
    *,
    window: Optional[int],
    logit_softcap: Optional[float],
    scale: float,
    axis_names: Tuple[str, ...] = (),
) -> jax.Array:
    """Flash-decoding: per-shard partial softmax + psum combine over the
    sequence-sharded KV axis. With no axis_names this is plain local attention.
    """
    b, h, d = q.shape
    kvh = k_cache.shape[1]
    g = h // kvh
    qg = q.reshape(b, kvh, g, d)
    valid = (kv_pos >= 0) & (kv_pos <= t)
    if window is not None:
        valid &= kv_pos > t - window
    valid = jnp.broadcast_to(valid[None, :], (b, kv_pos.shape[0]))
    o, m, l = _local_decode(qg, k_cache, v_cache, valid, scale, logit_softcap)
    if axis_names:
        # Cross-shard online-softmax combine: one tiny psum per layer.
        m_g = jax.lax.pmax(m, axis_names)
        corr = jnp.exp(m - m_g)
        l = jax.lax.psum(l * corr, axis_names)
        o = jax.lax.psum(o * corr[..., None], axis_names)
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, h, d).astype(q.dtype)


def sharded_flash_decode(q, k_cache, v_cache, kv_pos, t, *, window,
                         logit_softcap, scale):
    """Dispatch flash_decode under shard_map when KV-seq sharding rules are
    active; falls back to local computation otherwise."""
    rules = current_rules()
    mesh = getattr(_STATE, "mesh", None)
    seq_axes = rules.get("kv_seq") if rules else None
    if mesh is None or seq_axes is None:
        return flash_decode(q, k_cache, v_cache, kv_pos, t, window=window,
                            logit_softcap=logit_softcap, scale=scale)
    if isinstance(seq_axes, str):
        seq_axes = (seq_axes,)
    batch_axes = rules.get("kv_batch")

    q_spec = P(batch_axes, None, None)
    kv_spec = P(batch_axes, None, seq_axes, None)
    pos_spec = P(seq_axes)
    fn = functools.partial(
        flash_decode, window=window, logit_softcap=logit_softcap, scale=scale,
        axis_names=tuple(seq_axes))
    return jax.shard_map(
        fn, mesh=mesh,
        in_specs=(q_spec, kv_spec, kv_spec, pos_spec, P()),
        out_specs=q_spec,
        check_vma=False,
    )(q, k_cache, v_cache, kv_pos, t)


def seq_parallel_flash(q, k, v, *, window, logit_softcap, scale):
    """Context-parallel attention for archs whose heads don't divide the TP
    axis: q/k/v are sequence-sharded over the 'attn_seq' axes; each shard
    all-gathers K/V (tiled) and runs chunked flash on its local queries with
    the appropriate causal offset. One all-gather of K/V per layer; query
    compute perfectly seq-balanced (causal skew noted in DESIGN.md §5)."""
    rules = current_rules()
    mesh = getattr(_STATE, "mesh", None)
    seq_axes = rules.get("attn_seq") if rules else None
    if mesh is None or seq_axes is None:
        return flash_attention(q, k, v, causal=True, window=window,
                               logit_softcap=logit_softcap, scale=scale)
    if isinstance(seq_axes, str):
        seq_axes = (seq_axes,)
    batch_axes = rules.get("act_batch")

    def local_attn(ql, kl, vl):
        kf = jax.lax.all_gather(kl, seq_axes, axis=1, tiled=True)
        vf = jax.lax.all_gather(vl, seq_axes, axis=1, tiled=True)
        idx = jax.lax.axis_index(seq_axes[0])
        offset = idx * ql.shape[1]
        return flash_attention(q=ql, k=kf, v=vf, causal=True, window=window,
                               logit_softcap=logit_softcap, scale=scale,
                               q_offset=offset)

    spec = P(batch_axes, seq_axes, None, None)
    return jax.shard_map(local_attn, mesh=mesh, in_specs=(spec, spec, spec),
                         out_specs=spec, check_vma=False)(q, k, v)


# --------------------------------------------------------------- full forward
def cache_slot(t, capacity: int):
    return jnp.mod(t, capacity)


def attention_forward(
    params,
    x: jax.Array,  # [B, S, D]
    cfg: ArchConfig,
    layer_idx: int,
    *,
    positions: jax.Array,  # [S] (train/prefill) or scalar t (decode)
    mode: str,  # train | prefill | decode
    cache: Optional[dict] = None,
    cache_capacity: int = 0,
):
    window = effective_window(cfg, layer_idx)
    scale = _qscale(cfg)
    dh = cfg.resolved_head_dim

    b, s, _ = x.shape
    h, kvh = cfg.num_heads, cfg.num_kv_heads
    q = jnp.einsum("bsd,de->bse", x, params["wq"])
    k = jnp.einsum("bsd,de->bse", x, params["wk"])
    v = jnp.einsum("bsd,de->bse", x, params["wv"])
    q = constrain(q, "act_batch", "act_seq", "heads_fused")
    k = constrain(k, "act_batch", "act_seq", "kv_fused")
    v = constrain(v, "act_batch", "act_seq", "kv_fused")
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, kvh, dh)
    v = v.reshape(b, s, kvh, dh)
    q = constrain(q, "act_batch", "act_seq", "heads", None)
    k = constrain(k, "act_batch", "act_seq", "kv_heads", None)
    v = constrain(v, "act_batch", "act_seq", "kv_heads", None)

    if cfg.pos == "rope":
        sin, cos = rope_freqs(positions, dh, cfg.rope_theta)
        if mode == "decode":
            rq = (sin[None, None, :], cos[None, None, :])  # [1,1,D/2]
        else:
            rq = (sin[None, :, None, :], cos[None, :, None, :])
        q = apply_rope(q, *rq)
        k = apply_rope(k, *rq)

    if mode == "decode":
        # x is [B, 1, D]; insert (k, v) at slot t mod capacity, then attend.
        t = positions
        capacity = cache["k"].shape[2]  # [B, Kv, L, D] head-major layout
        slot = cache_slot(t, capacity)
        # The barrier pins the rope fp32->bf16 convert to the tiny new-token
        # tensors; without it XLA folds the convert into the cache-update
        # fusion and round-trips the whole stacked cache through fp32
        # (~1 GiB/layer/token of pure convert traffic).
        k_ins, v_ins = jax.lax.optimization_barrier(
            (k.astype(cache["k"].dtype).transpose(0, 2, 1, 3),
             v.astype(cache["v"].dtype).transpose(0, 2, 1, 3)))
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_ins, slot, axis=2)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_ins, slot, axis=2)
        kv_pos = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], t[None].astype(cache["pos"].dtype), slot, axis=0)
        out = sharded_flash_decode(
            q[:, 0], k_cache, v_cache, kv_pos, t,
            window=window, logit_softcap=cfg.attn_softcap, scale=scale)
        out = out[:, None]  # [B, 1, H, D]
        new_cache = {"k": k_cache, "v": v_cache, "pos": kv_pos}
    else:
        rules = current_rules()
        if rules and rules.get("attn_seq"):
            out = seq_parallel_flash(
                q, k, v, window=window, logit_softcap=cfg.attn_softcap,
                scale=scale)
        else:
            out = flash_attention(
                q, k, v, causal=True, window=window,
                logit_softcap=cfg.attn_softcap, scale=scale)
        new_cache = None
        if mode == "prefill":
            capacity = cache["k"].shape[2] if cache is not None \
                else cache_capacity  # [B, Kv, L, D]
            new_cache = prefill_cache(cfg, k, v, window, capacity)

    out = constrain(out.reshape(b, -1, h * dh),
                    "act_batch", "act_seq", "heads_fused")
    y = jnp.einsum("bse,ed->bsd", out, params["wo"])
    return y, new_cache


def prefill_cache(cfg: ArchConfig, k, v, window, capacity: int):
    """Lay out prefilled K/V into the (ring-buffer, head-major) decode cache
    format [B, Kv, L, D]."""
    b, s, kvh, dh = k.shape
    if window is not None:
        capacity = min(capacity, window)
    dt = jnp.dtype(cfg.dtype)
    k, v = k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)  # [B,Kv,S,D]
    if s >= capacity:
        # Keep last `capacity` positions at slots p mod capacity.
        k_tail, v_tail = k[:, :, s - capacity:], v[:, :, s - capacity:]
        shift = s % capacity
        k_c = jnp.roll(k_tail, shift, axis=2)
        v_c = jnp.roll(v_tail, shift, axis=2)
        pos_tail = jnp.arange(s - capacity, s)
        pos = jnp.roll(pos_tail, shift, axis=0)
    else:
        k_c = jnp.pad(k, ((0, 0), (0, 0), (0, capacity - s), (0, 0)))
        v_c = jnp.pad(v, ((0, 0), (0, 0), (0, capacity - s), (0, 0)))
        pos = jnp.concatenate(
            [jnp.arange(s), jnp.full((capacity - s,), -1, jnp.int32)])
    return {"k": k_c.astype(dt), "v": v_c.astype(dt),
            "pos": pos.astype(jnp.int32)}


def attn_cache_defs(cfg: ArchConfig, layer_idx: int, batch: int, capacity: int):
    window = effective_window(cfg, layer_idx)
    cap = min(capacity, window) if window is not None else capacity
    kvh, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    return {
        "k": ParamDef((batch, kvh, cap, dh),
                      ("kv_batch", "kv_heads_cache", "kv_seq", None),
                      init="zeros", dtype=dt),
        "v": ParamDef((batch, kvh, cap, dh),
                      ("kv_batch", "kv_heads_cache", "kv_seq", None),
                      init="zeros", dtype=dt),
        "pos": ParamDef((cap,), ("kv_seq",), init="const", scale=-1,
                        dtype=jnp.int32),
    }
