"""Shared building blocks: norms, positions, activations, MLPs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed import ParamDef, constrain


def rms_norm(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm_defs(cfg: ArchConfig, d: int):
    if cfg.norm == "rmsnorm":
        return {"scale": ParamDef((d,), (None,), init="zeros", dtype=jnp.float32)}
    return {
        "scale": ParamDef((d,), (None,), init="ones", dtype=jnp.float32),
        "bias": ParamDef((d,), (None,), init="zeros", dtype=jnp.float32),
    }


def apply_norm(params, x, cfg: ArchConfig):
    if cfg.norm == "rmsnorm":
        return rms_norm(x, params["scale"])
    return layer_norm(x, params["scale"], params["bias"])


def softcap(x, cap):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ------------------------------------------------------------------- positions
def rope_freqs(positions, head_dim: int, theta: float):
    """positions [*(shape)] -> (sin, cos) [*shape, head_dim/2], fp32."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x [..., S, H, D]; sin/cos broadcastable [..., S, 1, D/2]."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sincos_positions(positions, d_model: int):
    half = d_model // 2
    inv = 1.0 / (10_000.0 ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ------------------------------------------------------------------------ MLPs
def mlp_defs(cfg: ArchConfig):
    d, f = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "w_gate": ParamDef((d, f), ("embed", "ff"), dtype=dt),
            "w_up": ParamDef((d, f), ("embed", "ff"), dtype=dt),
            "w_down": ParamDef((f, d), ("ff", "embed"), dtype=dt),
        }
    if cfg.mlp == "gelu":
        return {
            "w_up": ParamDef((d, f), ("embed", "ff"), dtype=dt),
            "b_up": ParamDef((f,), ("ff",), init="zeros", dtype=dt),
            "w_down": ParamDef((f, d), ("ff", "embed"), dtype=dt),
            "b_down": ParamDef((d,), (None,), init="zeros", dtype=dt),
        }
    raise ValueError(cfg.mlp)


def mlp_forward(params, x, cfg: ArchConfig):
    """x [B, S, D] -> [B, S, D]; intermediate sharded over 'ff'."""
    if cfg.mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, params["w_up"])
        h = act(g) * u
        h = constrain(h, "act_batch", "act_seq", "ff")
        return jnp.einsum("bsf,fd->bsd", h, params["w_down"])
    h = jnp.einsum("bsd,df->bsf", x, params["w_up"]) + params["b_up"]
    h = jax.nn.gelu(h)
    h = constrain(h, "act_batch", "act_seq", "ff")
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"]) + params["b_down"]
