"""Optimizers (SGD-momentum — the paper's retraining choice — and AdamW),
LR schedules, all as pure pytree functions; fp32 master state regardless of
param dtype (bf16 params keep fp32 moments + master copy)."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"  # adamw | sgd
    lr: float = 3e-4
    momentum: float = 0.9
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


def schedule(step, cfg: OptimizerConfig):
    """Linear warmup + cosine decay."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params, cfg: OptimizerConfig):
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    if cfg.name == "sgd":
        return {"mu": jax.tree_util.tree_map(f32, params)}
    return {
        "mu": jax.tree_util.tree_map(f32, params),
        "nu": jax.tree_util.tree_map(f32, params),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def apply_updates(params, grads, state, step, cfg: OptimizerConfig):
    """Returns (new_params, new_state, metrics)."""
    lr = schedule(step, cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    grads = jax.tree_util.tree_map(
        lambda g: g.astype(jnp.float32) * scale, grads)

    if cfg.name == "sgd":
        new_mu = jax.tree_util.tree_map(
            lambda m, g: cfg.momentum * m + g, state["mu"], grads)
        new_params = jax.tree_util.tree_map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, new_mu)
        return new_params, {"mu": new_mu}, {"lr": lr, "grad_norm": gnorm}

    t = jnp.asarray(step, jnp.float32) + 1.0
    b1, b2 = cfg.beta1, cfg.beta2
    new_mu = jax.tree_util.tree_map(
        lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
    new_nu = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state["nu"], grads)

    def upd(p, m, v):
        mhat = m / (1 - b1 ** t)
        vhat = v / (1 - b2 ** t)
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        if cfg.weight_decay:
            step_ = step_ + cfg.weight_decay * p32
        return (p32 - lr * step_).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, new_mu, new_nu)
    return new_params, {"mu": new_mu, "nu": new_nu}, \
        {"lr": lr, "grad_norm": gnorm}
