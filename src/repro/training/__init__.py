from repro.training.optimizer import OptimizerConfig, init_opt_state, apply_updates  # noqa: F401
from repro.training.train_state import TrainState  # noqa: F401
