"""Train state container + sharding-spec derivation."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import param_specs
from repro.training.optimizer import OptimizerConfig, init_opt_state


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array

    @classmethod
    def create(cls, params, opt_cfg: OptimizerConfig) -> "TrainState":
        return cls(params=params,
                   opt_state=init_opt_state(params, opt_cfg),
                   step=jnp.zeros((), jnp.int32))


def train_state_specs(param_defs) -> TrainState:
    """PartitionSpec tree mirroring TrainState (moments shard like params)."""
    p_specs = param_specs(param_defs)
    return TrainState(
        params=p_specs,
        opt_state={"mu": p_specs, "nu": p_specs},
        step=P(),
    )


def train_state_specs_sgd(param_defs) -> TrainState:
    p_specs = param_specs(param_defs)
    return TrainState(params=p_specs, opt_state={"mu": p_specs}, step=P())
