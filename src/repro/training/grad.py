"""Gradient machinery for scale: microbatched accumulation (sequential over
microbatches via lax.scan, so peak activation memory is one microbatch) and
int8 error-feedback gradient compression for the slow inter-pod links."""
from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp


def microbatched_grads(loss_fn: Callable, params, batch,
                       num_microbatches: int,
                       constrain_grads: Optional[Callable] = None):
    """loss_fn(params, microbatch) -> (loss, metrics). Returns mean grads.

    The microbatch loop is a lax.scan, so only one microbatch's activations
    are live at a time — the standard memory lever for long-sequence
    training. ``constrain_grads`` (ZeRO-2): a pytree->pytree sharding
    constraint applied to the gradient accumulator so each microbatch's
    grads reduce-scatter into FSDP-sharded storage layer-by-layer instead of
    living replicated.
    """
    if num_microbatches <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        if constrain_grads is not None:
            grads = constrain_grads(grads)
        return loss, metrics, grads

    def split(x):
        b = x.shape[0]
        assert b % num_microbatches == 0, (b, num_microbatches)
        return x.reshape((num_microbatches, b // num_microbatches)
                         + x.shape[1:])

    micro = jax.tree_util.tree_map(split, batch)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def body(carry, mb):
        acc, loss_acc, metrics_acc = carry
        (loss, metrics), grads = grad_fn(params, mb)
        acc = jax.tree_util.tree_map(
            lambda a, g: a + g.astype(jnp.float32), acc, grads)
        if constrain_grads is not None:
            acc = constrain_grads(acc)
        metrics_acc = jax.tree_util.tree_map(
            lambda a, m: a + m / num_microbatches, metrics_acc, metrics)
        return (acc, loss_acc + loss / num_microbatches, metrics_acc), None

    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if constrain_grads is not None:
        zeros = constrain_grads(zeros)
    (grads, loss, metrics), _ = jax.lax.scan(
        body, (zeros, jnp.zeros(()), _metrics_zeros(loss_fn, params, micro)),
        micro)
    grads = jax.tree_util.tree_map(lambda g: g / num_microbatches, grads)
    return loss, metrics, grads


def _metrics_zeros(loss_fn, params, micro):
    shapes = jax.eval_shape(
        loss_fn, params, jax.tree_util.tree_map(lambda x: x[0], micro))[1]
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, jnp.float32), shapes)


# ----------------------------------------------------- gradient compression
def compress_int8(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback int8 quantization: returns (q, scale, new_err)."""
    g32 = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_err = g32 - q.astype(jnp.float32) * scale
    return q, scale, new_err


def compressed_cross_pod_mean(grads, err_state, mesh, pod_axis: str = "pod"):
    """All-reduce gradients across the pod axis with int8 error-feedback
    compression (shard_map over the pod axis; intra-pod reduction has already
    happened via the loss mean). Returns (grads, new_err_state).

    Crossing the inter-pod links at 8 bits cuts the slowest collective's
    bytes 4x vs fp32 (2x vs bf16); the quantization error is re-injected next
    step, which keeps SGD unbiased in expectation.
    """
    npods = mesh.shape[pod_axis]

    def reduce_leaf(g, err):
        q, scale, new_err = compress_int8(g, err)
        deq = q.astype(jnp.float32) * scale
        total = jax.lax.psum(deq, pod_axis)
        return total / npods, new_err

    pairs = jax.tree_util.tree_map(reduce_leaf, grads, err_state)
    new_grads = jax.tree_util.tree_map(
        lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree_util.tree_map(
        lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_err
