"""TraceReplayer — what-if phase-time prediction from recorded traces.

The trace spine (core/trace.py) records each phase as the ordered stream
of program/charge :class:`~repro.core.trace.TraceEvent`s the
:class:`~repro.core.dispatch.PhasePlan` executed, plus the phase's clock
boundaries. Because the plan's virtual clock is nothing but two per-role
float accumulators walked in issue order, replaying the same float-add
sequence reconstructs every phase end **bit-exactly** — in both dispatch
semantics (sequential SUM of the T-SA chain; concurrent
``max(t_TSA, t_BSA)``, both floored by pacing). That exactness is the
anchor; on top of it the replayer answers *what-if* questions without
executing anything:

* :meth:`TraceReplayer.predict` re-prices the decision-dependent events of
  a phase under a **candidate** :class:`~repro.core.decision.Decision` /
  ``FleetDecision`` — sample budgets re-scale each event by its recorded
  unit cost (``cost_s / units``), row/precision changes re-scale by the
  estimator's time ratios, profiling overhead is replaced outright — and
  replays the re-priced stream through the same clock arithmetic;
* ``from_units=True`` prices events from the trace-wide per-label cost
  histograms (:meth:`TraceReplayer.unit_costs`) instead of their recorded
  costs — the predictive mode whose concurrent-phase error the replay
  bench bounds (< 5% MAPE);
* ``mode=`` replays a trace under the *other* dispatch semantics (e.g.
  how much phase time concurrent overlap would save a sequential run);
* :meth:`TraceReplayer.calibrate` fits per-kernel scale factors — the
  Σwall/Σcost ratio of measured host wall time to modeled virtual cost,
  per label — and hands back a :class:`Calibration` that wraps the cycle
  model in a :class:`~repro.core.estimator.CalibratedEstimator` and
  corrects a :class:`~repro.core.estimator.PlacementCostModel`'s seconds.

The ``"dacapo-replay"`` allocation policy (core/allocation.py) drives
:meth:`predict` as its scoring oracle: K candidate decisions per phase are
priced by replay instead of execution, and the *measured* wall time of
that replay is charged to ``profile_cost_s`` — profiling overhead as a
real cost, not an assumed knob.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.decision import FleetDecision, as_decision
from repro.core.estimator import CalibratedEstimator, PlacementCostModel
from repro.core.trace import SessionTrace, TraceEvent, summarize_decision

# Labels whose cost scales with a temporal-plane budget; maps each to the
# candidate-summary key holding the new unit count.
_BUDGET_KEYS = {
    "retrain": None,  # batches — derived from hp (see _candidate_units)
    "label": "total_label_samples",
    "acc_label": "total_label_samples",
    "valid": "valid_samples",
}
# Forward-pass program labels (one model forward per unit).
_FORWARD_LABELS = ("valid", "label", "acc_label", "score")


@dataclasses.dataclass(frozen=True)
class ReplayNode:
    """One node of a phase's dependency DAG: an event + what it waits on.

    ``deps`` holds node ids (indices into the phase's node list); an empty
    tuple means the node starts at the phase start. The virtual ``end``
    node (id -1 in :meth:`TraceReplayer.dag`'s return) joins the chain
    tails — the phase-end barrier.
    """

    id: int
    event: TraceEvent
    deps: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Per-kernel scale factors fitted from a trace's measured wall times.

    ``scales[label]`` is Σwall/Σcost over that label's events — how many
    host wall seconds one modeled virtual second actually took;
    ``global_scale`` is the same ratio over every measured event. Use
    :meth:`seconds` to correct a modeled cost, :meth:`estimator` to wrap
    the cycle model, :meth:`placement_model` to correct the manager's
    placement economics.
    """

    scales: Dict[str, float]
    global_scale: float = 1.0

    def seconds(self, label: str, cost_s: float) -> float:
        """Corrected (wall-calibrated) seconds for a modeled cost."""
        return cost_s * self.scales.get(label, self.global_scale)

    def estimator(self, base=None) -> CalibratedEstimator:
        """The cycle model wrapped with the fitted forward/train scales
        (forward: pooled over the forward-pass program labels; train:
        the ``"retrain"`` scale; missing fits fall back to global)."""
        fwd = [self.scales[lb] for lb in _FORWARD_LABELS
               if lb in self.scales]
        return CalibratedEstimator(
            base=base if base is not None else CalibratedEstimator().base,
            forward_scale=(sum(fwd) / len(fwd) if fwd else self.global_scale),
            train_scale=self.scales.get("retrain", self.global_scale))

    def placement_model(self, model: PlacementCostModel
                        ) -> PlacementCostModel:
        """``model`` with its migration cost re-expressed in calibrated
        seconds, so placement trades off against what moves actually
        cost on this host."""
        return dataclasses.replace(
            model,
            migration_cost_s=model.migration_cost_s * self.global_scale)


class TraceReplayer:
    """Replays a recorded :class:`~repro.core.trace.SessionTrace`.

    ``estimator``/``student``/``teacher``/``hp`` are optional context for
    candidate re-pricing: the estimator + model configs enable
    row/precision re-scaling of program costs, ``hp`` (a
    :class:`~repro.core.allocation.CLHyperParams`) enables deriving a
    candidate's retrain batch count from its sample budget. Without them
    :meth:`predict` still re-prices by unit ratios alone.
    """

    def __init__(self, trace: SessionTrace, estimator=None, student=None,
                 teacher=None, hp=None):
        self.trace = trace
        self.estimator = estimator
        self.student = student
        self.teacher = teacher
        self.hp = hp

    def __len__(self) -> int:
        return len(self.trace.phases)

    # ----------------------------------------------------------------- DAG
    def dag(self, index: int) -> Dict[str, object]:
        """The phase's per-role dependency DAG.

        Sequential dispatch is one serial chain (every event waits on the
        previous — the single seed clock). Concurrent dispatch is two
        serial chains — the T-SA chain and the B-SA chain, each rooted at
        the phase start — joined by the phase-end barrier. Returns
        ``{"nodes": [ReplayNode...], "tails": [ids the end joins]}``.
        """
        phase = self.trace.phases[index]
        nodes: List[ReplayNode] = []
        if phase.mode == "sequential":
            for i, e in enumerate(phase.events):
                nodes.append(ReplayNode(
                    id=i, event=e, deps=(i - 1,) if i else ()))
            tails = [len(nodes) - 1] if nodes else []
            return {"nodes": nodes, "tails": tails}
        last: Dict[str, int] = {}
        for i, e in enumerate(phase.events):
            deps = (last[e.role],) if e.role in last else ()
            nodes.append(ReplayNode(id=i, event=e, deps=deps))
            last[e.role] = i
        return {"nodes": nodes, "tails": sorted(last.values())}

    # --------------------------------------------------------- exact replay
    def phase_time(self, index: int) -> float:
        """The phase's end clock, reconstructed bit-exactly by replaying
        the recorded event stream through the plan's own float-add
        sequence (see :meth:`predict` with no candidate)."""
        return self.predict(index)

    def durations(self) -> List[float]:
        """Replayed duration (end - start) of every phase."""
        return [self.phase_time(i) - p.start
                for i, p in enumerate(self.trace.phases)]

    # ----------------------------------------------------------- prediction
    def unit_costs(self) -> Dict[str, float]:
        """Trace-wide per-label cost histograms collapsed to unit costs:
        Σcost/Σunits over every event carrying a unit count — the virtual
        seconds one frame/sample/batch of each kernel costs."""
        cost: Dict[str, float] = {}
        units: Dict[str, float] = {}
        for e in self.trace.events():
            if e.units > 0:
                cost[e.label] = cost.get(e.label, 0.0) + e.cost_s
                units[e.label] = units.get(e.label, 0.0) + e.units
        return {lb: cost[lb] / units[lb] for lb in cost if units[lb] > 0}

    def predict(self, index: int, decision=None, mode: Optional[str] = None,
                from_units: bool = False) -> float:
        """Predicted end clock of phase ``index``.

        With every argument at its default this is the exact replay —
        bitwise equal to the recorded ``end``. ``decision`` re-prices the
        decision-dependent events under a candidate
        :class:`~repro.core.decision.Decision` (or ``FleetDecision``,
        matched to events by lane); ``mode`` replays under the other
        dispatch semantics; ``from_units`` prices unit-carrying events
        from the trace-wide histograms instead of their recorded costs.
        """
        phase = self.trace.phases[index]
        cands = self._candidate_summaries(decision)
        unit = self.unit_costs() if (from_units or cands) else {}
        now = phase.start
        b_sa = 0.0
        for e in phase.events:
            cost = self._event_cost(e, phase, cands, unit, from_units)
            if e.role == "t_sa":
                now += cost
            else:
                b_sa += cost
        end = now
        if (mode or phase.mode) == "concurrent":
            end = max(end, phase.start + b_sa)
        return max(end, phase.floor)

    def predict_duration(self, index: int, decision=None,
                         mode: Optional[str] = None,
                         from_units: bool = False) -> float:
        return (self.predict(index, decision, mode, from_units)
                - self.trace.phases[index].start)

    # ------------------------------------------------------------ repricing
    def _candidate_summaries(self, decision) -> Dict[object, dict]:
        """Candidate decision(s) keyed by lane (``None`` = any lane)."""
        if decision is None:
            return {}
        if isinstance(decision, FleetDecision):
            return {i: summarize_decision(d)
                    for i, d in enumerate(decision.per_lane())}
        summary = summarize_decision(as_decision(decision))
        return {None: summary, 0: summary}

    def _candidate_units(self, e: TraceEvent, cand: dict) -> Optional[float]:
        """The candidate's unit count for a budget-scaled event (None:
        the event does not scale with a temporal budget)."""
        if e.label not in _BUDGET_KEYS:
            return None
        if e.label == "retrain":
            if self.hp is None:
                return None  # can't derive a batch count
            epochs = cand.get("retrain_epochs") or self.hp.epochs
            return float(epochs
                         * (cand["retrain_samples"] // self.hp.sgd_batch))
        return float(cand[_BUDGET_KEYS[e.label]])

    def _model_ratio(self, e: TraceEvent, old: dict, cand: dict) -> float:
        """Cost ratio for a candidate's row/precision change, from the
        estimator's time model (1.0 when nothing changed or context is
        missing)."""
        if self.estimator is None or not old:
            return 1.0
        rows_key = "rows_tsa" if e.role == "t_sa" else "rows_bsa"
        prec_key = ("labeling_precision" if e.label == "label"
                    else "inference_precision")
        old_rows, new_rows = old.get(rows_key), cand.get(rows_key)
        old_prec, new_prec = old.get(prec_key), cand.get(prec_key)
        if (old_rows, old_prec) == (new_rows, new_prec):
            return 1.0
        if not old_rows or not new_rows or not old_prec or not new_prec:
            return 1.0  # unresolved rows: the offline split, unchanged
        cfg = self.teacher if e.label == "label" else self.student
        if cfg is None:
            return 1.0
        if e.label == "retrain":
            batch = self.hp.sgd_batch if self.hp is not None else 32
            t_old = self.estimator.train_step_time(cfg, old_rows, old_prec,
                                                   batch)
            t_new = self.estimator.train_step_time(cfg, new_rows, new_prec,
                                                   batch)
        else:
            t_old = self.estimator.forward_time(cfg, old_rows, old_prec)
            t_new = self.estimator.forward_time(cfg, new_rows, new_prec)
        return t_new / t_old if t_old > 0 else 1.0

    def _event_cost(self, e: TraceEvent, phase, cands: Dict[object, dict],
                    unit: Dict[str, float], from_units: bool) -> float:
        cost = e.cost_s
        if from_units and e.units > 0 and e.label in unit:
            cost = unit[e.label] * e.units
        if not cands:
            return cost
        cand = cands.get(e.lane if e.lane is not None else None,
                         cands.get(None))
        if cand is None:
            return cost
        if e.label == "profile":
            return float(cand.get("profile_cost_s") or 0.0)
        new_units = self._candidate_units(e, cand)
        if new_units is not None:
            if e.units > 0:
                cost = cost * (new_units / e.units)
            elif e.label in unit:
                cost = unit[e.label] * new_units
        old = {}
        if phase.decisions:
            lane = e.lane if e.lane is not None else 0
            if lane < len(phase.decisions):
                old = phase.decisions[lane]
        return cost * self._model_ratio(e, old, cand)

    # ---------------------------------------------------------- calibration
    def calibrate(self) -> Calibration:
        """Fit per-kernel wall/cost scale factors from the trace's
        measured wall times (program issue walls; the retrain charge's
        measured ``fit`` wall). Labels with no measured wall or no modeled
        cost are left to the global scale."""
        wall: Dict[str, float] = {}
        cost: Dict[str, float] = {}
        for e in self.trace.events():
            if e.wall_s > 0 and e.cost_s > 0:
                wall[e.label] = wall.get(e.label, 0.0) + e.wall_s
                cost[e.label] = cost.get(e.label, 0.0) + e.cost_s
        scales = {lb: wall[lb] / cost[lb] for lb in wall if cost[lb] > 0}
        total_wall = sum(wall.values())
        total_cost = sum(cost[lb] for lb in wall)
        return Calibration(
            scales=scales,
            global_scale=(total_wall / total_cost if total_cost > 0
                          else 1.0))
