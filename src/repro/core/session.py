"""CLSession — the continuous-learning engine (paper Fig. 4 + Algorithm 1).

Methodology mirrors the paper's evaluation split (§VII-A): the *virtual
clock* advances by phase durations computed from the performance estimator on
the FULL model configs (Table III / Table IV hardware), while the *learning
dynamics* (inference, labeling, retraining, accuracy) execute on reduced
same-family twins over the synthetic drift stream — "integrating hardware
simulation and GPU kernel execution" exactly as the paper's system simulator
does, with JAX/CPU in the GPU role.

Layering (see ROADMAP.md "Architecture"):

    CLSystemSpec ──build()──▶ CLSession ──executes──▶ Decision
                               │    ▲          (SpatialPlan × TemporalPlan)
                     kernels ◀─┘    └── PhaseFeedback ◀── AllocationPolicy
             (core/kernel.py)                        (core/allocation.py)

The engine is policy-free: it consumes the two-plane
:class:`~repro.core.decision.Decision` the bound
:class:`~repro.core.allocation.AllocationPolicy` emits (flat legacy
``AllocationDecision``s are lifted via their ``.split()`` facade) — the
spatial plane carries the T-SA/B-SA row split, per-kernel MX precisions and
mesh re-fission intent; the temporal plane carries sample budgets, pacing,
retraining depth and profiling cost — and reports ``PhaseFeedback`` (with
the engine-side ``drifted`` verdict) back. When constructed
with a multi-device ``mesh``, the engine calls
:func:`~repro.core.partition.partition_mesh` to fission the mesh into T-SA /
B-SA sub-meshes and binds each kernel to its sub-accelerator (re-partitioning
online if a decision changes the split); on a single device the partition
degenerates to time-sharing, the paper's own fallback.

Execution goes through the dispatch layer (core/dispatch.py): each phase is
a :class:`~repro.core.dispatch.PhasePlan` the loop builds as it goes — kernel
programs are *dispatched* (issued async, returning device arrays) and host
values are *collected* only at the phase-end barrier where ``PhaseFeedback``
needs them. ``dispatch="sequential"`` (default) preserves the seed's serial
virtual-clock accounting bit-for-bit; ``dispatch="concurrent"`` charges
``max(t_TSA, t_BSA)`` per phase — the paper's Fig. 4 overlap of B-SA serving
with T-SA labeling/retraining — and fuses score windows into batched
inference calls.

Frame access goes through the data plane (data/pipeline.py): ``run`` wraps
the stream in a :class:`~repro.data.pipeline.FramePipeline` (or consumes a
ready pipeline handle) and every window — scoring, labeling — is fetched
through the phase plan, never by indexing the stream directly. In
concurrent mode the pipeline speculates the next phase's windows from the
last phase's layout and prefetches them on a background thread, so host
frame synthesis overlaps device dispatch; reconcile hits/misses are
threaded into each :class:`PhaseRecord` (``spec_hits``/``spec_misses``).

Per-phase structured metrics flow to observers — callables receiving a
:class:`PhaseRecord` — instead of being scraped out of ad-hoc dicts.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.dacapo_pairs import VisionConfig
from repro.core import mx as mx_lib
from repro.core.allocation import (
    AllocationDecision,
    AllocationPolicy,
    CLHyperParams,
    PhaseFeedback,
    make_allocator,
)
from repro.core.decision import SpatialPlan, as_decision
from repro.core.dispatch import KernelDispatcher, PhasePlan
from repro.core.estimator import DaCapoEstimator
from repro.core.kernel import InferenceKernel, LabelingKernel, RetrainKernel
from repro.core.partition import (
    SpatialPartition,
    partition_mesh,
    single_device_partition,
)
from repro.core.sample_buffer import SampleBuffer
from repro.core.trace import TraceRecorder
from repro.data.pipeline import FramePipeline
from repro.data.stream import DriftStream
from repro.models.registry import make_vision_model


@dataclasses.dataclass
class CLResult:
    name: str
    accuracy_timeline: List[Tuple[float, float]]  # (t, acc on [t-dt, t))
    phase_log: List[dict]
    avg_accuracy: float
    retrain_time: float
    label_time: float
    drift_events: int
    records: List["PhaseRecord"] = dataclasses.field(default_factory=list)


@dataclasses.dataclass(frozen=True)
class PhaseRecord:
    """Structured per-phase metrics delivered to observers."""

    index: int
    t: float  # virtual clock at phase end
    acc_valid: float
    acc_label: float
    drift: bool  # drift detected at this phase boundary
    retrain_time: float  # cumulative
    label_time: float  # cumulative
    decision: AllocationDecision  # the decision this phase executed
    next_decision: AllocationDecision  # what the policy chose for the next
    phase_start: float = 0.0  # virtual clock at phase start
    t_tsa: float = 0.0  # T-SA kernel time this phase (retrain+valid+label)
    t_bsa: float = 0.0  # B-SA kernel time this phase (serving-side programs)
    spec_hits: int = 0  # frame windows served from speculative prefetch
    spec_misses: int = 0  # frame windows synthesized inline (reconcile miss)
    stream: int = 0  # fleet stream lane this record belongs to

    def as_log_entry(self) -> dict:
        """``phase_log`` dict layout — every PhaseRecord field the legacy
        consumers scrape, including the per-phase timing split."""
        return {"t": self.t, "acc_valid": self.acc_valid,
                "acc_label": self.acc_label, "drift": self.drift,
                "retrain_time": self.retrain_time,
                "label_time": self.label_time,
                "phase_start": self.phase_start,
                "t_tsa": self.t_tsa, "t_bsa": self.t_bsa,
                "spec_hits": self.spec_hits,
                "spec_misses": self.spec_misses,
                "stream": self.stream}


PhaseObserver = Callable[[PhaseRecord], None]


class _ScoreSink:
    """Deferred accuracy timeline: the B-SA serving-side scoring stream.

    ``add`` queues a score window; without fusion each window is dispatched
    immediately as its own async predict (the seed's one-jitted-call-per-
    window pattern, minus the per-call host sync). With ``fuse`` (concurrent
    dispatch), windows accumulate and ``flush`` issues ONE batched predict
    per phase via ``InferenceKernel.predict_batched``. ``timeline`` is the
    only point that materializes predictions to host numpy.
    """

    def __init__(self, kernel: InferenceKernel, fuse: bool):
        self.kernel = kernel
        self.fuse = fuse
        self._pending: List[tuple] = []  # (t_end, x, y, keep_frac)
        self._params = None  # serving params of the pending windows
        self._entries: List[tuple] = []  # (t_end, pred_dev, y, keep_frac)

    def add(self, t_end: float, x, y, keep_frac: float, params) -> None:
        if not self.fuse:
            pred = self.kernel.predict_async(params, x)
            self._entries.append((t_end, pred, y, keep_frac))
            return
        if self._pending and self._params is not params:
            self.flush()  # serving params changed mid-queue
        self._params = params
        self._pending.append((t_end, x, y, keep_frac))

    def flush(self) -> None:
        """Dispatch queued windows (one fused jitted call) — still async."""
        if not self._pending:
            return
        preds = self.kernel.predict_batched(
            self._params, [x for _, x, _, _ in self._pending])
        for (t_end, _x, y, kf), pred in zip(self._pending, preds):
            self._entries.append((t_end, pred, y, kf))
        self._pending.clear()

    def timeline(self) -> List[Tuple[float, float]]:
        """Collect: materialize every queued prediction into (t, acc)."""
        self.flush()
        return [(t_end, float((np.asarray(pred) == y).mean()) * kf)
                for t_end, pred, y, kf in self._entries]


def flush_sinks_batched(kernel: InferenceKernel,
                        sinks: Sequence[_ScoreSink]) -> None:
    """Flush several lanes' score sinks through ONE vmapped fleet program
    (:meth:`InferenceKernel.predict_fleet_async`) instead of one fused
    predict per lane — the fleet's B-SA serves every lane's queued score
    windows in a single program per phase. Each live sink's windows are
    concatenated into that lane's batch; predictions split back per window
    device-side. Empty sinks are skipped and a single pending lane takes
    its sink's own fused flush path (exactly ``_ScoreSink.flush``)."""
    live = [s for s in sinks if s._pending]
    if len(live) <= 1:
        for sink in live:
            sink.flush()
        return
    lane_windows = [np.concatenate([x for _, x, _, _ in s._pending], axis=0)
                    for s in live]
    preds = kernel.predict_fleet_async([s._params for s in live],
                                       lane_windows)
    for sink, pred in zip(live, preds):
        off = 0
        for t_end, x, y, kf in sink._pending:
            sink._entries.append((t_end, pred[off: off + len(x)], y, kf))
            off += len(x)
        sink._pending.clear()


class CLSession:
    """Executes allocation decisions phase-by-phase against the kernels."""

    def __init__(
        self,
        student_cfg: VisionConfig,
        teacher_cfg: VisionConfig,
        hp: Optional[CLHyperParams] = None,
        estimator=None,
        allocator: Union[str, AllocationPolicy] = "dacapo-spatiotemporal",
        precision_policy: mx_lib.PrecisionPolicy = mx_lib.DEFAULT_POLICY,
        apply_mx_numerics: bool = True,
        seed: int = 0,
        eval_fps: float = 2.0,
        mesh=None,
        observers: Sequence[PhaseObserver] = (),
        dispatch: str = "sequential",
        label_microbatch: Optional[int] = None,
        speculative_frames: Optional[bool] = None,
        decision_aware_spec: bool = True,
        trace: Union[None, bool, TraceRecorder] = None,
    ):
        self.hp = hp or CLHyperParams()
        self.estimator = estimator or DaCapoEstimator()
        self.policy = precision_policy
        self.apply_mx = apply_mx_numerics
        self.eval_fps = eval_fps  # accuracy-scoring subsample rate
        self.allocator = make_allocator(allocator, self.hp, precision_policy)
        # Trace spine (core/trace.py): ``trace=None`` keeps recording off
        # (bit-identical, zero overhead) — unless the bound policy declares
        # ``needs_trace`` (dacapo-replay), in which case a recorder is
        # auto-created. ``trace=True`` makes a fresh recorder; a ready
        # TraceRecorder instance is shared as-is (fleet/manager tiers).
        if trace is None and getattr(self.allocator, "needs_trace", False):
            trace = True
        if trace is True:
            trace = TraceRecorder()
        elif trace is False:
            trace = None
        # NB: ``trace`` is None or a recorder here; len()-based truthiness
        # would drop a fresh (empty) recorder, so test against None only.
        self.dispatcher = KernelDispatcher(
            dispatch, recorder=trace if trace is not None else None)
        if trace is not None:
            self.allocator.attach_trace(trace)
        # Speculative frame prefetch (data/pipeline.py): defaults to the
        # dispatch mode's appetite — concurrent dispatch overlaps host frame
        # synthesis with device programs; sequential keeps the transparent
        # inline path the goldens pin.
        if speculative_frames is None:
            speculative_frames = self.dispatcher.concurrent
        self.speculative_frames = speculative_frames
        # Decision-aware speculation: at each phase barrier the next
        # decision's labeling budget is handed to the pipeline so the
        # speculated labeling burst is pre-sized (drift phases stop missing
        # on the replayed small layout). Only meaningful when speculating.
        self.decision_aware_spec = decision_aware_spec
        # Microbatched labeling: seed call pattern (one jitted call) by
        # default; concurrent mode chunks big label bursts unless overridden
        # (0 explicitly disables microbatching in either mode).
        if label_microbatch is None:
            self._label_microbatch = (64 if self.dispatcher.concurrent
                                      else None)
        else:
            self._label_microbatch = label_microbatch or None
        self.full_student, self.full_teacher = student_cfg, teacher_cfg
        self.student_cfg = student_cfg.reduced()
        self.teacher_cfg = teacher_cfg.reduced()
        self.student = make_vision_model(self.student_cfg)
        self.teacher = make_vision_model(self.teacher_cfg)
        self.seed = seed
        self.key = jax.random.PRNGKey(seed)
        self.rng = np.random.default_rng(seed)
        self._observers: List[PhaseObserver] = list(observers)

        # (allocator constructed above, before the dispatcher, so the trace
        # recorder could be attached when the policy needs one)
        # The session's precision policy is authoritative — also for ready
        # policy instances handed in via the spec — so decisions, kernel
        # costs and the spatial split all agree on one PrecisionPolicy.
        self.allocator.precision = precision_policy
        self.allocator.bind(self.estimator, self.full_student)

        # Offline spatial allocation (Alg. 1 lines 1-2) — single source of
        # truth: the split the bound policy computed.
        self.r_tsa, self.r_bsa = self.allocator.rows

        # The three kernels (Fig. 4), each owning its jitted apply and cost.
        self.inference = InferenceKernel(
            self.student, self.full_student, self.estimator, self.apply_mx)
        self.labeling = LabelingKernel(
            self.teacher, self.full_teacher, self.estimator, self.apply_mx)
        self.retrain = RetrainKernel(
            self.student, self.full_student, self.estimator, self.hp)
        self.kernels = (self.inference, self.labeling, self.retrain)
        # Retraining supersedes the student tree: drop its RESIDENT
        # quantized serving copy from the inference kernel's version-keyed
        # cache (the teacher's cache needs no wiring — its tree never
        # changes, so its resident copy is filled once and lives forever).
        self.retrain.invalidates = (self.inference.serving_cache,)

        # Spatial partition: fission the mesh if one is given.
        self.mesh = mesh
        self._mesh_rows_bsa: Optional[int] = None
        self.partition: SpatialPartition = single_device_partition()
        self._repartition(self.r_bsa)

    # --------------------------------------------------------------- mesh
    def _mesh_split(self, rows_bsa: int) -> int:
        """Map the estimator's row split onto the mesh's leading axis.
        A single-row mesh cannot be fissioned — return 0 so `_repartition`
        degenerates to time-sharing (the paper's R=0 fallback) instead of
        asking `partition_mesh` to split an unsplittable mesh."""
        n_rows = self.mesh.devices.shape[0]
        if n_rows < 2:
            return 0
        frac = rows_bsa / max(1, self.estimator.total_rows)
        return max(1, min(n_rows - 1, round(n_rows * frac)))

    def _repartition(self, rows_bsa: int) -> None:
        """(Re)fission the mesh for a row split; bind kernels to sub-meshes.
        Single-device sessions keep the degenerate time-shared partition;
        an unchanged split leaves the current partition untouched."""
        if self.mesh is None:
            for k in self.kernels:
                k.bind_partition(self.partition)
            return
        want = self._mesh_split(rows_bsa)
        if want == self._mesh_rows_bsa:
            return
        self._mesh_rows_bsa = want
        self.partition = (single_device_partition() if want == 0
                          else partition_mesh(self.mesh, want))
        for k in self.kernels:
            k.bind_partition(self.partition)

    # ---------------------------------------------------------- observers
    def add_observer(self, observer: PhaseObserver) -> None:
        self._observers.append(observer)

    # --------------------------------------------------------- pretraining
    def pretrain(self, stream: DriftStream, teacher_steps: int = 300,
                 student_steps: int = 80, batch: int = 64):
        """Teacher: pretrained across the whole attribute space (general).
        Student: narrow slice only (first segment's context) -> must adapt."""
        t_params = pretrain_model(self.teacher, stream, teacher_steps, batch,
                                  rng=self.rng)
        s_params = pretrain_model(self.student, stream, student_steps, batch,
                                  rng=self.rng, segments=stream.segments[:1],
                                  seed=8)
        self.set_pretrained(t_params, s_params)

    def set_pretrained(self, teacher_params, student_params):
        """Install (shared) pretrained weights; benches pretrain once per
        (pair, scenario) and clone into every allocator variant."""
        self.teacher_params = teacher_params
        self.student_params = jax.tree_util.tree_map(
            lambda x: x.copy(), student_params)
        self._opt = self.retrain.init_state(self.student_params)

    # ------------------------------------------------------------ main loop
    def _resolve_spatial(self, decision) -> SpatialPlan:
        """The decision's spatial plane with concrete rows: ``None`` rows
        fall back to the offline split, a 0-row side time-shares the whole
        array (the paper's R=0 fallback)."""
        return as_decision(decision).spatial.resolve(
            self.r_tsa, self.r_bsa, self.estimator.total_rows)

    def _effective_rows(self, decision) -> Tuple[int, int]:
        """Legacy view of :meth:`_resolve_spatial`: the concrete row pair."""
        spatial = self._resolve_spatial(decision)
        return spatial.rows_tsa, spatial.rows_bsa

    def run(self, stream: Union[DriftStream, FramePipeline],
            duration: Optional[float] = None,
            observers: Sequence[PhaseObserver] = ()) -> CLResult:
        """Execute the continuous-learning loop over ``stream`` — a raw
        :class:`DriftStream` (the session wraps it in its own
        :class:`FramePipeline` data plane) or a ready pipeline handle."""
        if isinstance(stream, FramePipeline):
            pipe, own_pipe = stream, False
        else:
            pipe = FramePipeline(stream, speculative=self.speculative_frames)
            own_pipe = True
        try:
            return self._run(pipe, duration, observers)
        finally:
            if own_pipe:
                pipe.close()

    def _run(self, pipe: FramePipeline, duration: Optional[float],
             observers: Sequence[PhaseObserver]) -> CLResult:
        hp = self.hp
        duration = duration or pipe.duration
        buffer = SampleBuffer(hp.c_b, seed=3)
        observers = self._observers + list(observers)
        # The policy's raw output (legacy facade or two-plane Decision) is
        # what records carry; the engine consumes the two-plane view.
        raw = self.allocator.initial_decision()
        dec = as_decision(raw)

        spatial = self._resolve_spatial(dec)
        keep_frac = self.inference.plan_keep_frac(spatial, hp.fps)
        serving = self.inference.serving_params(
            self.student_params, spatial.precisions.inference)
        clock = 0.0
        eval_cursor = 0.0
        sink = _ScoreSink(self.inference,
                          fuse=self.dispatcher.concurrent)
        records: List[PhaseRecord] = []
        retrain_time = label_time = 0.0
        drift_events = 0

        def score_until(t_end: float, serving_params,
                        plan: Optional[PhasePlan]):
            """Queue student-accuracy scoring on [eval_cursor, t_end): the
            B-SA serving-side program of the phase. Predictions are
            dispatched async (fused per phase in concurrent mode) and
            materialized only when the timeline is assembled."""
            nonlocal eval_cursor
            if t_end <= eval_cursor + 1e-9:
                return
            n_eval = max(1, int((t_end - eval_cursor) * self.eval_fps))
            x, y = (plan.fetch(eval_cursor, t_end, max_frames=n_eval)
                    if plan is not None
                    else pipe.frames(eval_cursor, t_end, max_frames=n_eval))
            if plan is not None:
                plan.charge("b_sa", len(x)
                            * self.inference.plan_time_per_sample(spatial),
                            label="score", units=len(x))
            sink.add(t_end, x, y, keep_frac, serving_params)
            eval_cursor = t_end

        while clock < duration:
            phase_start = clock
            spatial = self._resolve_spatial(dec)
            temporal = dec.temporal
            prec = spatial.precisions
            if spatial.refission:  # the plane's mesh re-fission intent
                self._repartition(spatial.rows_bsa)
            keep_frac = self.inference.plan_keep_frac(spatial, hp.fps)
            # ---- Plan: open the phase ledger on the dispatcher; the plan
            # consumes the Decision — rotating the pipeline's speculation
            # onto this phase start, pre-sized with the temporal plane's
            # labeling budget (the decision-aware predictor — the budget
            # is known at the barrier, so drift-phase N_ldd bursts
            # prefetch whole). ----
            plan = self.dispatcher.begin_phase(
                clock, pipe, decisions=(dec,),
                fps=hp.fps if self.decision_aware_spec else None)
            spec_seen = (pipe.hits, pipe.misses)
            valid_h = xv = yv = None
            # Profiling overhead (e.g. Ekya's per-window microprofiling)
            # rides on the temporal plane and is charged to the T-SA ledger
            # before the window's own work — zero for idealized policies.
            if temporal.profile_cost_s:
                plan.charge("t_sa", temporal.profile_cost_s, label="profile")
            # ---------------- Retraining (Alg. 1 lines 4-7) ----------------
            acc_v = 1.0
            if len(buffer) >= hp.sgd_batch and temporal.retrain_samples > 0:
                xt, yt, xv, yv = buffer.get_data(temporal.retrain_samples,
                                                 temporal.valid_samples)
                fit_t0 = time.perf_counter() if plan.traced else 0.0
                self.student_params, self._opt, n_batches = self.retrain.fit(
                    self.student_params, self._opt, xt, yt, self.rng,
                    epochs=temporal.retrain_epochs)
                t_phase = n_batches * self.retrain.plan_time_per_batch(
                    spatial)
                plan.charge(
                    "t_sa", t_phase, label="retrain", units=n_batches,
                    wall_s=(time.perf_counter() - fit_t0 if plan.traced
                            else 0.0))
                retrain_time += t_phase
                # UpdateWeight + Valid (lines 6-7) — dispatched async; the
                # accuracy is collected at the phase-end feedback barrier.
                # Sequential keeps the seed's time-shared serial accounting
                # (validation charged on the T-SA chain); concurrent places
                # it where the inference kernel actually lives — the B-SA —
                # so it overlaps the T-SA moving on to labeling.
                serving = self.inference.serving_params(self.student_params,
                                                        prec.inference)
                v_role = ("b_sa" if self.dispatcher.concurrent else "t_sa")
                valid_h = plan.dispatch(
                    v_role, "valid",
                    lambda s=serving, v=xv: self.inference.predict_async(s, v),
                    cost_s=len(xv) * self.inference.plan_time_per_sample(
                        spatial, role=v_role),
                    units=len(xv))
            score_until(min(plan.now(), duration), serving, plan)
            if plan.now() >= duration:
                clock = plan.finish()
                break

            # ---------------- Labeling (lines 8-10) ------------------------
            n_label = temporal.total_label_samples
            if temporal.reset_buffer:
                buffer.reset()  # line 12
                drift_events += 1
            t_lab0 = plan.now()
            x_l, _y_true = plan.fetch(t_lab0, t_lab0 + n_label / hp.fps,
                                      max_frames=n_label, tag="label")
            label_h = plan.dispatch(
                "t_sa", "label",
                lambda: self.labeling.label_async(
                    self.teacher_params, x_l, prec.labeling,
                    microbatch=self._label_microbatch),
                cost_s=n_label * self.labeling.plan_time_per_sample(spatial),
                units=n_label)
            label_time += plan.now() - t_lab0
            pred_l_h = plan.dispatch(
                "b_sa", "acc_label",
                lambda: self.inference.predict_async(serving, x_l),
                cost_s=len(x_l) * self.inference.plan_time_per_sample(
                    spatial),
                units=len(x_l))
            score_until(min(plan.now(), duration), serving, plan)

            # Fixed-window pacing, declared by the temporal plane (no
            # baseline-specific branch: any policy may pace on a grid).
            if temporal.pace_window_s:
                w = temporal.pace_window_s
                next_boundary = (int(phase_start / w) + 1) * w
                if plan.now() < next_boundary:
                    score_until(min(next_boundary, duration), serving, plan)
                    plan.pad_to(next_boundary)

            # ---- Collect: the phase-end barrier — the only host sync. ----
            clock = plan.finish()
            # Concurrent mode: when the B-SA dominates, the phase end runs
            # past the T-SA clock the score windows tracked — score that
            # tail now, under THIS phase's serving params (uncharged: the
            # phase end already reflects the B-SA busy period). Sequential
            # mode is a no-op (clock == the last scored boundary).
            score_until(min(clock, duration), serving, None)
            if valid_h is not None:
                acc_v = float((valid_h.collect() == yv).mean())
            y_l = label_h.collect()
            acc_l = float((pred_l_h.collect() == y_l).mean())
            buffer.update(x_l, y_l)  # line 14
            sink.flush()  # issue fused scoring before serving params change

            # ---------------- Next decision (lines 11-13) ------------------
            # The engine-side drift verdict: computed once here, handed to
            # the policy on the feedback (the deduped source of truth).
            drifted = self.allocator.observe_drift(acc_l, acc_v, clock)
            feedback = PhaseFeedback(
                acc_valid=acc_v, acc_label=acc_l, t=clock,
                phase_start=phase_start, retrain_time=retrain_time,
                label_time=label_time, drifted=drifted)
            next_raw = self.allocator.next_decision(feedback)
            next_dec = as_decision(next_raw)
            record = PhaseRecord(
                index=len(records), t=clock, acc_valid=acc_v,
                acc_label=acc_l, drift=next_dec.temporal.reset_buffer,
                retrain_time=retrain_time, label_time=label_time,
                decision=raw, next_decision=next_raw,
                phase_start=phase_start, t_tsa=plan.t_tsa, t_bsa=plan.t_bsa,
                spec_hits=pipe.hits - spec_seen[0],
                spec_misses=pipe.misses - spec_seen[1])
            records.append(record)
            for obs in observers:
                obs(record)
            raw, dec = next_raw, next_dec

        score_until(duration, serving, None)
        acc_timeline = sink.timeline()
        accs = [a for _, a in acc_timeline]
        return CLResult(
            name=self.allocator.name,
            accuracy_timeline=acc_timeline,
            phase_log=[r.as_log_entry() for r in records],
            avg_accuracy=float(np.mean(accs)) if accs else 0.0,
            retrain_time=retrain_time,
            label_time=label_time,
            drift_events=drift_events,
            records=records,
        )


@dataclasses.dataclass
class CLSystemSpec:
    """Declarative front door: describe a CL system, then ``build()`` it.

    ``estimator`` accepts an instance or a zero-arg factory (class/lambda);
    ``allocator`` accepts a registry name, an ``AllocationPolicy`` class, or
    a ready instance. ``student``/``teacher`` are the FULL paper configs
    (Table III); the session derives the reduced twins itself.

        spec = CLSystemSpec(student=RESNET18, teacher=WIDERESNET50,
                            allocator="ekya", apply_mx=False)
        session = spec.build()
    """

    student: Optional[VisionConfig] = None
    teacher: Optional[VisionConfig] = None
    allocator: Union[str, AllocationPolicy] = "dacapo-spatiotemporal"
    estimator: object = None  # instance or zero-arg factory
    policy: mx_lib.PrecisionPolicy = mx_lib.DEFAULT_POLICY
    hp: Optional[CLHyperParams] = None
    apply_mx: bool = True
    seed: int = 0
    eval_fps: float = 2.0
    mesh: object = None
    dispatch: str = "sequential"  # see core/dispatch.py for the semantics
    label_microbatch: Optional[int] = None
    # Speculative frame prefetch (data/pipeline.py); None = follow dispatch
    # mode (on for concurrent, off for sequential).
    speculative_frames: Optional[bool] = None
    # Pre-size speculated labeling bursts with the next decision's budget.
    decision_aware_spec: bool = True
    # Trace spine: None = off (bit-identical), True = fresh TraceRecorder,
    # or a ready TraceRecorder instance to share. See core/trace.py.
    trace: Union[None, bool, TraceRecorder] = None

    def _session_kwargs(self) -> dict:
        """The resolved CLSession constructor kwargs this spec describes —
        shared with subclasses (FleetSpec) so new knobs are mirrored once."""
        if self.student is None or self.teacher is None:
            raise ValueError(
                f"{type(self).__name__} needs student and teacher configs")
        est = self.estimator
        if est is not None and (isinstance(est, type)
                                or not hasattr(est, "total_rows")):
            est = est()  # class or zero-arg factory -> instance
        return dict(
            student_cfg=self.student,
            teacher_cfg=self.teacher,
            hp=self.hp,
            estimator=est,
            allocator=self.allocator,
            precision_policy=self.policy,
            apply_mx_numerics=self.apply_mx,
            seed=self.seed,
            eval_fps=self.eval_fps,
            mesh=self.mesh,
            dispatch=self.dispatch,
            label_microbatch=self.label_microbatch,
            speculative_frames=self.speculative_frames,
            decision_aware_spec=self.decision_aware_spec,
            trace=self.trace,
        )

    def build(self) -> CLSession:
        return CLSession(**self._session_kwargs())


# ------------------------------------------------------------------ helpers
def _sgd_state(params):
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def pretrain_model(model, stream: DriftStream, steps: int, batch: int,
                   rng: np.random.Generator, segments=None, seed: int = 7,
                   lr: float = 3e-3):
    """Jitted SGD-momentum pretraining over IID stream samples."""
    params = model.init(jax.random.PRNGKey(seed))
    opt = _sgd_state(params)

    @jax.jit
    def update(params, opt, x, y):
        def loss_fn(p):
            logp = jax.nn.log_softmax(model.apply(p, x))
            return -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()

        grads = jax.grad(loss_fn)(params)
        opt = jax.tree_util.tree_map(lambda m, g: 0.9 * m + g, opt, grads)
        params = jax.tree_util.tree_map(lambda p, m: p - lr * m, params, opt)
        return params, opt

    for _ in range(steps):
        x, y = stream.sample_dataset(batch, rng, segments=segments)
        params, opt = update(params, opt, x, y)
    return params
