"""The paper's primary contribution: MX precision, Algorithm 1 allocation
policies, pluggable CL kernels, mesh spatial partitioning, the performance
estimator and the CLSession engine behind the CLSystemSpec front door."""
from repro.core.allocation import (  # noqa: F401
    ALLOCATORS,
    FLEET_MODES,
    AllocationDecision,
    AllocationPolicy,
    CLHyperParams,
    EkyaAllocator,
    EOMUAllocator,
    FleetAllocator,
    OnlineSpatiotemporalAllocator,
    PhaseFeedback,
    ReplayAllocator,
    SpatialAllocator,
    SpatiotemporalAllocator,
    make_allocator,
)
# ``SCHEDULERS`` is the legacy alias for the allocator registry; imported
# from allocation (not the deprecated core.scheduler shim) so importing
# repro.core stays warning-free under -W error::DeprecationWarning.
from repro.core.allocation import ALLOCATORS as SCHEDULERS  # noqa: F401
from repro.core.cl_system import ContinuousLearningSystem  # noqa: F401
from repro.core.decision import (  # noqa: F401
    FLEET_ROW_POLICIES,
    Decision,
    FleetDecision,
    FleetRowContext,
    FleetRowPolicy,
    ManagerDecision,
    PlacementAction,
    SpatialPlan,
    TemporalPlan,
    as_decision,
    make_fleet_row_policy,
)
from repro.core.dispatch import (  # noqa: F401
    DISPATCH_MODES,
    DeviceProgram,
    KernelDispatcher,
    PhasePlan,
    ProgramHandle,
)
from repro.core.estimator import (  # noqa: F401
    CalibratedEstimator,
    DaCapoEstimator,
    PlacementCostModel,
    TPUEstimator,
    spatial_allocation,
)
from repro.core.fleet import (  # noqa: F401
    FleetResult,
    FleetRun,
    FleetSession,
    FleetSpec,
    LaneSnapshot,
)
from repro.core.manager import (  # noqa: F401
    PLACEMENT_POLICIES,
    FleetManager,
    ManagerResult,
    ManagerSpec,
    PlacementPolicy,
    make_placement_policy,
)
from repro.core.kernel import (  # noqa: F401
    InferenceKernel,
    Kernel,
    LabelingKernel,
    RetrainKernel,
)
from repro.core.mx import DEFAULT_POLICY, PrecisionPolicy, mx_dense  # noqa: F401
from repro.core.partition import SpatialPartition, partition_mesh  # noqa: F401
from repro.core.replay import (  # noqa: F401
    Calibration,
    ReplayNode,
    TraceReplayer,
)
from repro.core.sample_buffer import SampleBuffer  # noqa: F401
from repro.core.session import (  # noqa: F401
    CLResult,
    CLSession,
    CLSystemSpec,
    PhaseRecord,
    pretrain_model,
)
from repro.core.trace import (  # noqa: F401
    PhaseTrace,
    SessionTrace,
    TraceEvent,
    TraceRecorder,
)
