"""The paper's primary contribution: MX precision, Algorithm 1 scheduling,
mesh spatial partitioning, the performance estimator and the CL system."""
from repro.core.cl_system import CLResult, ContinuousLearningSystem  # noqa: F401
from repro.core.estimator import (  # noqa: F401
    DaCapoEstimator,
    TPUEstimator,
    spatial_allocation,
)
from repro.core.mx import DEFAULT_POLICY, PrecisionPolicy, mx_dense  # noqa: F401
from repro.core.partition import SpatialPartition, partition_mesh  # noqa: F401
from repro.core.sample_buffer import SampleBuffer  # noqa: F401
from repro.core.scheduler import CLHyperParams, SCHEDULERS  # noqa: F401
