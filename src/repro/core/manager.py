"""FleetManager — the sharded, elastic, fault-tolerant fleet-of-fleets tier.

DaCapo's deployment story is one autonomous system on one spatially-
partitioned accelerator; the ROADMAP north star is production scale —
thousands of fleets, millions of streams — which needs the tier the paper
never did: sharding, placement, failure recovery. Cross-camera systems
like ECCO regroup cameras by context, and SoC-edge systems like
Legilimens assume devices come and go (PAPERS.md); both presuppose the
admission/migration/recovery machinery this module provides.

Architecture (see ROADMAP.md):

* a **shard** is one :class:`~repro.core.fleet.FleetSession` on its own
  mesh/sub-accelerator, opened phase-steppable as a
  :class:`~repro.core.fleet.FleetRun` — the manager never reaches inside
  a shard's phase; it acts only at phase boundaries, where no
  :class:`~repro.core.dispatch.PhasePlan` is in flight;
* the **manager loop** is round-based: each round, every live shard
  executes one fleet phase; between rounds the manager checkpoints lanes
  (per-lane :class:`~repro.checkpoint.CheckpointManager` directories),
  admits due cameras, and migrates lanes per its placement policy;
* **overlapped rounds** — with ``parallel_shards > 1`` the live shards'
  phases run concurrently on a ``ThreadPoolExecutor`` (shards model
  disjoint sub-accelerators; the wall should pay ``max`` over shards,
  not ``sum``) and meet at a phase-boundary **barrier**, where all
  bookkeeping — ledger charges, checkpointing, admission, migration,
  failure recovery — happens in shard-index order.  The overlapped loop
  is **bit-identical to serial stepping**: shard phases touch only
  shard-private state (the process-global kernel-stats counters and
  serving caches are locked), the failure injector is probed with
  deterministic ``(round, shard)`` keys, and the barrier fixes the order
  of every charge, event and :class:`PlacementAction` regardless of
  worker completion order;
* **lane admission** — a camera joining mid-run is placed on the shard
  the :class:`PlacementPolicy` picks (``headroom``: most T-SA headroom);
  a policy may instead *reject* the camera when every shard is
  oversubscribed (``admit()`` returning ``None``, surfaced as a
  ``PlacementAction(kind="reject")`` — degraded service is an explicit
  decision, never a silent drop);
* **estimator-driven placement** — the ``estimator`` policy scores
  moves with :class:`~repro.core.estimator.PlacementCostModel` on the
  overlap model: a migration fires only when the T-SA seconds it shaves
  off the per-round load maximum, amortized over a horizon, exceed the
  explicit ``migration_cost_s`` the manager charges its ledger per move;
* **live lane migration** — a lane that drifts hot on an oversubscribed
  shard is frozen into a :class:`~repro.core.fleet.LaneSnapshot` (student
  weights + optimizer + :class:`~repro.core.sample_buffer.SampleBuffer` +
  policy/detector state) and re-homed, resuming *bit-identically*: the
  snapshot carries every bit of lane state, and the lane's pipeline moves
  with it;
* **fault tolerance** — a simulated accelerator loss
  (:class:`~repro.runtime.fault.FailureInjector`, probed per round with
  ``key=shard_index``) kills a shard: its lanes restore from their last
  durable per-lane checkpoint (host arrays re-homed onto the surviving
  shard's devices via :func:`~repro.runtime.elastic.rehome_tree` — the
  restore half of an ``elastic_data_axis``-style shrink) and re-home
  across survivors, with ``recovery_cost_s`` per lane charged explicitly
  to the manager ledger;
* the **virtual-clock ledger is conserved**: every phase's T-SA/B-SA
  seconds are charged once to the owning shard and once to the manager,
  so ``manager.t_tsa == Σ shard.t_tsa`` (to float re-association) and the
  only extra manager-level charges are the explicit recovery and
  migration costs;
* each round is recorded as a :class:`~repro.core.decision.ManagerDecision`
  — the per-shard tuple of :class:`~repro.core.decision.FleetDecision`s
  plus the round's :class:`~repro.core.decision.PlacementAction`s — the
  fleet decision generalized one tier up.

Degeneracy contract, continuing PRs 4–5: a **1-shard FleetManager is
bit-identical to a bare FleetSession** (same records, timelines, ledger;
both dispatch modes) — the manager opens the shard's run through the same
:meth:`~repro.core.fleet.FleetSession.open_run` path ``run()`` uses, and
checkpointing is side-effect free on live lanes.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple, Type, Union

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.decision import ManagerDecision, PlacementAction
from repro.core.estimator import PlacementCostModel
from repro.core.fleet import (
    FleetResult,
    FleetRun,
    FleetSession,
    FleetSpec,
    LaneSnapshot,
)
from repro.core.session import CLResult
from repro.core.trace import PhaseTrace, SessionTrace
from repro.data.pipeline import FramePipeline
from repro.runtime.fault import FailureInjector


# --------------------------------------------------------------- shard views
@dataclasses.dataclass(frozen=True)
class ShardView:
    """Frozen per-shard stats a placement policy conditions on."""

    index: int
    alive: bool
    done: bool
    n_lanes: int
    clock: float
    t_tsa: float  # accumulated T-SA seconds on this shard
    recent_t_tsa: float  # last phase's T-SA seconds (headroom proxy)
    drifted_lanes: int  # lanes whose latest phase fired drift
    recent_phase_s: float = 0.0  # last phase's wall (t - phase_start)

    @property
    def placeable(self) -> bool:
        return self.alive and not self.done


@dataclasses.dataclass(frozen=True)
class LaneView:
    """Frozen per-lane stats for migration decisions."""

    shard: int
    index: int
    key: object
    drifted: bool  # latest phase fired drift
    drift_events: int
    recent_t_tsa: float = 0.0  # last phase's T-SA seconds for this lane


# --------------------------------------------------------- placement policies
class PlacementPolicy:
    """Pluggable lane-placement policy: where admitted/re-homed lanes land
    and which lanes migrate, mirroring the
    :class:`~repro.core.decision.FleetRowPolicy` registry pattern —
    ``PlacementPolicy("headroom", **kwargs)`` dispatches through
    :data:`PLACEMENT_POLICIES` (subclasses construct directly), unknown
    kwargs are rejected, :meth:`reset` is called once per manager run.
    """

    name = "base"

    def __new__(cls, spec: Optional[str] = None, **kwargs):
        if cls is PlacementPolicy:
            key = spec or "headroom"
            try:
                sub = PLACEMENT_POLICIES[key]
            except KeyError:
                raise KeyError(
                    f"unknown placement policy {key!r}; "
                    f"known: {sorted(PLACEMENT_POLICIES)}") from None
            return super().__new__(sub)
        return super().__new__(cls)

    def __init__(self, spec: Optional[str] = None, **kwargs):
        # ``spec`` is the registry key consumed by __new__; unknown kwargs
        # are rejected, not swallowed — a typo'd knob must not silently
        # measure default behavior.
        del spec
        if kwargs:
            raise TypeError(
                f"{type(self).__name__} got unexpected keyword "
                f"arguments: {sorted(kwargs)}")

    def reset(self, n_shards: int) -> None:
        """Fresh per-run state (cursors etc.)."""

    def place(self, views: Sequence[ShardView]) -> int:
        """Shard index for a new or re-homed lane. At least one view is
        guaranteed placeable."""
        raise NotImplementedError

    def admit(self, views: Sequence[ShardView]) -> Optional[int]:
        """Shard index for a *mid-run* admission, or ``None`` to reject
        the camera (every shard oversubscribed — surfaced by the manager
        as ``PlacementAction(kind="reject")``). Default: admission is
        just placement, never rejected. Initial placement and fault
        recovery go through :meth:`place` and cannot reject."""
        return self.place(views)

    def migrate(self, views: Sequence[ShardView],
                lanes: Sequence[LaneView]
                ) -> Optional[Tuple[LaneView, int]]:
        """Propose at most one migration: (lane, target shard index), or
        None. Default: placement-only policies never migrate."""
        return None


class StaticPlacementPolicy(PlacementPolicy):
    """Round-robin admission over placeable shards, never migrates — the
    no-elasticity baseline the manager bench compares against."""

    name = "static"

    def __init__(self, spec: Optional[str] = None):
        super().__init__(spec)
        self._cursor = 0

    def reset(self, n_shards: int) -> None:
        self._cursor = 0

    def place(self, views: Sequence[ShardView]) -> int:
        order = [v for v in views if v.placeable]
        pick = order[self._cursor % len(order)]
        self._cursor += 1
        return pick.index


class HeadroomPlacementPolicy(PlacementPolicy):
    """Admit onto the shard with the most T-SA headroom (fewest lanes,
    then least recent T-SA time); migrate a drifted lane off an
    oversubscribed shard when a strictly less-loaded shard exists.

    The migration trigger is the DaCapo contention story one tier up: a
    drifting lane means an N_ldd labeling burst plus buffer-refill
    retraining on its shard's single T-SA — if another shard's T-SA is
    sitting idle, moving the hot lane buys recovery time on the target
    *and* serving time back on the source. ``min_gap`` is the load gap
    (in lanes) required before a move fires (hysteresis against
    ping-ponging)."""

    name = "headroom"

    def __init__(self, spec: Optional[str] = None, *, min_gap: int = 2):
        super().__init__(spec)
        self.min_gap = min_gap

    def place(self, views: Sequence[ShardView]) -> int:
        order = sorted((v for v in views if v.placeable),
                       key=lambda v: (v.n_lanes, v.recent_t_tsa, v.index))
        return order[0].index

    def migrate(self, views, lanes):
        placeable = [v for v in views if v.placeable]
        if len(placeable) < 2:
            return None
        # Busiest shard that has a drifted lane and >= 2 lanes.
        sources = sorted(
            (v for v in placeable
             if v.n_lanes >= 2 and v.drifted_lanes > 0),
            key=lambda v: (-v.recent_t_tsa, -v.n_lanes, v.index))
        for src in sources:
            targets = sorted(
                (v for v in placeable if v.index != src.index),
                key=lambda v: (v.n_lanes, v.recent_t_tsa, v.index))
            tgt = targets[0]
            if src.n_lanes - tgt.n_lanes < self.min_gap:
                continue  # not oversubscribed enough to pay a move
            for lane in lanes:
                if lane.shard == src.index and lane.drifted:
                    return lane, tgt.index
        return None


class DriftPackPlacementPolicy(PlacementPolicy):
    """Consolidate drifting lanes onto one shard: admissions land on the
    *quietest* shard (fewest drifted lanes), and a drifted lane migrates
    onto the shard already owning the most drifted lanes — packing the
    retraining-heavy lanes so their N_ldd bursts share one T-SA while the
    other shards' B-SAs serve healthy lanes undisturbed."""

    name = "drift-pack"

    def place(self, views: Sequence[ShardView]) -> int:
        order = sorted((v for v in views if v.placeable),
                       key=lambda v: (v.drifted_lanes, v.n_lanes, v.index))
        return order[0].index

    def migrate(self, views, lanes):
        placeable = [v for v in views if v.placeable]
        if len(placeable) < 2:
            return None
        hot = sorted(placeable,
                     key=lambda v: (-v.drifted_lanes, v.n_lanes, v.index))[0]
        if hot.drifted_lanes == 0:
            return None  # nothing drifting anywhere
        for lane in lanes:
            if lane.drifted and lane.shard != hot.index:
                src = next(v for v in placeable if v.index == lane.shard)
                if src.n_lanes >= 2:
                    return lane, hot.index
        return None


class EstimatorPlacementPolicy(PlacementPolicy):
    """Placement scored by :class:`~repro.core.estimator
    .PlacementCostModel` instead of lane counts.

    Under overlapped rounds the manager's wall per round is the *maximum*
    of the per-shard T-SA loads, so this policy reasons in seconds on
    that maximum (the Ekya-style microprofiled-placement idea one tier
    up): admissions land on the shard with the least recent T-SA load;
    a lane migrates only when the load-max seconds it saves, amortized
    over ``horizon_rounds``, exceed ``migration_cost_s`` — the same
    figure the manager charges its ledger per move, so a migration that
    fires has, by construction, already paid for itself in the model;
    and a mid-run admission is **rejected** when every warm shard's
    predicted T-SA utilization (T-SA seconds per phase over the phase
    wall) would exceed ``oversub_limit`` with one more lane aboard.
    """

    name = "estimator"

    def __init__(self, spec: Optional[str] = None, *,
                 migration_cost_s: float = 2.0,
                 horizon_rounds: int = 4,
                 oversub_limit: float = 1.5):
        super().__init__(spec)
        self.model = PlacementCostModel(
            migration_cost_s=migration_cost_s,
            horizon_rounds=horizon_rounds,
            oversub_limit=oversub_limit)

    def place(self, views: Sequence[ShardView]) -> int:
        order = sorted((v for v in views if v.placeable),
                       key=lambda v: (v.recent_t_tsa, v.n_lanes, v.index))
        return order[0].index

    def admit(self, views: Sequence[ShardView]) -> Optional[int]:
        placeable = [v for v in views if v.placeable]
        warm = [v for v in placeable if v.recent_phase_s > 0]
        if not warm:
            return self.place(views)  # no utilization signal yet
        lanes = sum(v.n_lanes for v in placeable)
        # The incoming camera's cost is unknown until it runs; predict it
        # as the fleet-mean per-lane T-SA load.
        lane_cost = (sum(v.recent_t_tsa for v in placeable) / lanes
                     if lanes else 0.0)
        fits = [v for v in warm
                if self.model.admits(v.recent_t_tsa, v.recent_phase_s,
                                     lane_cost)]
        # An idle shard (no phase yet) always has room.
        fits += [v for v in placeable if v.recent_phase_s <= 0]
        if not fits:
            return None
        order = sorted(fits,
                       key=lambda v: (v.recent_t_tsa, v.n_lanes, v.index))
        return order[0].index

    def migrate(self, views, lanes):
        placeable = sorted((v for v in views if v.placeable),
                           key=lambda v: v.index)
        if len(placeable) < 2:
            return None
        pos = {v.index: i for i, v in enumerate(placeable)}
        loads = [v.recent_t_tsa for v in placeable]
        lanes_per = {v.index: v.n_lanes for v in placeable}
        best = None  # (gain, lane, target shard index)
        for lane in sorted(lanes, key=lambda l: (l.shard, l.index)):
            if lane.shard not in pos or lane.recent_t_tsa <= 0:
                continue
            if lanes_per[lane.shard] < 2:
                continue  # never drain a shard's last lane
            for tgt in placeable:
                if tgt.index == lane.shard:
                    continue
                gain = self.model.migration_gain_s(
                    loads, pos[lane.shard], pos[tgt.index],
                    lane.recent_t_tsa)
                # Strictly-greater keeps the first (lowest shard/lane
                # index) candidate on ties — deterministic proposals.
                if best is None or gain > best[0]:
                    best = (gain, lane, tgt.index)
        if best is None or best[0] <= self.model.migration_cost_s:
            return None
        return best[1], best[2]


PLACEMENT_POLICIES: Dict[str, Type[PlacementPolicy]] = {
    "static": StaticPlacementPolicy,
    "headroom": HeadroomPlacementPolicy,
    "drift-pack": DriftPackPlacementPolicy,
    "estimator": EstimatorPlacementPolicy,
}


def make_placement_policy(policy, **kwargs) -> PlacementPolicy:
    """Resolve a placement policy from a registry name, class, or ready
    instance."""
    if isinstance(policy, PlacementPolicy):
        return policy
    if isinstance(policy, str):
        return PlacementPolicy(policy, **kwargs)
    return policy(**kwargs)


# ------------------------------------------------------ durable lane snapshot
def snapshot_to_state(snap: LaneSnapshot) -> Dict[str, object]:
    """Encode a :class:`LaneSnapshot` as the flat array tree
    :class:`~repro.checkpoint.CheckpointManager` persists: the large
    arrays (params / opt / buffer samples) as npz leaves, everything else
    — RNG states, the pickled lane policy, records, timeline — as one
    opaque ``aux`` uint8 blob, so the checkpoint round-trips bit-exactly
    without ``allow_pickle`` on the array file."""
    bx, by = snap.buffer["x"], snap.buffer["y"]
    aux = {
        "key": snap.key,
        "rng_state": snap.rng_state,
        "policy": snap.policy,
        "lane_state": snap.lane_state,
        "decision": snap.decision,
        "eval_cursor": snap.eval_cursor,
        "retrain_time": snap.retrain_time,
        "label_time": snap.label_time,
        "drift_events": snap.drift_events,
        "records": snap.records,
        "timeline": snap.timeline,
        "clock": snap.clock,
        "buffer_meta": {"capacity": snap.buffer["capacity"],
                        "rng_state": snap.buffer["rng_state"]},
    }
    blob = np.frombuffer(pickle.dumps(aux), dtype=np.uint8).copy()
    return {
        "params": snap.params,
        "opt": snap.opt,
        "buffer_x": bx if bx is not None else np.zeros((0,), np.float32),
        "buffer_y": by if by is not None else np.zeros((0,), np.int64),
        "aux": blob,
    }


def state_to_snapshot(state: Dict[str, object]) -> LaneSnapshot:
    """Decode :func:`snapshot_to_state` (the exact inverse)."""
    aux = pickle.loads(np.asarray(state["aux"]).tobytes())
    bx = np.asarray(state["buffer_x"])
    by = np.asarray(state["buffer_y"])
    meta = aux["buffer_meta"]
    return LaneSnapshot(
        key=aux["key"],
        params=state["params"],
        opt=state["opt"],
        buffer={"x": None if bx.size == 0 else bx,
                "y": None if by.size == 0 else by,
                "capacity": meta["capacity"],
                "rng_state": meta["rng_state"]},
        rng_state=aux["rng_state"],
        policy=aux["policy"],
        lane_state=aux["lane_state"],
        decision=aux["decision"],
        eval_cursor=aux["eval_cursor"],
        retrain_time=aux["retrain_time"],
        label_time=aux["label_time"],
        drift_events=aux["drift_events"],
        records=aux["records"],
        timeline=aux["timeline"],
        clock=aux["clock"],
    )


# ---------------------------------------------------------------- the manager
@dataclasses.dataclass
class ManagerEvent:
    """One entry of the manager's re-homing/recovery timeline."""

    round: int
    t: float  # manager virtual clock (fleet frontier) at the event
    kind: str  # "admit"|"reject"|"migrate"|"fail"|"recover"|"checkpoint"
    shard: int
    key: object = None
    to_shard: Optional[int] = None
    detail: str = ""


@dataclasses.dataclass
class _Shard:
    index: int
    session: FleetSession
    run: Optional[FleetRun] = None
    alive: bool = True
    t_tsa: float = 0.0
    t_bsa: float = 0.0
    recent_t_tsa: float = 0.0
    recent_phase_s: float = 0.0
    phases: int = 0
    trace_seen: int = 0  # cursor into the shard recorder's phase list


@dataclasses.dataclass
class ManagerResult:
    """One manager run: per-shard fleet results, flat per-lane lanes, the
    conserved two-level ledger, and the event/decision timelines."""

    name: str
    shard_results: List[Optional[FleetResult]]  # None for dead shards
    lane_results: Dict[object, CLResult]  # key -> final lane result
    fleet_avg_accuracy: float  # mean over all surviving lanes
    ledger: Dict[str, float]  # manager level: t_tsa/t_bsa/recovery_cost
    shard_ledgers: List[Dict[str, float]]
    events: List[ManagerEvent]
    decisions: List[ManagerDecision]
    rounds: int
    parallel_rounds: int = 0  # rounds stepped on the worker pool

    @property
    def n_shards(self) -> int:
        return len(self.shard_results)

    def conservation_gap(self) -> float:
        """|manager T-SA ledger − Σ shard T-SA ledgers| — zero modulo
        float re-association; recovery and migration costs are charged
        only at manager level, on top (``ledger['total']``)."""
        return abs(self.ledger["t_tsa"]
                   - sum(s["t_tsa"] for s in self.shard_ledgers))


class FleetManager:
    """Owns N shards and runs the fleet-of-fleets phase loop above them.

    ``spec`` is the :class:`~repro.core.fleet.FleetSpec` every shard is
    built from (one independent :class:`FleetSession` — its own mesh/
    sub-accelerator — per shard). The manager acts only at phase
    boundaries: admission, migration, per-lane checkpointing, and
    fault recovery all happen between :meth:`FleetRun.step` calls.

    ``checkpoint_dir=None`` disables durable checkpoints (recovery then
    restarts lost lanes fresh from the pretrained student);
    ``failure_injector`` is probed once per shard per round with
    ``key=shard_index``; ``recovery_cost_s`` is the explicit manager-level
    charge per re-homed lane (checkpoint read + re-home + re-jit, in
    virtual seconds), and ``migration_cost_s`` the analogous charge per
    policy migration (``ledger['migration_cost']``, included in
    ``ledger['total']`` — a move is never free; the ``estimator`` policy
    additionally *decides* with the same figure, so set both from one
    number).

    ``parallel_shards > 1`` steps the live shards' phases concurrently on
    a ``ThreadPoolExecutor`` of that many workers; ``0``/``1`` (default)
    keeps the serial loop. Either way every round ends at a barrier that
    charges ledgers, recovers failures, checkpoints, admits and migrates
    in shard-index order, so the overlapped loop is **bit-identical** to
    serial stepping: same records, same ``ManagerDecision`` stream, same
    two-level ledger (shard phases touch only shard-private state; the
    process-global kernel-stat counters and serving caches are locked;
    the failure injector is probed with deterministic ``(round, shard)``
    keys).

    ``shard_pace`` emulates each shard's own sub-accelerator executing in
    real time: after a shard's phase its worker blocks ``shard_pace``
    host-seconds per modeled phase-second before the barrier. On a host
    with fewer cores than shards this device-wait is what overlapped
    stepping actually hides (the host waits on N devices concurrently
    instead of one after another) and is what ``bench_manager``'s
    ``parallel`` section measures; pacing sleeps touch no state, so paced
    and unpaced, serial and parallel all produce the same result stream.
    """

    def __init__(self, spec: FleetSpec, n_shards: int = 2,
                 placement="headroom",
                 placement_kwargs: Optional[dict] = None,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 1,
                 migration: bool = True,
                 migration_cooldown: int = 2,
                 migration_cost_s: float = 0.0,
                 failure_injector: Optional[FailureInjector] = None,
                 recovery_cost_s: float = 0.0,
                 parallel_shards: int = 0,
                 shard_pace: float = 0.0):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.spec = spec
        self.placement = make_placement_policy(placement,
                                               **(placement_kwargs or {}))
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = max(1, checkpoint_every)
        self.migration = migration
        self.migration_cooldown = max(0, migration_cooldown)
        self.migration_cost_s = migration_cost_s
        self.failure_injector = failure_injector
        self.recovery_cost_s = recovery_cost_s
        self.parallel_shards = max(0, parallel_shards)
        self.shard_pace = shard_pace
        self.shards: List[_Shard] = [
            _Shard(index=i, session=spec.build()) for i in range(n_shards)]
        self.name = f"manager-{self.placement.name}x{n_shards}"
        self.events: List[ManagerEvent] = []
        self.decisions: List[ManagerDecision] = []
        self.ledger: Dict[str, float] = {
            "t_tsa": 0.0, "t_bsa": 0.0, "recovery_cost": 0.0,
            "migration_cost": 0.0}
        self.parallel_rounds = 0
        # Merged trace spine: when the fleet spec carries ``trace``, every
        # shard session records its own phases (each ``spec.build()`` gets
        # its own recorder) and the manager merges them at the round
        # barrier, in shard-index order — deterministic whatever order the
        # overlapped workers finish in. ``self.trace`` is the merged view.
        self.trace_phases: List[PhaseTrace] = []
        self._streams: Dict[object, object] = {}  # key -> source stream
        self._ckpts: Dict[object, CheckpointManager] = {}
        self._round = 0
        self._last_migration = -(10 ** 9)

    # ----------------------------------------------------------- pretrained
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def set_pretrained(self, teacher_params, student_params) -> None:
        """Install the shared pretrained teacher/student on every shard."""
        for shard in self.shards:
            shard.session.set_pretrained(teacher_params, student_params)

    # -------------------------------------------------------------- views
    def _views(self) -> List[ShardView]:
        views = []
        for shard in self.shards:
            run = shard.run
            drifted = 0
            if run is not None:
                drifted = sum(1 for lane in run.lanes
                              if lane.records and lane.records[-1].drift)
            views.append(ShardView(
                index=shard.index, alive=shard.alive,
                done=(run.done if run is not None else True),
                n_lanes=(len(run.lanes) if run is not None else 0),
                clock=(run.clock if run is not None else 0.0),
                t_tsa=shard.t_tsa, recent_t_tsa=shard.recent_t_tsa,
                drifted_lanes=drifted,
                recent_phase_s=shard.recent_phase_s))
        return views

    def _lane_views(self) -> List[LaneView]:
        lanes = []
        for shard in self.shards:
            if not shard.alive or shard.run is None:
                continue
            for lane in shard.run.lanes:
                lanes.append(LaneView(
                    shard=shard.index, index=lane.index, key=lane.key,
                    drifted=bool(lane.records and lane.records[-1].drift),
                    drift_events=lane.drift_events,
                    recent_t_tsa=(lane.records[-1].t_tsa
                                  if lane.records else 0.0)))
        return lanes

    def _frontier(self) -> float:
        live = [s.run.clock for s in self.shards
                if s.alive and s.run is not None and not s.run.done
                and s.run.lanes]
        if live:
            return min(live)
        any_run = [s.run.clock for s in self.shards if s.run is not None]
        return max(any_run) if any_run else 0.0

    # ------------------------------------------------------------- ledger
    def _charge(self, shard: _Shard) -> None:
        """Charge any newly-logged phases to both ledgers — once to the
        shard, once to the manager, same numbers: conservation by
        construction."""
        log = shard.run.fleet_phase_log
        for entry in log[shard.phases:]:
            shard.t_tsa += entry["t_tsa"]
            shard.t_bsa += entry["t_bsa"]
            shard.recent_t_tsa = entry["t_tsa"]
            shard.recent_phase_s = entry["t"] - entry["phase_start"]
            self.ledger["t_tsa"] += entry["t_tsa"]
            self.ledger["t_bsa"] += entry["t_bsa"]
        shard.phases = len(log)
        self._drain_trace(shard)

    # -------------------------------------------------------------- trace
    def _drain_trace(self, shard: _Shard) -> None:
        """Pull the shard recorder's newly-completed phases into the
        manager's merged trace, stamping their shard index. Called only at
        the round barrier, in shard-index order, so the merged event
        stream is identical for serial and overlapped stepping."""
        recorder = shard.session.dispatcher.recorder
        if recorder is None:
            return
        for phase in recorder.drain_since(shard.trace_seen):
            phase.shard = shard.index
            self.trace_phases.append(phase)
        shard.trace_seen = len(recorder.phases)

    @property
    def trace(self) -> SessionTrace:
        """The barrier-merged manager trace (empty when tracing is off)."""
        return SessionTrace(phases=self.trace_phases,
                            meta={"tier": "manager", "name": self.name})

    # -------------------------------------------------------- checkpoints
    def _ckpt_for(self, key: object) -> Optional[CheckpointManager]:
        if self.checkpoint_dir is None:
            return None
        if key not in self._ckpts:
            self._ckpts[key] = CheckpointManager(
                os.path.join(self.checkpoint_dir, f"lane_{key}"),
                max_to_keep=2)
        return self._ckpts[key]

    def _checkpoint_lanes(self) -> None:
        for shard in self.shards:
            if not shard.alive or shard.run is None or shard.run.done:
                continue
            for i, lane in enumerate(shard.run.lanes):
                mgr = self._ckpt_for(lane.key)
                if mgr is None:
                    continue
                snap = shard.run.snapshot_lane(i)
                mgr.save(self._round, snapshot_to_state(snap),
                         metadata={"key": str(lane.key),
                                   "shard": shard.index,
                                   "clock": snap.clock})
        if self.checkpoint_dir is not None:
            self.events.append(ManagerEvent(
                round=self._round, t=self._frontier(), kind="checkpoint",
                shard=-1, detail=f"round {self._round}"))

    def _restore_snapshot(self, key: object) -> Optional[LaneSnapshot]:
        mgr = self._ckpt_for(key)
        if mgr is None:
            return None
        mgr.wait()  # join any in-flight async save before reading
        step = mgr.latest_step()
        if step is None:
            return None
        shard = next(s for s in self.shards if s.alive)
        like = snapshot_to_state(_template_snapshot(shard.session))
        state, _ = mgr.restore(step, like)
        return state_to_snapshot(state)

    # ----------------------------------------------------------- recovery
    def _fail_shard(self, shard: _Shard, reason: str,
                    placements: List[PlacementAction]) -> None:
        """Accelerator loss on ``shard``: mark it dead (its accumulated
        ledger stays — that work happened), restore every lane from its
        last durable checkpoint (fresh from the pretrained student if it
        never checkpointed), and re-home across survivors; each re-homed
        lane costs ``recovery_cost_s`` on the manager ledger."""
        shard.alive = False
        self._drain_trace(shard)  # keep any completed phases of the dead
        t = self._frontier()
        self.events.append(ManagerEvent(
            round=self._round, t=t, kind="fail", shard=shard.index,
            detail=reason))
        lost = [(lane.key, lane.index) for lane in shard.run.lanes]
        shard.run.close()
        shard.run = None
        survivors = [s for s in self.shards
                     if s.alive and s.run is not None and not s.run.done]
        if not survivors:
            raise RuntimeError(
                f"shard {shard.index} failed with no surviving shards")
        for key, _ in lost:
            snap = self._restore_snapshot(key)
            views = self._views()
            target = next(s for s in self.shards
                          if s.index == self.placement.place(views))
            # A recovered lane gets a FRESH pipeline over the source
            # stream — the dead shard's speculation state died with it.
            pipe = FramePipeline(
                self._streams[key],
                speculative=target.session.speculative_frames)
            target.run.attach_lane(pipe, key=key, snapshot=snap, own=True)
            self.ledger["recovery_cost"] += self.recovery_cost_s
            detail = ("restored from checkpoint" if snap is not None
                      else "no checkpoint; restarted fresh")
            placements.append(PlacementAction(
                kind="recover", key=key, to_shard=target.index,
                from_shard=shard.index, reason=detail))
            self.events.append(ManagerEvent(
                round=self._round, t=t, kind="recover", shard=shard.index,
                key=key, to_shard=target.index, detail=detail))

    # ---------------------------------------------------------- migration
    def _maybe_migrate(self, placements: List[PlacementAction]) -> None:
        if not self.migration:
            return
        if self._round - self._last_migration < self.migration_cooldown:
            return
        proposal = self.placement.migrate(self._views(), self._lane_views())
        if proposal is None:
            return
        lane_view, target_idx = proposal
        src = self.shards[lane_view.shard]
        tgt = self.shards[target_idx]
        snap, pipe = src.run.detach_lane(lane_view.index)
        tgt.run.attach_lane(pipe, snapshot=snap, own=True)
        self._last_migration = self._round
        self.ledger["migration_cost"] += self.migration_cost_s
        placements.append(PlacementAction(
            kind="migrate", key=lane_view.key, to_shard=target_idx,
            from_shard=src.index, reason="placement-policy migration"))
        self.events.append(ManagerEvent(
            round=self._round, t=self._frontier(), kind="migrate",
            shard=src.index, key=lane_view.key, to_shard=target_idx,
            detail=f"lane {lane_view.key}: shard {src.index} -> "
                   f"{target_idx}"))

    # --------------------------------------------------------- round step
    def _step_shard(self, shard: _Shard) -> None:
        """One round's unit of work for one shard — the piece the worker
        pool overlaps. Probes the failure injector (keyed by
        ``(round, shard)``, so the outcome is deterministic whichever
        thread runs it), executes one fleet phase, and, when
        ``shard_pace`` is set, blocks for the phase's modeled device
        occupancy. Touches only shard-private state: ledger charges and
        membership changes happen at the barrier, in shard-index order."""
        if self.failure_injector is not None:
            self.failure_injector.maybe_fail(self._round, key=shard.index)
        shard.run.step()
        if self.shard_pace > 0.0:
            busy = sum(entry["t"] - entry["phase_start"]
                       for entry in
                       shard.run.fleet_phase_log[shard.phases:])
            if busy > 0.0:
                time.sleep(self.shard_pace * busy)

    # ---------------------------------------------------------------- run
    def run(self, streams: Union[Sequence, Dict[object, object]],
            duration: Optional[float] = None,
            admissions: Sequence[Tuple[float, object, object]] = (),
            observers: Sequence = ()) -> ManagerResult:
        """Run the fleet-of-fleets to ``duration``.

        ``streams``: the initial cameras — a sequence of streams/pipelines
        (keys auto-assigned ``cam0..``) or a dict ``key -> stream``.
        Initial placement groups them shard-by-shard via the placement
        policy, then opens each shard's run through
        :meth:`FleetSession.open_run` — a 1-shard manager therefore takes
        the exact code path of :meth:`FleetSession.run` (the degeneracy
        golden). ``admissions`` is a sequence of ``(t, key, stream)``:
        each camera joins at the first phase boundary where the fleet
        frontier has reached ``t``.
        """
        if isinstance(streams, dict):
            items = list(streams.items())
        else:
            items = [(f"cam{i}", s) for i, s in enumerate(streams)]
        self.placement.reset(len(self.shards))
        self.events, self.decisions = [], []
        self.ledger = {"t_tsa": 0.0, "t_bsa": 0.0, "recovery_cost": 0.0,
                       "migration_cost": 0.0}
        self.parallel_rounds = 0
        self._round = 0
        self._last_migration = -(10 ** 9)

        # Initial placement: policy-placed, then one open_run per shard so
        # the per-shard loop is the exact FleetSession.run code path.
        groups: List[List[Tuple[object, object]]] = [
            [] for _ in self.shards]
        for key, stream in items:
            views = [ShardView(index=i, alive=True, done=False,
                               n_lanes=len(groups[i]), clock=0.0,
                               t_tsa=0.0, recent_t_tsa=0.0,
                               drifted_lanes=0)
                     for i in range(len(self.shards))]
            groups[self.placement.place(views)].append((key, stream))
            self._streams[key] = stream
        for shard, group in zip(self.shards, groups):
            shard.run = shard.session.open_run(
                [s for _, s in group], duration=duration,
                observers=observers)
            for lane, (key, _) in zip(shard.run.lanes, group):
                lane.key = key
        pending = sorted(admissions, key=lambda a: a[0])
        pending = list(pending)

        # ------------------------------------------------ the round loop
        pool: Optional[ThreadPoolExecutor] = None
        if self.parallel_shards > 1 and len(self.shards) > 1:
            pool = ThreadPoolExecutor(
                max_workers=min(self.parallel_shards, len(self.shards)),
                thread_name_prefix="shard-step")
        try:
            self._round_loop(pool, pending)
        finally:
            if pool is not None:
                pool.shutdown(wait=True)

        # ------------------------------------------------------ finalize
        for mgr in self._ckpts.values():
            mgr.close()  # flush any in-flight async saves
        shard_results: List[Optional[FleetResult]] = []
        lane_results: Dict[object, CLResult] = {}
        for shard in self.shards:
            if not shard.alive:
                shard_results.append(None)
                continue
            result = shard.run.finalize()
            shard_results.append(result)
            for lane, lane_result in zip(shard.run.lanes, result.streams):
                lane_results[lane.key] = lane_result
            shard.run.close()
        accs = [r.avg_accuracy for r in lane_results.values()]
        return ManagerResult(
            name=self.name,
            shard_results=shard_results,
            lane_results=lane_results,
            fleet_avg_accuracy=float(np.mean(accs)) if accs else 0.0,
            ledger={**self.ledger,
                    "total": self.ledger["t_tsa"]
                    + self.ledger["recovery_cost"]
                    + self.ledger["migration_cost"]},
            shard_ledgers=[{"t_tsa": s.t_tsa, "t_bsa": s.t_bsa}
                           for s in self.shards],
            events=self.events,
            decisions=self.decisions,
            rounds=self._round,
            parallel_rounds=self.parallel_rounds,
        )

    def _round_loop(self, pool: Optional[ThreadPoolExecutor],
                    pending: List[Tuple[float, object, object]]) -> None:
        """Rounds until every shard drains. Each round has two halves:
        the **step phase** — every live shard's :meth:`_step_shard`, on
        the pool when one is given (overlapped) or inline (serial) — and
        the **barrier**, which replays outcomes in shard-index order:
        charges for survivors, recovery for failures, then checkpointing,
        admission and migration. Joining futures in shard-index order and
        doing ALL bookkeeping at the barrier is what makes the overlapped
        loop bit-identical to the serial one whatever order workers
        finish in."""
        while any(s.alive and s.run is not None and not s.run.done
                  and s.run.lanes for s in self.shards):
            placements: List[PlacementAction] = []
            stepping = [s for s in self.shards
                        if s.alive and s.run is not None
                        and not s.run.done and s.run.lanes]
            failures: Dict[int, str] = {}
            if pool is not None and len(stepping) > 1:
                self.parallel_rounds += 1
                futures = {s.index: pool.submit(self._step_shard, s)
                           for s in stepping}
                for shard in stepping:
                    try:
                        futures[shard.index].result()
                    except RuntimeError as e:
                        failures[shard.index] = str(e)
            else:
                for shard in stepping:
                    try:
                        self._step_shard(shard)
                    except RuntimeError as e:
                        failures[shard.index] = str(e)
            for shard in stepping:
                if shard.index in failures:
                    self._fail_shard(shard, failures[shard.index],
                                     placements)
                else:
                    self._charge(shard)
            live = [s for s in self.shards
                    if s.alive and s.run is not None and not s.run.done]
            # An idle (empty) shard's virtual clock tracks the fleet
            # frontier — it sits ready; time passes. A lane attached to
            # it later starts scoring from the join point, not t=0.
            frontier = self._frontier()
            for shard in live:
                if not shard.run.lanes:
                    shard.run.clock = max(shard.run.clock, frontier)
            if live:
                # Per-lane checkpoints every checkpoint_every rounds
                # (side-effect free on the live lanes).
                if (self._round + 1) % self.checkpoint_every == 0:
                    self._checkpoint_lanes()
                # Due admissions: cameras whose join time the fleet
                # frontier has passed.
                frontier = self._frontier()
                while pending and pending[0][0] <= frontier:
                    t_at, key, stream = pending.pop(0)
                    views = self._views()
                    target_idx = self.placement.admit(views)
                    if target_idx is None:
                        # Every shard oversubscribed: the camera is turned
                        # away — explicit degraded service, recorded in
                        # the decision stream, never a silent drop.
                        placements.append(PlacementAction(
                            kind="reject", key=key, to_shard=None,
                            reason=f"admission due at t={t_at:g}: "
                                   f"fleet oversubscribed"))
                        self.events.append(ManagerEvent(
                            round=self._round, t=frontier, kind="reject",
                            shard=-1, key=key,
                            detail=f"due t={t_at:g}: oversubscribed"))
                        continue
                    self._streams[key] = stream
                    target = next(s for s in self.shards
                                  if s.index == target_idx)
                    target.run.attach_lane(stream, key=key)
                    placements.append(PlacementAction(
                        kind="admit", key=key, to_shard=target.index,
                        reason=f"admission due at t={t_at:g}"))
                    self.events.append(ManagerEvent(
                        round=self._round, t=frontier, kind="admit",
                        shard=target.index, key=key,
                        detail=f"due t={t_at:g}"))
                self._maybe_migrate(placements)
            self.decisions.append(ManagerDecision(
                shards=tuple(
                    (s.run.fleet_dec
                     if s.alive and s.run is not None and not s.run.done
                     else None)
                    for s in self.shards),
                placements=tuple(placements)))
            self._round += 1


def _template_snapshot(session: FleetSession) -> LaneSnapshot:
    """A structure-only :class:`LaneSnapshot` used as the ``like`` tree
    for :meth:`CheckpointManager.restore` — array *structures* must match
    the saved state (shapes are immaterial to npz restore; the aux blob
    and buffer arrays are single leaves)."""
    params = session.student_params
    return LaneSnapshot(
        key=None, params=params,
        opt=session.retrain.init_state(params),
        buffer={"x": np.zeros((0,), np.float32),
                "y": np.zeros((0,), np.int64),
                "capacity": session.hp.c_b, "rng_state": {}},
        rng_state={}, policy=None, lane_state=(), decision=None,
        eval_cursor=0.0, retrain_time=0.0, label_time=0.0,
        drift_events=0, records=[], timeline=[], clock=0.0)


@dataclasses.dataclass
class ManagerSpec:
    """Declarative front door for the manager tier, mirroring
    :class:`~repro.core.fleet.FleetSpec`: one fleet spec for every shard
    plus the manager surface (shard count, placement policy and knobs,
    checkpointing, migration and its ledger cost, failure injection,
    recovery cost, and the overlapped-stepping knobs ``parallel_shards``
    — worker-pool size, 0/1 = serial, bit-identical either way — and
    ``shard_pace`` — emulated device seconds of real blocking per modeled
    phase-second; see :class:`FleetManager`)."""

    fleet: FleetSpec
    n_shards: int = 2
    placement: object = "headroom"  # name, class, or ready instance
    placement_kwargs: Optional[dict] = None
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 1
    migration: bool = True
    migration_cooldown: int = 2
    migration_cost_s: float = 0.0
    failure_injector: Optional[FailureInjector] = None
    recovery_cost_s: float = 0.0
    parallel_shards: int = 0
    shard_pace: float = 0.0
    # Trace spine: ``True`` gives EVERY shard its own fresh recorder (one
    # per ``fleet.build()``), merged at the manager's round barrier into
    # ``FleetManager.trace``. Prefer True over a shared recorder instance
    # here — shards step concurrently under ``parallel_shards``.
    trace: object = None

    def build(self) -> FleetManager:
        fleet = self.fleet
        if self.trace is not None:
            fleet = dataclasses.replace(fleet, trace=self.trace)
        return FleetManager(
            fleet, n_shards=self.n_shards, placement=self.placement,
            placement_kwargs=self.placement_kwargs,
            checkpoint_dir=self.checkpoint_dir,
            checkpoint_every=self.checkpoint_every,
            migration=self.migration,
            migration_cooldown=self.migration_cooldown,
            migration_cost_s=self.migration_cost_s,
            failure_injector=self.failure_injector,
            recovery_cost_s=self.recovery_cost_s,
            parallel_shards=self.parallel_shards,
            shard_pace=self.shard_pace)
