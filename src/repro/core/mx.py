"""MX precision as a first-class training/inference feature.

``mx_dense`` is a drop-in matmul whose forward runs at a configurable MX
precision (MX6 for inference/labeling, MX9 for retraining — the paper's §IV
operating points) with a straight-through-estimator backward at MX9. The
forward routes through the FUSED quantize→matmul entry
(``ops.mx_matmul_fused``); the backward routes through the BACKWARD PAIR
(``ops.mx_matmul_bwd_pair``): dX and dW are emitted by ONE program, the
cotangent resident across both gradient GEMMs — the paper's §V-C
precision-conversion unit producing transposed MX blocks so both consumers
share it. Quantization happens inside the matmul (in VMEM on the Pallas
path, in one jit on CPU hosts); MX mantissas/scales never materialize
between ops, and the whole backward is one launch instead of two.

Serving weights come in two resident forms:

* ``quantize_tree`` — legacy fake-quant: fp32 trees carrying the MX
  rounding, consumed by unmodified ``model.apply``.
* ``quantize_tree_mx`` / ``dequantize_tree_mx`` — the RESIDENT form:
  weight leaves stored as actual MX representations (int8 mantissas +
  shared exponents, ~3.5× smaller than fp32). ``dequantize_tree_mx``
  reproduces ``quantize_tree``'s output bit-for-bit, so legacy apply
  paths are unchanged; ``mx_dense_prequant`` consumes rhs-layout resident
  weights (``ops.mx_quantize_rhs``) directly with zero per-call weight
  quantization. The per-kernel cache over these lives in core/kernel.py
  (``ServingParamsCache``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Per-kernel MX precisions (paper §IV step 2)."""

    inference: str = "mx6"
    labeling: str = "mx6"
    retraining: str = "mx9"
    backward: str = "mx9"


DEFAULT_POLICY = PrecisionPolicy()


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def mx_dense(x: jax.Array, w: jax.Array, fwd_prec: str = "mx9",
             bwd_prec: str = "mx9") -> jax.Array:
    """x [..., K] @ w [K, N] with MX quantization of both operands, fused
    into the matmul (one program per GEMM — ``ops.mx_matmul_fused``).

    Differentiable: backward quantizes the incoming cotangent and the saved
    operands at ``bwd_prec`` (straight-through estimator), mirroring the
    paper's MX9 retraining path where the precision-conversion unit emits
    column-major (transposed) MX blocks for the gradient GEMMs (§V-C).
    """
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    y = ops.mx_matmul_fused(x2, w, fwd_prec, fwd_prec)
    return y.reshape(*shape[:-1], w.shape[-1]).astype(x.dtype)


def _mx_dense_fwd(x, w, fwd_prec, bwd_prec):
    return mx_dense(x, w, fwd_prec, bwd_prec), (x, w)


def _mx_dense_bwd(fwd_prec, bwd_prec, res, g):
    x, w = res
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    g2 = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
    # dX = g @ W^T ; dW = X^T @ g — ONE backward-pair program at bwd_prec,
    # bit-identical to the former two independent fused launches.
    dx, dw = ops.mx_matmul_bwd_pair(g2, x2, w, bwd_prec)
    return dx.reshape(shape).astype(x.dtype), dw.astype(w.dtype)


mx_dense.defvjp(_mx_dense_fwd, _mx_dense_bwd)


@functools.partial(jax.jit, static_argnames=("precision",))
def _fake_quant(x, precision: str):
    from repro.kernels import ref as _ref

    flat = x.reshape(-1, x.shape[-1])
    pad = (-flat.shape[-1]) % _ref.BLOCK
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    y = _ref.mx_quant_dequant_ref(flat, precision)
    if pad:
        y = y[:, : x.shape[-1]]
    return y.reshape(x.shape).astype(x.dtype)


def quantize_tree(params, precision: str, min_size: int = 1024):
    """Fake-quant every >=2D parameter (weights) in a pytree to ``precision``.

    Used to run student inference / teacher labeling at MX6 while the
    retraining master copy stays fp32 (the paper's precision-flexible SAs).
    Uses the jitted jnp reference path (bit-identical to the kernel; the
    Pallas kernel is for TPU, interpret mode is too slow for host loops).
    """
    def q(p):
        if not isinstance(p, jax.Array) and not hasattr(p, "ndim"):
            return p
        if p.ndim < 2 or p.size < min_size or not jnp.issubdtype(
                p.dtype, jnp.floating):
            return p
        return _fake_quant(p, precision)

    return jax.tree_util.tree_map(q, params)


@dataclasses.dataclass(frozen=True)
class MXLeaf:
    """A weight leaf held in its RESIDENT quantized MX form.

    ``q`` is the actual MX representation (int8 mantissas, shared
    exponents, micro-exponent bits) of the leaf flattened to
    [-1, last_dim] and padded to a 16 multiple; ``shape``/``dtype``/``k``
    record what an exact round trip back to the fake-quant fp32 leaf
    needs. Deliberately NOT a pytree node: tree_maps over a quantized
    tree see it as one opaque leaf."""

    q: object  # kernels.ref.MXTensor
    shape: tuple
    dtype: object
    k: int


@functools.partial(jax.jit, static_argnames=("precision",))
def _quant_leaf(x, precision: str):
    from repro.kernels import ref as _ref

    flat = x.reshape(-1, x.shape[-1])
    pad = (-flat.shape[-1]) % _ref.BLOCK
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return _ref.mx_quantize_ref(flat, precision)


@functools.partial(jax.jit, static_argnames=("k", "shape", "dtype"))
def _dequant_leaf(q, k: int, shape, dtype):
    from repro.kernels import ref as _ref

    y = _ref.mx_dequantize_ref(q)
    if y.shape[-1] != k:
        y = y[:, :k]
    return y.reshape(shape).astype(dtype)


def _quantizable(p, min_size: int) -> bool:
    if not isinstance(p, jax.Array) and not hasattr(p, "ndim"):
        return False
    return (p.ndim >= 2 and p.size >= min_size
            and jnp.issubdtype(p.dtype, jnp.floating))


def quantize_tree_mx(params, precision: str, min_size: int = 1024):
    """Quantize every >=2D weight into its RESIDENT MX representation.

    Same leaf predicate as :func:`quantize_tree`, but the quantized leaves
    are stored as ``MXLeaf`` (int8 mantissas + shared exponents — the
    ~3.5×-smaller copy ``ServingParamsCache`` keeps resident) instead of
    being immediately dequantized back to fp32. ``dequantize_tree_mx``
    reproduces ``quantize_tree(params, precision)`` bit-for-bit: the
    quantize and dequantize halves here are exactly the two halves of
    ``_fake_quant``'s round trip.
    """
    def q(p):
        if not _quantizable(p, min_size):
            return p
        return MXLeaf(_quant_leaf(p, precision), tuple(p.shape), p.dtype,
                      int(p.shape[-1]))

    return jax.tree_util.tree_map(q, params)


def dequantize_tree_mx(qtree):
    """Expand a :func:`quantize_tree_mx` tree back to the fake-quant fp32
    serving tree legacy ``model.apply`` paths consume — bit-identical to
    ``quantize_tree`` on the source tree."""
    def dq(p):
        if isinstance(p, MXLeaf):
            return _dequant_leaf(p.q, p.k, p.shape, p.dtype)
        return p

    return jax.tree_util.tree_map(
        dq, qtree, is_leaf=lambda p: isinstance(p, MXLeaf))


def mx_dense_prequant(x: jax.Array, qw, fwd_prec: str = "mx6") -> jax.Array:
    """Weight-resident serving matmul: ``x [..., K]`` against a weight
    already stored in rhs layout (``ops.mx_quantize_rhs(w, precision)``).
    Bit-identical to ``mx_dense(x, w, fwd_prec, ...)``'s forward, with
    zero weight-quantization work per call. Serving only — no VJP;
    retraining goes through ``mx_dense``."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    y = ops.mx_matmul_prequant(x2, qw, fwd_prec)
    return y.reshape(*shape[:-1], y.shape[-1]).astype(x.dtype)


def activation_quant(x: jax.Array, precision: Optional[str]) -> jax.Array:
    """Straight-through activation fake-quant (identity gradient)."""
    if precision is None:
        return x
    y = ops.mx_quant_dequant(x, precision)
    return x + jax.lax.stop_gradient(y - x)
