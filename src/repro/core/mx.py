"""MX precision as a first-class training/inference feature.

``mx_dense`` is a drop-in matmul whose forward runs at a configurable MX
precision (MX6 for inference/labeling, MX9 for retraining — the paper's §IV
operating points) with a straight-through-estimator backward at MX9. The
forward AND both gradient GEMMs route through the FUSED quantize→matmul
entry (``ops.mx_matmul_fused``): one program per GEMM, quantization happens
inside the matmul (in VMEM on the Pallas path, in one jit on CPU hosts) —
MX mantissas/scales never materialize between ops. Model quantization
helpers fake-quant whole parameter trees for MX inference; the per-kernel
serving-copy *cache* over those trees lives in core/kernel.py
(``ServingParamsCache``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Per-kernel MX precisions (paper §IV step 2)."""

    inference: str = "mx6"
    labeling: str = "mx6"
    retraining: str = "mx9"
    backward: str = "mx9"


DEFAULT_POLICY = PrecisionPolicy()


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def mx_dense(x: jax.Array, w: jax.Array, fwd_prec: str = "mx9",
             bwd_prec: str = "mx9") -> jax.Array:
    """x [..., K] @ w [K, N] with MX quantization of both operands, fused
    into the matmul (one program per GEMM — ``ops.mx_matmul_fused``).

    Differentiable: backward quantizes the incoming cotangent and the saved
    operands at ``bwd_prec`` (straight-through estimator), mirroring the
    paper's MX9 retraining path where the precision-conversion unit emits
    column-major (transposed) MX blocks for the gradient GEMMs (§V-C).
    """
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    y = ops.mx_matmul_fused(x2, w, fwd_prec, fwd_prec)
    return y.reshape(*shape[:-1], w.shape[-1]).astype(x.dtype)


def _mx_dense_fwd(x, w, fwd_prec, bwd_prec):
    return mx_dense(x, w, fwd_prec, bwd_prec), (x, w)


def _mx_dense_bwd(fwd_prec, bwd_prec, res, g):
    x, w = res
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    g2 = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
    # dX = g @ W^T ; dW = X^T @ g — both through fused MX at bwd_prec.
    dx = ops.mx_matmul_fused(g2, w.T, bwd_prec, bwd_prec)
    dw = ops.mx_matmul_fused(x2.T, g2, bwd_prec, bwd_prec)
    return dx.reshape(shape).astype(x.dtype), dw.astype(w.dtype)


mx_dense.defvjp(_mx_dense_fwd, _mx_dense_bwd)


@functools.partial(jax.jit, static_argnames=("precision",))
def _fake_quant(x, precision: str):
    from repro.kernels import ref as _ref

    flat = x.reshape(-1, x.shape[-1])
    pad = (-flat.shape[-1]) % _ref.BLOCK
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    y = _ref.mx_quant_dequant_ref(flat, precision)
    if pad:
        y = y[:, : x.shape[-1]]
    return y.reshape(x.shape).astype(x.dtype)


def quantize_tree(params, precision: str, min_size: int = 1024):
    """Fake-quant every >=2D parameter (weights) in a pytree to ``precision``.

    Used to run student inference / teacher labeling at MX6 while the
    retraining master copy stays fp32 (the paper's precision-flexible SAs).
    Uses the jitted jnp reference path (bit-identical to the kernel; the
    Pallas kernel is for TPU, interpret mode is too slow for host loops).
    """
    def q(p):
        if not isinstance(p, jax.Array) and not hasattr(p, "ndim"):
            return p
        if p.ndim < 2 or p.size < min_size or not jnp.issubdtype(
                p.dtype, jnp.floating):
            return p
        return _fake_quant(p, precision)

    return jax.tree_util.tree_map(q, params)


def activation_quant(x: jax.Array, precision: Optional[str]) -> jax.Array:
    """Straight-through activation fake-quant (identity gradient)."""
    if precision is None:
        return x
    y = ops.mx_quant_dequant(x, precision)
    return x + jax.lax.stop_gradient(y - x)
