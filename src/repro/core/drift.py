"""Data-drift detection (Algorithm 1, line 11).

Drift is flagged when the freshly-labeled stream accuracy falls below the
buffer-validation accuracy by more than V_thr: the model fits its buffer but
the world moved.
"""
from __future__ import annotations

import dataclasses
from typing import List


@dataclasses.dataclass
class DriftDetector:
    v_thr: float = -0.05  # acc_l - acc_v < v_thr  ==>  drift
    history: List[dict] = dataclasses.field(default_factory=list)

    def check(self, acc_label: float, acc_valid: float, t: float) -> bool:
        drift = (acc_label - acc_valid) < self.v_thr
        self.history.append(
            {"t": t, "acc_label": acc_label, "acc_valid": acc_valid,
             "drift": drift})
        return drift
