"""FleetSession — multi-camera fleet sessions on one spatially-shared array.

DaCapo's deployment story (paper §2, §5) is an autonomous system serving
*several* camera feeds from one accelerator: every feed needs its own
inference timeline on the B-SA while labeling and retraining for all feeds
compete for the single T-SA. Ekya frames the same setting as a multi-tenant
scheduling problem over shared retraining compute; ECCO shows the accuracy
is won by sharing the labeling/retraining budget *across* cameras. This
module is that layer: the engine that turns N independent
:class:`~repro.data.stream.DriftStream`s into one fleet session.

Architecture (see ROADMAP.md):

* each stream gets its own **data-plane lane** — a
  :class:`~repro.data.pipeline.FramePipeline` with per-stream speculation
  state, a per-stream :class:`~repro.core.session._ScoreSink` (its B-SA
  serving/accuracy timeline), a per-stream
  :class:`~repro.core.sample_buffer.SampleBuffer`, student weights and
  optimizer state, and a per-stream :class:`~repro.core.session.PhaseRecord`
  record lane (``record.stream`` carries the lane id);
* one **shared plan** per fleet phase: the
  :class:`~repro.core.dispatch.KernelDispatcher` binds all N pipelines to a
  single :class:`~repro.core.dispatch.PhasePlan` whose T-SA ledger is
  charged once for the fleet while each charge is also attributed to its
  lane (``plan.lane_time``) — the virtual clock pays for the shared T-SA,
  not for N copies of it;
* labeling bursts are **batched across streams** on the shared T-SA
  (:meth:`~repro.core.kernel.LabelingKernel.label_fleet_async` via
  ``plan.dispatch_multi``): one microbatched device program labels the whole
  fleet's burst, and per-lane label handles split back out device-side;
* each phase executes ONE :class:`~repro.core.decision.FleetDecision`: a
  :class:`~repro.core.allocation.FleetAllocator` proportions the fleet's
  temporal budget across streams (uniform / round-robin / drift-weighted /
  isolated) into N per-lane :class:`~repro.core.decision.TemporalPlan`s,
  while a pluggable :class:`~repro.core.decision.FleetRowPolicy` resolves
  the N per-lane spatial requests into the ONE fleet-wide
  :class:`~repro.core.decision.SpatialPlan` the engine executes
  (``resolve-max`` reproduces the pre-plane max/min resolution
  bit-for-bit; ``drift-surge`` grows the fleet T-SA under multi-lane drift
  with hysteresis; ``weighted-vote`` follows the drift-weighted temporal
  shares). Each lane still keeps an ordinary per-stream
  :class:`~repro.core.allocation.AllocationPolicy` underneath.

Degeneracy contract: a **1-stream fleet is bit-identical to**
:class:`~repro.core.session.CLSession` — same records (including per-phase
``t_tsa``/``t_bsa`` and speculation counters), same accuracy timeline, same
virtual clock. The fleet loop is the session loop generalized over lanes;
every float accumulation it performs at N=1 replays the single-stream
sequence exactly, and ``tests/test_fleet.py`` pins that against the seed
goldens of ``tests/test_session.py``.
"""
from __future__ import annotations

import copy
import dataclasses
import time
from typing import List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.configs.dacapo_pairs import VisionConfig
from repro.core.allocation import (
    AllocationDecision,
    CLHyperParams,
    FleetAllocator,
    PhaseFeedback,
)
from repro.core.decision import FleetDecision
from repro.core.sample_buffer import SampleBuffer
from repro.core.session import (
    CLResult,
    CLSession,
    CLSystemSpec,
    PhaseObserver,
    PhaseRecord,
    _ScoreSink,
    flush_sinks_batched,
)
from repro.data.pipeline import FramePipeline
from repro.data.stream import DriftStream
from repro.runtime.elastic import rehome_tree


@dataclasses.dataclass
class _StreamLane:
    """Per-stream engine state: one camera's data plane + learning state."""

    index: int
    pipe: FramePipeline  # ownership is tracked by FleetSession.run
    buffer: SampleBuffer
    sink: _ScoreSink
    rng: np.random.Generator
    params: object  # this stream's student weights (master, fp32)
    opt: object
    serving: object  # quantized serving copy of ``params``
    decision: AllocationDecision
    keep_frac: float = 1.0
    eval_cursor: float = 0.0
    retrain_time: float = 0.0
    label_time: float = 0.0
    drift_events: int = 0
    records: List[PhaseRecord] = dataclasses.field(default_factory=list)
    # per-phase scratch
    spec_seen: Tuple[int, int] = (0, 0)
    acc_v: float = 1.0
    valid_h: object = None
    yv: object = None
    label_h: object = None
    pred_l_h: object = None
    x_l: object = None
    # manager-tier identity + migration carry-over
    key: object = None  # stable camera id across shards (None: anonymous)
    timeline_prefix: List = dataclasses.field(default_factory=list)
    # accuracy timeline accrued on previous shards, prepended at finalize


@dataclasses.dataclass
class LaneSnapshot:
    """A lane frozen at a phase boundary — the unit of migration and
    per-lane checkpointing in the manager tier.

    Everything a lane needs to resume *bit-identically* on another
    :class:`FleetSession` (same model/kernel configs): host copies of the
    student weights and optimizer state, the :class:`SampleBuffer` state
    dict (samples + draw-RNG bit-generator state), the lane RNG's
    bit-generator state, a deep copy of the lane's live
    :class:`~repro.core.allocation.AllocationPolicy` (its drift detector
    and online row state), the fleet-side lane state
    (:meth:`~repro.core.allocation.FleetAllocator.lane_policy_state`), and
    the accounting carried into the next shard's records (cursor, times,
    records, accuracy timeline, the virtual clock at capture).
    """

    key: object
    params: object  # host (numpy) student tree
    opt: object  # host optimizer tree
    buffer: dict  # SampleBuffer.state_dict()
    rng_state: dict  # np bit-generator state
    policy: object  # deep-copied lane AllocationPolicy
    lane_state: tuple  # FleetAllocator.lane_policy_state(i)
    decision: object  # the lane's current AllocationDecision
    eval_cursor: float
    retrain_time: float
    label_time: float
    drift_events: int
    records: List[PhaseRecord]
    timeline: List  # accuracy timeline accrued so far
    clock: float  # virtual clock at capture (phase boundary)


@dataclasses.dataclass
class FleetResult:
    """One fleet run: per-stream :class:`CLResult` lanes + fleet ledger."""

    name: str
    streams: List[CLResult]
    fleet_avg_accuracy: float  # mean of the per-stream averages
    fleet_phase_log: List[dict]  # per-phase shared-T-SA/B-SA ledger
    drift_events: int  # total across streams

    @property
    def n_streams(self) -> int:
        return len(self.streams)


class FleetSession(CLSession):
    """Executes fleet allocation decisions phase-by-phase for N streams.

    Construction mirrors :class:`CLSession`; ``allocator`` is either a ready
    :class:`FleetAllocator` or a per-stream policy (registry name / class /
    instance) that gets wrapped in one, with ``fleet_mode`` /
    ``fleet_budget_streams`` / ``fleet_kwargs`` configuring the wrapper.
    All streams share the student/teacher model pair (one jitted apply per
    kernel for the whole fleet) but keep independent weights, buffers and
    drift state per lane.
    """

    def __init__(self, student_cfg: VisionConfig, teacher_cfg: VisionConfig,
                 hp: Optional[CLHyperParams] = None, estimator=None,
                 allocator="dacapo-spatiotemporal",
                 fleet_mode: str = "drift-weighted",
                 fleet_budget_streams: float = 1.0,
                 fleet_row_policy="resolve-max",
                 fleet_kwargs: Optional[dict] = None,
                 fleet_serve_batched: bool = False, **kwargs):
        hp = hp or CLHyperParams()
        if not isinstance(allocator, FleetAllocator):
            allocator = FleetAllocator(
                hp, policy=allocator, mode=fleet_mode,
                budget_streams=fleet_budget_streams,
                row_policy=fleet_row_policy, **(fleet_kwargs or {}))
        super().__init__(student_cfg, teacher_cfg, hp=hp,
                         estimator=estimator, allocator=allocator, **kwargs)
        self.fleet_allocator: FleetAllocator = self.allocator
        # Opt-in: serve every lane's queued score windows through ONE
        # vmapped B-SA program per phase (InferenceKernel.
        # predict_fleet_async) instead of one fused predict per lane.
        # Default OFF: the vmapped apply can differ from per-lane applies
        # in float ulps, and the degeneracy goldens pin per-lane numerics.
        self.fleet_serve_batched = fleet_serve_batched

    # ------------------------------------------------------------ fleet run
    def run(self, streams: Union[DriftStream, FramePipeline,
                                 Sequence[Union[DriftStream, FramePipeline]]],
            duration: Optional[float] = None,
            observers: Sequence[PhaseObserver] = ()) -> FleetResult:
        """Execute the fleet loop over ``streams`` — raw
        :class:`DriftStream`s (each wrapped in its own lane pipeline) or
        ready :class:`FramePipeline` handles, freely mixed. A single stream
        is a 1-lane fleet (bit-identical to :class:`CLSession`)."""
        run = self.open_run(streams, duration, observers)
        try:
            while run.step():
                pass
            return run.finalize()
        finally:
            run.close()

    def open_run(self, streams: Union[DriftStream, FramePipeline,
                                      Sequence[Union[DriftStream,
                                                     FramePipeline]], None]
                 = None,
                 duration: Optional[float] = None,
                 observers: Sequence[PhaseObserver] = (),
                 clock: float = 0.0) -> "FleetRun":
        """Open the fleet loop as a phase-steppable :class:`FleetRun` —
        the handle the manager tier drives: ``step()`` one phase at a
        time, with lane admission/migration/checkpointing between steps.
        ``streams`` may be ``None``/empty (an empty shard populated by
        ``attach_lane``, e.g. the fault-recovery restore path; requires an
        explicit ``duration``). ``run()`` is exactly open → step* →
        finalize → close."""
        streams = [] if streams is None else streams
        if isinstance(streams, (DriftStream, FramePipeline)):
            streams = [streams]
        pipes: List[FramePipeline] = []
        owned: List[FramePipeline] = []
        for s in streams:
            if isinstance(s, FramePipeline):
                pipes.append(s)
            else:
                pipe = FramePipeline(s, speculative=self.speculative_frames)
                pipes.append(pipe)
                owned.append(pipe)
        try:
            run = FleetRun(self, pipes, duration, observers, clock=clock)
        except Exception:
            for pipe in owned:
                pipe.close()
            raise
        run._owned = owned
        return run


class FleetRun:
    """One live fleet phase loop, opened phase-steppable.

    This is the engine loop of :meth:`FleetSession.run` hoisted into an
    object so the manager tier can interleave *membership changes* with
    phases: :meth:`step` executes exactly one fleet phase (one shared
    :class:`~repro.core.dispatch.PhasePlan`), and between steps — at
    phase boundaries, the only points where no plan is in flight — lanes
    can be snapshotted (:meth:`snapshot_lane`), detached
    (:meth:`detach_lane`) and attached (:meth:`attach_lane`: fresh camera
    or :class:`LaneSnapshot` restore). A run executed as pure
    step-until-done reproduces the pre-manager monolithic loop
    bit-for-bit — the degeneracy goldens of tests/test_fleet.py pin that
    — because the loop body below *is* the old loop body, with locals
    hoisted to attributes in the same accumulation order.

    Thread-independence contract (what lets the manager overlap shards,
    ``FleetManager(parallel_shards=N)``): :meth:`step` reads and writes
    only this run's state — its session (own kernels, allocator, RNGs),
    its lanes, its pipelines — never another run's; the only process-
    global state a phase touches is append-only jit caches (no numeric
    effect) and the locked kernel-stat counters / serving caches.
    Concurrent :meth:`step` calls on *different* runs are therefore safe
    and bit-identical to stepping them in any serial order. Membership
    mutations (attach/detach/snapshot) are NOT part of that contract —
    the manager calls them only at its barrier, single-threaded.
    """

    def __init__(self, session: FleetSession, pipes: List[FramePipeline],
                 duration: Optional[float] = None,
                 observers: Sequence[PhaseObserver] = (),
                 clock: float = 0.0):
        self.session = session
        hp = session.hp
        n = len(pipes)
        if duration is None:
            if not pipes:
                raise ValueError(
                    "an empty FleetRun needs an explicit duration")
            duration = min(p.duration for p in pipes)
        self.duration = duration
        self.observers = session._observers + list(observers)
        self.clock = clock
        self.done = False
        self.fleet_phase_log: List[dict] = []
        self._owned: List[FramePipeline] = []
        self._lane_seq = n  # monotonic rng-seed cursor across admissions
        if n == 0:
            session.fleet_allocator.begin_empty()
            self.fleet_dec: Optional[FleetDecision] = None
            self.decisions: List[AllocationDecision] = []
            self.lanes: List[_StreamLane] = []
            self._spatial = None
            return
        # One FleetDecision per phase: N per-lane temporal planes + ONE
        # fleet spatial plane (rows already resolved by the row policy).
        self.fleet_dec = session.fleet_allocator.initial_fleet_decision(n)
        self.decisions = list(self.fleet_dec.lane_decisions)
        self.lanes = [
            _StreamLane(
                index=i, pipe=pipe,
                buffer=SampleBuffer(hp.c_b, seed=3),
                sink=_ScoreSink(session.inference,
                                fuse=session.dispatcher.concurrent),
                rng=np.random.default_rng(session.seed + i),
                params=jax.tree_util.tree_map(
                    lambda x: x.copy(), session.student_params),
                opt=None, serving=None, decision=self.decisions[i])
            for i, pipe in enumerate(pipes)
        ]
        spatial = self.fleet_dec.spatial
        self._spatial = spatial
        for lane in self.lanes:
            lane.opt = session.retrain.init_state(lane.params)
            # The B-SA serves all N streams: per-stream sustainable frame
            # fraction divides its throughput by the fleet's aggregate fps.
            lane.keep_frac = session.inference.plan_keep_frac(spatial,
                                                              hp.fps * n)
            lane.serving = session.inference.serving_params(
                lane.params, spatial.precisions.inference)

    @property
    def n_lanes(self) -> int:
        return len(self.lanes)

    def close(self) -> None:
        """Close the pipelines this run owns (wrapped from raw streams)."""
        for pipe in self._owned:
            pipe.close()
        self._owned = []

    # ------------------------------------------------------------- scoring
    def _score_lane_until(self, lane: _StreamLane, t_end: float, serving,
                          plan) -> None:
        """Queue lane-``i`` student-accuracy scoring on
        [lane.eval_cursor, t_end): that stream's B-SA serving program.
        The generalization of the session's ``score_until`` — same
        guard, same subsampling, same charge, per lane."""
        session = self.session
        if t_end <= lane.eval_cursor + 1e-9:
            return
        n_eval = max(1, int((t_end - lane.eval_cursor) * session.eval_fps))
        if plan is not None:
            x, y = plan.fetch(lane.eval_cursor, t_end,
                              max_frames=n_eval, lane=lane.index)
            plan.charge(
                "b_sa",
                len(x) * session.inference.plan_time_per_sample(
                    self._spatial),
                lane=lane.index, label="score", units=len(x))
        else:
            x, y = lane.pipe.frames(lane.eval_cursor, t_end,
                                    max_frames=n_eval)
        lane.sink.add(t_end, x, y, lane.keep_frac, serving)
        lane.eval_cursor = t_end

    # -------------------------------------------------------------- phases
    def step(self) -> bool:
        """Execute ONE fleet phase. Returns False (and marks the run done)
        when the virtual clock has reached the duration — including the
        mid-phase exit, where the phase's plan is finished early — or when
        the run has no lanes."""
        if self.done:
            return False
        if not self.lanes or self.clock >= self.duration:
            self.done = True
            return False
        session = self.session
        hp = session.hp
        duration = self.duration
        lanes = self.lanes
        n = len(lanes)
        pipes = [lane.pipe for lane in lanes]
        fleet_dec = self.fleet_dec
        decisions = self.decisions
        clock = self.clock

        if True:  # one while-body iteration of the pre-manager loop
            phase_start = clock
            spatial = fleet_dec.spatial
            self._spatial = spatial
            temporal = fleet_dec.temporal
            r_tsa, r_bsa = spatial.rows_tsa, spatial.rows_bsa
            if spatial.refission:  # the fleet plane's re-fission intent
                session._repartition(r_bsa)
            for lane in lanes:
                lane.decision = decisions[lane.index]
                lane.keep_frac = session.inference.plan_keep_frac(
                    spatial, hp.fps * n)
            # ---- Plan: one shared ledger for the fleet phase; the plan
            # consumes the fleet decision's per-lane views — rotating every
            # lane's speculation, pre-sized with its temporal budget. ----
            plan = session.dispatcher.begin_phase(
                clock, pipes, decisions=fleet_dec.per_lane(),
                fps=hp.fps if session.decision_aware_spec else None)
            for lane in lanes:
                lane.spec_seen = (lane.pipe.hits, lane.pipe.misses)
                lane.valid_h = lane.yv = None
                lane.acc_v = 1.0
                if temporal[lane.index].profile_cost_s:
                    plan.charge("t_sa", temporal[lane.index].profile_cost_s,
                                lane=lane.index, label="profile")
            # -------- Retraining (Alg. 1 lines 4-7), lane by lane on the
            # shared T-SA chain --------
            for lane in lanes:
                t_lane = temporal[lane.index]
                if (len(lane.buffer) >= hp.sgd_batch
                        and t_lane.retrain_samples > 0):
                    xt, yt, xv, yv = lane.buffer.get_data(
                        t_lane.retrain_samples, t_lane.valid_samples)
                    fit_t0 = time.perf_counter() if plan.traced else 0.0
                    lane.params, lane.opt, n_batches = session.retrain.fit(
                        lane.params, lane.opt, xt, yt, lane.rng,
                        epochs=t_lane.retrain_epochs)
                    t_phase = n_batches * session.retrain.plan_time_per_batch(
                        spatial)
                    plan.charge(
                        "t_sa", t_phase, lane=lane.index, label="retrain",
                        units=n_batches,
                        wall_s=(time.perf_counter() - fit_t0 if plan.traced
                                else 0.0))
                    lane.retrain_time += t_phase
                    lane.serving = session.inference.serving_params(
                        lane.params, spatial.precisions.inference)
                    lane.yv = yv
                    v_role = ("b_sa" if session.dispatcher.concurrent
                              else "t_sa")
                    lane.valid_h = plan.dispatch(
                        v_role, "valid",
                        lambda s=lane.serving, v=xv:
                        session.inference.predict_async(s, v),
                        cost_s=len(xv) * session.inference.plan_time_per_sample(
                            spatial, role=v_role),
                        lane=lane.index, units=len(xv))
            for lane in lanes:
                self._score_lane_until(lane, min(plan.now(), duration),
                                       lane.serving, plan)
            if plan.now() >= duration:
                self.clock = plan.finish()
                self.done = True
                return False

            # -------- Labeling (lines 8-10): bursts fetched per lane, then
            # batched across the fleet on the shared T-SA --------
            for lane in lanes:
                if temporal[lane.index].reset_buffer:
                    lane.buffer.reset()  # line 12
                    lane.drift_events += 1
            t_lab0 = plan.now()
            for lane in lanes:
                n_label = temporal[lane.index].total_label_samples
                lane.x_l, _ = plan.fetch(t_lab0, t_lab0 + n_label / hp.fps,
                                         max_frames=n_label,
                                         lane=lane.index, tag="label")
            # ONE batched device program labels the whole fleet's burst at
            # the fleet spatial plane's labeling precision (cross-stream
            # microbatches on the shared T-SA).
            costs = [
                temporal[lane.index].total_label_samples
                * session.labeling.plan_time_per_sample(spatial)
                for lane in lanes]
            t_run = plan.now()
            handles = plan.dispatch_multi(
                "t_sa", "label",
                lambda: session.labeling.label_fleet_async(
                    session.teacher_params, [ln.x_l for ln in lanes],
                    spatial.precisions.labeling,
                    microbatch=session._label_microbatch),
                costs=costs, lanes=[lane.index for lane in lanes],
                units=[float(temporal[lane.index].total_label_samples)
                       for lane in lanes])
            for lane, handle, cost in zip(lanes, handles, costs):
                # Replay the plan's serial accumulation so each lane's
                # label_time reproduces the single-stream float pattern
                # ((t + c) - t), which the degeneracy golden pins.
                t_next = t_run + cost
                lane.label_time += t_next - t_run
                t_run = t_next
                lane.label_h = handle
            for lane in lanes:
                lane.pred_l_h = plan.dispatch(
                    "b_sa", "acc_label",
                    lambda s=lane.serving, x=lane.x_l:
                    session.inference.predict_async(s, x),
                    cost_s=len(lane.x_l)
                    * session.inference.plan_time_per_sample(spatial),
                    lane=lane.index, units=len(lane.x_l))
            for lane in lanes:
                self._score_lane_until(lane, min(plan.now(), duration),
                                       lane.serving, plan)

            # Fixed-window pacing, per lane temporal plane (the pacing
            # floor is the max boundary any paced lane declares).
            for lane in lanes:
                if temporal[lane.index].pace_window_s:
                    w = temporal[lane.index].pace_window_s
                    next_boundary = (int(phase_start / w) + 1) * w
                    if plan.now() < next_boundary:
                        self._score_lane_until(
                            lane, min(next_boundary, duration),
                            lane.serving, plan)
                        plan.pad_to(next_boundary)

            # ---- Collect: the fleet phase-end barrier. ----
            clock = plan.finish()
            self.clock = clock
            serve_batched = session.fleet_serve_batched
            for lane in lanes:
                self._score_lane_until(lane, min(clock, duration),
                                       lane.serving, None)
                if lane.valid_h is not None:
                    lane.acc_v = float(
                        (lane.valid_h.collect() == lane.yv).mean())
                y_l = lane.label_h.collect()
                lane.acc_l = float(
                    (lane.pred_l_h.collect() == y_l).mean())
                lane.buffer.update(lane.x_l, y_l)  # line 14
                if not serve_batched:
                    lane.sink.flush()
            if serve_batched:
                # One vmapped B-SA program serves every lane's queued
                # score windows (ledger already charged per window).
                flush_sinks_batched(session.inference,
                                    [ln.sink for ln in lanes])

            # -------- Next decisions (lines 11-13), fleet-proportioned ----
            # Per-lane engine-side drift verdicts: computed once here (by
            # each lane policy's detector) and handed down on the feedback
            # — the deduped source the lane policies, the drift-weighted
            # split AND the fleet row policy all read.
            feedbacks = [
                PhaseFeedback(acc_valid=lane.acc_v, acc_label=lane.acc_l,
                              t=clock, phase_start=phase_start,
                              retrain_time=lane.retrain_time,
                              label_time=lane.label_time,
                              drifted=session.fleet_allocator.policies[
                                  lane.index].observe_drift(
                                      lane.acc_l, lane.acc_v, clock))
                for lane in lanes]
            next_fleet = session.fleet_allocator.next_fleet_decision(feedbacks)
            next_decisions = list(next_fleet.lane_decisions)
            self.fleet_phase_log.append({
                "t": clock, "phase_start": phase_start,
                "t_tsa": plan.t_tsa, "t_bsa": plan.t_bsa,
                "rows_tsa": r_tsa, "rows_bsa": r_bsa,
                "per_stream_t_tsa": [plan.lane_time("t_sa", lane.index)
                                     for lane in lanes],
                "per_stream_t_bsa": [plan.lane_time("b_sa", lane.index)
                                     for lane in lanes],
            })
            for lane in lanes:
                record = PhaseRecord(
                    index=len(lane.records), t=clock, acc_valid=lane.acc_v,
                    acc_label=lane.acc_l,
                    drift=next_decisions[lane.index].reset_buffer,
                    retrain_time=lane.retrain_time,
                    label_time=lane.label_time,
                    decision=lane.decision,
                    next_decision=next_decisions[lane.index],
                    phase_start=phase_start,
                    t_tsa=plan.lane_time("t_sa", lane.index),
                    t_bsa=plan.lane_time("b_sa", lane.index),
                    spec_hits=lane.pipe.hits - lane.spec_seen[0],
                    spec_misses=lane.pipe.misses - lane.spec_seen[1],
                    stream=lane.index)
                lane.records.append(record)
                for obs in self.observers:
                    obs(record)
            self.fleet_dec = next_fleet
            self.decisions = next_decisions
        return True

        raise AssertionError("unreachable")

    def finalize(self) -> FleetResult:
        """Score every lane to the duration and assemble the
        :class:`FleetResult` — the post-loop tail of the pre-manager run.
        Migrated lanes prepend the accuracy timeline they accrued on
        previous shards."""
        session = self.session
        results = []
        for lane in self.lanes:
            self._score_lane_until(lane, self.duration, lane.serving, None)
        if session.fleet_serve_batched:
            flush_sinks_batched(session.inference,
                                [ln.sink for ln in self.lanes])
        for lane in self.lanes:
            acc_timeline = lane.timeline_prefix + lane.sink.timeline()
            accs = [a for _, a in acc_timeline]
            results.append(CLResult(
                name=f"{session.fleet_allocator.name}[{lane.index}]",
                accuracy_timeline=acc_timeline,
                phase_log=[r.as_log_entry() for r in lane.records],
                avg_accuracy=float(np.mean(accs)) if accs else 0.0,
                retrain_time=lane.retrain_time,
                label_time=lane.label_time,
                drift_events=lane.drift_events,
                records=lane.records,
            ))
        return FleetResult(
            name=session.fleet_allocator.name,
            streams=results,
            fleet_avg_accuracy=(float(
                np.mean([r.avg_accuracy for r in results]))
                if results else 0.0),
            fleet_phase_log=self.fleet_phase_log,
            drift_events=sum(r.drift_events for r in results),
        )

    # -------------------------------------------- membership (manager tier)
    # All membership operations happen BETWEEN steps — at phase boundaries,
    # where no PhasePlan is in flight and every lane's device work has been
    # collected — so a snapshot is a consistent cut of the lane.

    def snapshot_lane(self, index: int) -> LaneSnapshot:
        """Freeze lane ``index`` at the current phase boundary. Side-effect
        free on the live lane: params/opt are host-copied, RNG/buffer
        states and the lane policy deep-copied — continuing the run does
        not mutate the snapshot, which is what makes periodic per-lane
        checkpointing safe."""
        lane = self.lanes[index]
        alloc = self.session.fleet_allocator

        def host(tree):
            return jax.tree_util.tree_map(lambda x: np.array(x), tree)

        return LaneSnapshot(
            key=lane.key,
            params=host(lane.params),
            opt=host(lane.opt),
            buffer=lane.buffer.state_dict(),
            rng_state=copy.deepcopy(lane.rng.bit_generator.state),
            policy=copy.deepcopy(alloc.policies[index]),
            lane_state=copy.deepcopy(alloc.lane_policy_state(index)),
            decision=lane.decision,
            eval_cursor=lane.eval_cursor,
            retrain_time=lane.retrain_time,
            label_time=lane.label_time,
            drift_events=lane.drift_events,
            records=list(lane.records),
            timeline=lane.timeline_prefix + lane.sink.timeline(),
            clock=self.clock,
        )

    def attach_lane(self, source: Union[DriftStream, FramePipeline],
                    key: object = None,
                    snapshot: Optional[LaneSnapshot] = None,
                    own: Optional[bool] = None) -> _StreamLane:
        """Admit a lane at the current phase boundary — a fresh camera
        (``snapshot=None``: new lane from the session's pretrained
        student, scoring from the current clock) or a
        :class:`LaneSnapshot` restore (migration / fault recovery: the
        lane resumes with the snapshot's weights, buffer, RNG and policy
        state). Raw streams are wrapped in an owned pipeline; pass
        ``own=True`` to hand over an existing pipeline's ownership too."""
        session = self.session
        hp = session.hp
        alloc = session.fleet_allocator
        if isinstance(source, FramePipeline):
            pipe = source
            if own:
                self._owned.append(pipe)
        else:
            pipe = FramePipeline(source,
                                 speculative=session.speculative_frames)
            self._owned.append(pipe)
        index = len(self.lanes)
        sink = _ScoreSink(session.inference,
                          fuse=session.dispatcher.concurrent)
        if snapshot is None:
            alloc.admit_lane()
            lane = _StreamLane(
                index=index, pipe=pipe,
                buffer=SampleBuffer(hp.c_b, seed=3), sink=sink,
                rng=np.random.default_rng(session.seed + self._lane_seq),
                params=jax.tree_util.tree_map(
                    lambda x: x.copy(), session.student_params),
                opt=None, serving=None, decision=None, key=key)
            lane.opt = session.retrain.init_state(lane.params)
            lane.eval_cursor = self.clock  # score from the join point
        else:
            alloc.admit_lane(policy=copy.deepcopy(snapshot.policy),
                             lane_state=copy.deepcopy(snapshot.lane_state))
            buffer = SampleBuffer(hp.c_b, seed=3)
            buffer.load_state_dict(snapshot.buffer)
            rng = np.random.default_rng(0)
            rng.bit_generator.state = copy.deepcopy(snapshot.rng_state)
            lane = _StreamLane(
                index=index, pipe=pipe, buffer=buffer, sink=sink, rng=rng,
                params=rehome_tree(snapshot.params),
                opt=rehome_tree(snapshot.opt),
                serving=None, decision=snapshot.decision,
                key=snapshot.key if key is None else key)
            lane.eval_cursor = snapshot.eval_cursor
            lane.retrain_time = snapshot.retrain_time
            lane.label_time = snapshot.label_time
            lane.drift_events = snapshot.drift_events
            lane.records = list(snapshot.records)
            lane.timeline_prefix = list(snapshot.timeline)
        self._lane_seq += 1
        self.lanes.append(lane)
        self._refresh_decisions()
        spatial = self.fleet_dec.spatial
        if self._spatial is None:
            self._spatial = spatial
        lane.keep_frac = session.inference.plan_keep_frac(
            spatial, hp.fps * len(self.lanes))
        lane.serving = session.inference.serving_params(
            lane.params, spatial.precisions.inference)
        if lane.decision is None:
            lane.decision = self.decisions[lane.index]
        if self.done and self.clock < self.duration:
            self.done = False  # an emptied run can be repopulated
        return lane

    def detach_lane(self, index: int) -> Tuple[LaneSnapshot, FramePipeline]:
        """Remove lane ``index`` at the current phase boundary, returning
        its :class:`LaneSnapshot` and its pipeline (which keeps the lane's
        speculation state — hand both to ``attach_lane`` on the target
        shard for a bit-identical resume). Surviving lanes are re-indexed
        compactly; ownership of the pipe transfers to the caller."""
        snap = self.snapshot_lane(index)
        lane = self.lanes.pop(index)
        self.session.fleet_allocator.remove_lane(index)
        if lane.pipe in self._owned:
            self._owned.remove(lane.pipe)
        for j, ln in enumerate(self.lanes):
            ln.index = j
        if self.lanes:
            self._refresh_decisions()
        else:
            self.fleet_dec = None
            self.decisions = []
        return snap, lane.pipe

    def _refresh_decisions(self) -> None:
        """Re-emit the fleet decision for the current membership (see
        :meth:`~repro.core.allocation.FleetAllocator
        .rebuild_fleet_decision`)."""
        self.fleet_dec = \
            self.session.fleet_allocator.rebuild_fleet_decision()
        self.decisions = list(self.fleet_dec.lane_decisions)
        for lane, d in zip(self.lanes, self.decisions):
            lane.decision = d


@dataclasses.dataclass
class FleetSpec(CLSystemSpec):
    """Declarative front door for fleet sessions: every
    :class:`~repro.core.session.CLSystemSpec` knob (inherited — new session
    knobs are mirrored automatically via ``_session_kwargs``) plus the
    fleet surface: the per-stream ``allocator`` is wrapped in a
    :class:`FleetAllocator` with ``fleet_mode`` / ``budget_streams`` /
    ``row_policy`` (the :class:`~repro.core.decision.FleetRowPolicy`
    resolving the fleet's per-phase spatial plane) / ``fleet_kwargs``."""

    fleet_mode: str = "drift-weighted"
    budget_streams: float = 1.0
    row_policy: object = "resolve-max"  # name, class, or ready instance
    fleet_kwargs: Optional[dict] = None
    serve_batched: bool = False  # one vmapped B-SA program per phase

    def build(self) -> FleetSession:
        return FleetSession(
            fleet_mode=self.fleet_mode,
            fleet_budget_streams=self.budget_streams,
            fleet_row_policy=self.row_policy,
            fleet_kwargs=self.fleet_kwargs,
            fleet_serve_batched=self.serve_batched,
            **self._session_kwargs(),
        )
