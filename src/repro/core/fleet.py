"""FleetSession — multi-camera fleet sessions on one spatially-shared array.

DaCapo's deployment story (paper §2, §5) is an autonomous system serving
*several* camera feeds from one accelerator: every feed needs its own
inference timeline on the B-SA while labeling and retraining for all feeds
compete for the single T-SA. Ekya frames the same setting as a multi-tenant
scheduling problem over shared retraining compute; ECCO shows the accuracy
is won by sharing the labeling/retraining budget *across* cameras. This
module is that layer: the engine that turns N independent
:class:`~repro.data.stream.DriftStream`s into one fleet session.

Architecture (see ROADMAP.md):

* each stream gets its own **data-plane lane** — a
  :class:`~repro.data.pipeline.FramePipeline` with per-stream speculation
  state, a per-stream :class:`~repro.core.session._ScoreSink` (its B-SA
  serving/accuracy timeline), a per-stream
  :class:`~repro.core.sample_buffer.SampleBuffer`, student weights and
  optimizer state, and a per-stream :class:`~repro.core.session.PhaseRecord`
  record lane (``record.stream`` carries the lane id);
* one **shared plan** per fleet phase: the
  :class:`~repro.core.dispatch.KernelDispatcher` binds all N pipelines to a
  single :class:`~repro.core.dispatch.PhasePlan` whose T-SA ledger is
  charged once for the fleet while each charge is also attributed to its
  lane (``plan.lane_time``) — the virtual clock pays for the shared T-SA,
  not for N copies of it;
* labeling bursts are **batched across streams** on the shared T-SA
  (:meth:`~repro.core.kernel.LabelingKernel.label_fleet_async` via
  ``plan.dispatch_multi``): one microbatched device program labels the whole
  fleet's burst, and per-lane label handles split back out device-side;
* each phase executes ONE :class:`~repro.core.decision.FleetDecision`: a
  :class:`~repro.core.allocation.FleetAllocator` proportions the fleet's
  temporal budget across streams (uniform / round-robin / drift-weighted /
  isolated) into N per-lane :class:`~repro.core.decision.TemporalPlan`s,
  while a pluggable :class:`~repro.core.decision.FleetRowPolicy` resolves
  the N per-lane spatial requests into the ONE fleet-wide
  :class:`~repro.core.decision.SpatialPlan` the engine executes
  (``resolve-max`` reproduces the pre-plane max/min resolution
  bit-for-bit; ``drift-surge`` grows the fleet T-SA under multi-lane drift
  with hysteresis; ``weighted-vote`` follows the drift-weighted temporal
  shares). Each lane still keeps an ordinary per-stream
  :class:`~repro.core.allocation.AllocationPolicy` underneath.

Degeneracy contract: a **1-stream fleet is bit-identical to**
:class:`~repro.core.session.CLSession` — same records (including per-phase
``t_tsa``/``t_bsa`` and speculation counters), same accuracy timeline, same
virtual clock. The fleet loop is the session loop generalized over lanes;
every float accumulation it performs at N=1 replays the single-stream
sequence exactly, and ``tests/test_fleet.py`` pins that against the seed
goldens of ``tests/test_session.py``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.configs.dacapo_pairs import VisionConfig
from repro.core.allocation import (
    AllocationDecision,
    CLHyperParams,
    FleetAllocator,
    PhaseFeedback,
)
from repro.core.decision import FleetDecision
from repro.core.sample_buffer import SampleBuffer
from repro.core.session import (
    CLResult,
    CLSession,
    CLSystemSpec,
    PhaseObserver,
    PhaseRecord,
    _ScoreSink,
)
from repro.data.pipeline import FramePipeline
from repro.data.stream import DriftStream


@dataclasses.dataclass
class _StreamLane:
    """Per-stream engine state: one camera's data plane + learning state."""

    index: int
    pipe: FramePipeline  # ownership is tracked by FleetSession.run
    buffer: SampleBuffer
    sink: _ScoreSink
    rng: np.random.Generator
    params: object  # this stream's student weights (master, fp32)
    opt: object
    serving: object  # quantized serving copy of ``params``
    decision: AllocationDecision
    keep_frac: float = 1.0
    eval_cursor: float = 0.0
    retrain_time: float = 0.0
    label_time: float = 0.0
    drift_events: int = 0
    records: List[PhaseRecord] = dataclasses.field(default_factory=list)
    # per-phase scratch
    spec_seen: Tuple[int, int] = (0, 0)
    acc_v: float = 1.0
    valid_h: object = None
    yv: object = None
    label_h: object = None
    pred_l_h: object = None
    x_l: object = None


@dataclasses.dataclass
class FleetResult:
    """One fleet run: per-stream :class:`CLResult` lanes + fleet ledger."""

    name: str
    streams: List[CLResult]
    fleet_avg_accuracy: float  # mean of the per-stream averages
    fleet_phase_log: List[dict]  # per-phase shared-T-SA/B-SA ledger
    drift_events: int  # total across streams

    @property
    def n_streams(self) -> int:
        return len(self.streams)


class FleetSession(CLSession):
    """Executes fleet allocation decisions phase-by-phase for N streams.

    Construction mirrors :class:`CLSession`; ``allocator`` is either a ready
    :class:`FleetAllocator` or a per-stream policy (registry name / class /
    instance) that gets wrapped in one, with ``fleet_mode`` /
    ``fleet_budget_streams`` / ``fleet_kwargs`` configuring the wrapper.
    All streams share the student/teacher model pair (one jitted apply per
    kernel for the whole fleet) but keep independent weights, buffers and
    drift state per lane.
    """

    def __init__(self, student_cfg: VisionConfig, teacher_cfg: VisionConfig,
                 hp: Optional[CLHyperParams] = None, estimator=None,
                 allocator="dacapo-spatiotemporal",
                 fleet_mode: str = "drift-weighted",
                 fleet_budget_streams: float = 1.0,
                 fleet_row_policy="resolve-max",
                 fleet_kwargs: Optional[dict] = None, **kwargs):
        hp = hp or CLHyperParams()
        if not isinstance(allocator, FleetAllocator):
            allocator = FleetAllocator(
                hp, policy=allocator, mode=fleet_mode,
                budget_streams=fleet_budget_streams,
                row_policy=fleet_row_policy, **(fleet_kwargs or {}))
        super().__init__(student_cfg, teacher_cfg, hp=hp,
                         estimator=estimator, allocator=allocator, **kwargs)
        self.fleet_allocator: FleetAllocator = self.allocator

    # ------------------------------------------------------------ fleet run
    def run(self, streams: Union[DriftStream, FramePipeline,
                                 Sequence[Union[DriftStream, FramePipeline]]],
            duration: Optional[float] = None,
            observers: Sequence[PhaseObserver] = ()) -> FleetResult:
        """Execute the fleet loop over ``streams`` — raw
        :class:`DriftStream`s (each wrapped in its own lane pipeline) or
        ready :class:`FramePipeline` handles, freely mixed. A single stream
        is a 1-lane fleet (bit-identical to :class:`CLSession`)."""
        if isinstance(streams, (DriftStream, FramePipeline)):
            streams = [streams]
        pipes: List[Tuple[FramePipeline, bool]] = []
        for s in streams:
            if isinstance(s, FramePipeline):
                pipes.append((s, False))
            else:
                pipes.append((FramePipeline(
                    s, speculative=self.speculative_frames), True))
        try:
            return self._run_fleet([p for p, _ in pipes], duration,
                                   observers)
        finally:
            for pipe, own in pipes:
                if own:
                    pipe.close()

    def _run_fleet(self, pipes: List[FramePipeline],
                   duration: Optional[float],
                   observers: Sequence[PhaseObserver]) -> FleetResult:
        hp = self.hp
        n = len(pipes)
        duration = duration or min(p.duration for p in pipes)
        observers = self._observers + list(observers)
        # One FleetDecision per phase: N per-lane temporal planes + ONE
        # fleet spatial plane (rows already resolved by the row policy).
        fleet_dec: FleetDecision = \
            self.fleet_allocator.initial_fleet_decision(n)
        decisions = list(fleet_dec.lane_decisions)

        lanes = [
            _StreamLane(
                index=i, pipe=pipe,
                buffer=SampleBuffer(hp.c_b, seed=3),
                sink=_ScoreSink(self.inference,
                                fuse=self.dispatcher.concurrent),
                rng=np.random.default_rng(self.seed + i),
                params=jax.tree_util.tree_map(
                    lambda x: x.copy(), self.student_params),
                opt=None, serving=None, decision=decisions[i])
            for i, pipe in enumerate(pipes)
        ]
        spatial = fleet_dec.spatial
        r_tsa, r_bsa = spatial.rows_tsa, spatial.rows_bsa
        for lane in lanes:
            lane.opt = self.retrain.init_state(lane.params)
            # The B-SA serves all N streams: per-stream sustainable frame
            # fraction divides its throughput by the fleet's aggregate fps.
            lane.keep_frac = self.inference.plan_keep_frac(spatial,
                                                           hp.fps * n)
            lane.serving = self.inference.serving_params(
                lane.params, spatial.precisions.inference)
        clock = 0.0
        fleet_phase_log: List[dict] = []

        def score_lane_until(lane: _StreamLane, t_end: float, serving,
                             plan) -> None:
            """Queue lane-``i`` student-accuracy scoring on
            [lane.eval_cursor, t_end): that stream's B-SA serving program.
            The generalization of the session's ``score_until`` — same
            guard, same subsampling, same charge, per lane."""
            if t_end <= lane.eval_cursor + 1e-9:
                return
            n_eval = max(1, int((t_end - lane.eval_cursor) * self.eval_fps))
            if plan is not None:
                x, y = plan.fetch(lane.eval_cursor, t_end,
                                  max_frames=n_eval, lane=lane.index)
                plan.charge(
                    "b_sa",
                    len(x) * self.inference.plan_time_per_sample(spatial),
                    lane=lane.index)
            else:
                x, y = lane.pipe.frames(lane.eval_cursor, t_end,
                                        max_frames=n_eval)
            lane.sink.add(t_end, x, y, lane.keep_frac, serving)
            lane.eval_cursor = t_end

        while clock < duration:
            phase_start = clock
            spatial = fleet_dec.spatial
            temporal = fleet_dec.temporal
            r_tsa, r_bsa = spatial.rows_tsa, spatial.rows_bsa
            if spatial.refission:  # the fleet plane's re-fission intent
                self._repartition(r_bsa)
            for lane in lanes:
                lane.decision = decisions[lane.index]
                lane.keep_frac = self.inference.plan_keep_frac(
                    spatial, hp.fps * n)
            # ---- Plan: one shared ledger for the fleet phase; the plan
            # consumes the fleet decision's per-lane views — rotating every
            # lane's speculation, pre-sized with its temporal budget. ----
            plan = self.dispatcher.begin_phase(
                clock, pipes, decisions=fleet_dec.per_lane(),
                fps=hp.fps if self.decision_aware_spec else None)
            for lane in lanes:
                lane.spec_seen = (lane.pipe.hits, lane.pipe.misses)
                lane.valid_h = lane.yv = None
                lane.acc_v = 1.0
                if temporal[lane.index].profile_cost_s:
                    plan.charge("t_sa", temporal[lane.index].profile_cost_s,
                                lane=lane.index)
            # -------- Retraining (Alg. 1 lines 4-7), lane by lane on the
            # shared T-SA chain --------
            for lane in lanes:
                t_lane = temporal[lane.index]
                if (len(lane.buffer) >= hp.sgd_batch
                        and t_lane.retrain_samples > 0):
                    xt, yt, xv, yv = lane.buffer.get_data(
                        t_lane.retrain_samples, t_lane.valid_samples)
                    lane.params, lane.opt, n_batches = self.retrain.fit(
                        lane.params, lane.opt, xt, yt, lane.rng,
                        epochs=t_lane.retrain_epochs)
                    t_phase = n_batches * self.retrain.plan_time_per_batch(
                        spatial)
                    plan.charge("t_sa", t_phase, lane=lane.index)
                    lane.retrain_time += t_phase
                    lane.serving = self.inference.serving_params(
                        lane.params, spatial.precisions.inference)
                    lane.yv = yv
                    v_role = ("b_sa" if self.dispatcher.concurrent
                              else "t_sa")
                    lane.valid_h = plan.dispatch(
                        v_role, "valid",
                        lambda s=lane.serving, v=xv:
                        self.inference.predict_async(s, v),
                        cost_s=len(xv) * self.inference.plan_time_per_sample(
                            spatial, role=v_role),
                        lane=lane.index)
            for lane in lanes:
                score_lane_until(lane, min(plan.now(), duration),
                                 lane.serving, plan)
            if plan.now() >= duration:
                clock = plan.finish()
                break

            # -------- Labeling (lines 8-10): bursts fetched per lane, then
            # batched across the fleet on the shared T-SA --------
            for lane in lanes:
                if temporal[lane.index].reset_buffer:
                    lane.buffer.reset()  # line 12
                    lane.drift_events += 1
            t_lab0 = plan.now()
            for lane in lanes:
                n_label = temporal[lane.index].total_label_samples
                lane.x_l, _ = plan.fetch(t_lab0, t_lab0 + n_label / hp.fps,
                                         max_frames=n_label,
                                         lane=lane.index, tag="label")
            # ONE batched device program labels the whole fleet's burst at
            # the fleet spatial plane's labeling precision (cross-stream
            # microbatches on the shared T-SA).
            costs = [
                temporal[lane.index].total_label_samples
                * self.labeling.plan_time_per_sample(spatial)
                for lane in lanes]
            t_run = plan.now()
            handles = plan.dispatch_multi(
                "t_sa", "label",
                lambda: self.labeling.label_fleet_async(
                    self.teacher_params, [ln.x_l for ln in lanes],
                    spatial.precisions.labeling,
                    microbatch=self._label_microbatch),
                costs=costs, lanes=[lane.index for lane in lanes])
            for lane, handle, cost in zip(lanes, handles, costs):
                # Replay the plan's serial accumulation so each lane's
                # label_time reproduces the single-stream float pattern
                # ((t + c) - t), which the degeneracy golden pins.
                t_next = t_run + cost
                lane.label_time += t_next - t_run
                t_run = t_next
                lane.label_h = handle
            for lane in lanes:
                lane.pred_l_h = plan.dispatch(
                    "b_sa", "acc_label",
                    lambda s=lane.serving, x=lane.x_l:
                    self.inference.predict_async(s, x),
                    cost_s=len(lane.x_l)
                    * self.inference.plan_time_per_sample(spatial),
                    lane=lane.index)
            for lane in lanes:
                score_lane_until(lane, min(plan.now(), duration),
                                 lane.serving, plan)

            # Fixed-window pacing, per lane temporal plane (the pacing
            # floor is the max boundary any paced lane declares).
            for lane in lanes:
                if temporal[lane.index].pace_window_s:
                    w = temporal[lane.index].pace_window_s
                    next_boundary = (int(phase_start / w) + 1) * w
                    if plan.now() < next_boundary:
                        score_lane_until(lane, min(next_boundary, duration),
                                         lane.serving, plan)
                        plan.pad_to(next_boundary)

            # ---- Collect: the fleet phase-end barrier. ----
            clock = plan.finish()
            for lane in lanes:
                score_lane_until(lane, min(clock, duration), lane.serving,
                                 None)
                if lane.valid_h is not None:
                    lane.acc_v = float(
                        (lane.valid_h.collect() == lane.yv).mean())
                y_l = lane.label_h.collect()
                lane.acc_l = float(
                    (lane.pred_l_h.collect() == y_l).mean())
                lane.buffer.update(lane.x_l, y_l)  # line 14
                lane.sink.flush()

            # -------- Next decisions (lines 11-13), fleet-proportioned ----
            # Per-lane engine-side drift verdicts: computed once here (by
            # each lane policy's detector) and handed down on the feedback
            # — the deduped source the lane policies, the drift-weighted
            # split AND the fleet row policy all read.
            feedbacks = [
                PhaseFeedback(acc_valid=lane.acc_v, acc_label=lane.acc_l,
                              t=clock, phase_start=phase_start,
                              retrain_time=lane.retrain_time,
                              label_time=lane.label_time,
                              drifted=self.fleet_allocator.policies[
                                  lane.index].observe_drift(
                                      lane.acc_l, lane.acc_v, clock))
                for lane in lanes]
            next_fleet = self.fleet_allocator.next_fleet_decision(feedbacks)
            next_decisions = list(next_fleet.lane_decisions)
            fleet_phase_log.append({
                "t": clock, "phase_start": phase_start,
                "t_tsa": plan.t_tsa, "t_bsa": plan.t_bsa,
                "rows_tsa": r_tsa, "rows_bsa": r_bsa,
                "per_stream_t_tsa": [plan.lane_time("t_sa", lane.index)
                                     for lane in lanes],
                "per_stream_t_bsa": [plan.lane_time("b_sa", lane.index)
                                     for lane in lanes],
            })
            for lane in lanes:
                record = PhaseRecord(
                    index=len(lane.records), t=clock, acc_valid=lane.acc_v,
                    acc_label=lane.acc_l,
                    drift=next_decisions[lane.index].reset_buffer,
                    retrain_time=lane.retrain_time,
                    label_time=lane.label_time,
                    decision=lane.decision,
                    next_decision=next_decisions[lane.index],
                    phase_start=phase_start,
                    t_tsa=plan.lane_time("t_sa", lane.index),
                    t_bsa=plan.lane_time("b_sa", lane.index),
                    spec_hits=lane.pipe.hits - lane.spec_seen[0],
                    spec_misses=lane.pipe.misses - lane.spec_seen[1],
                    stream=lane.index)
                lane.records.append(record)
                for obs in observers:
                    obs(record)
            fleet_dec = next_fleet
            decisions = next_decisions

        results = []
        for lane in lanes:
            score_lane_until(lane, duration, lane.serving, None)
            acc_timeline = lane.sink.timeline()
            accs = [a for _, a in acc_timeline]
            results.append(CLResult(
                name=f"{self.fleet_allocator.name}[{lane.index}]",
                accuracy_timeline=acc_timeline,
                phase_log=[r.as_log_entry() for r in lane.records],
                avg_accuracy=float(np.mean(accs)) if accs else 0.0,
                retrain_time=lane.retrain_time,
                label_time=lane.label_time,
                drift_events=lane.drift_events,
                records=lane.records,
            ))
        return FleetResult(
            name=self.fleet_allocator.name,
            streams=results,
            fleet_avg_accuracy=float(
                np.mean([r.avg_accuracy for r in results])),
            fleet_phase_log=fleet_phase_log,
            drift_events=sum(r.drift_events for r in results),
        )


@dataclasses.dataclass
class FleetSpec(CLSystemSpec):
    """Declarative front door for fleet sessions: every
    :class:`~repro.core.session.CLSystemSpec` knob (inherited — new session
    knobs are mirrored automatically via ``_session_kwargs``) plus the
    fleet surface: the per-stream ``allocator`` is wrapped in a
    :class:`FleetAllocator` with ``fleet_mode`` / ``budget_streams`` /
    ``row_policy`` (the :class:`~repro.core.decision.FleetRowPolicy`
    resolving the fleet's per-phase spatial plane) / ``fleet_kwargs``."""

    fleet_mode: str = "drift-weighted"
    budget_streams: float = 1.0
    row_policy: object = "resolve-max"  # name, class, or ready instance
    fleet_kwargs: Optional[dict] = None

    def build(self) -> FleetSession:
        return FleetSession(
            fleet_mode=self.fleet_mode,
            fleet_budget_streams=self.budget_streams,
            fleet_row_policy=self.row_policy,
            fleet_kwargs=self.fleet_kwargs,
            **self._session_kwargs(),
        )
