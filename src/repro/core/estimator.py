"""Performance estimators feeding the resource allocator (paper §IV step 2).

In trace vocabulary (core/trace.py): an estimator is the *prior* over
program costs — it predicts, before anything runs, the virtual-clock
seconds each device program the engine will dispatch (``"valid"``,
``"label"``, ``"score"`` forwards; ``"retrain"`` SGD batches) should
charge for a given row split and MX precision. The trace spine records
what those programs *actually* cost the host (per-event ``wall_s``), and
:meth:`~repro.core.replay.TraceReplayer.calibrate` closes the loop: it
fits per-kernel scale factors from recorded traces and wraps the prior in
a :class:`CalibratedEstimator` whose corrected seconds feed allocation
and the manager's :class:`PlacementCostModel`.

Two model backends:

* ``DaCapoEstimator`` — the paper's accelerator: an R x 16 array of DPEs at
  500 MHz, each computing one 16-wide dot product in 1 (MX4) / 4 (MX6) /
  16 (MX9) cycles (§V-B). Output-stationary tiling with pipeline fill,
  SCALE-Sim-style. This is what Algorithm 1's GetSpatialAllocation consumes
  for the faithful reproduction.
* ``TPUEstimator`` — the adapted target: a roofline model of TPU v5e chips
  (197 bf16 TFLOP/s, 819 GB/s HBM per chip); resources are chips instead of
  DPE rows.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

from repro.configs.dacapo_pairs import VisionConfig
from repro.models.resnet import block_plan

MX_CYCLES = {"mx4": 1, "mx6": 4, "mx9": 16}

# TPU v5e constants (per chip) — also used by launch/roofline.py.
TPU_PEAK_FLOPS = 197e12
TPU_HBM_BW = 819e9
TPU_ICI_BW = 50e9


def vision_gemms(cfg: VisionConfig, batch: int = 1) -> List[Tuple[int, int, int]]:
    """(M, N, K) GEMM list for one forward pass (convs via im2col)."""
    gemms: List[Tuple[int, int, int]] = []
    if cfg.kind == "vit":
        n = (cfg.img_size // cfg.patch) ** 2 + 1
        d, f = cfg.d_model, cfg.d_ff
        gemms.append((batch * n, d, cfg.patch * cfg.patch * 3))
        for _ in range(cfg.num_layers):
            gemms.append((batch * n, 3 * d, d))
            gemms.append((batch * n, n, d))  # QK^T (per-head K folded)
            gemms.append((batch * n, d, n))  # AV
            gemms.append((batch * n, d, d))
            gemms.append((batch * n, f, d))
            gemms.append((batch * n, d, f))
        gemms.append((batch, cfg.num_classes, d))
        return gemms
    # ResNet.
    h = w = cfg.img_size
    stem_k = 7 if cfg.img_size > 64 else 3
    stride0 = 2 if cfg.img_size > 64 else 1
    h, w = h // stride0, w // stride0
    gemms.append((batch * h * w, 64, stem_k * stem_k * 3))
    if cfg.img_size > 64:
        h, w = h // 2, w // 2
    for kind, cin, mid, cout, stride in block_plan(cfg):
        h2, w2 = h // stride, w // stride
        if kind == "basic":
            gemms.append((batch * h2 * w2, mid, 9 * cin))
            gemms.append((batch * h2 * w2, cout, 9 * mid))
        else:
            gemms.append((batch * h * w, mid, cin))
            gemms.append((batch * h2 * w2, mid, 9 * mid))
            gemms.append((batch * h2 * w2, cout, mid))
        if stride != 1 or cin != cout:
            gemms.append((batch * h2 * w2, cout, cin))
        h, w = h2, w2
    gemms.append((batch, cfg.num_classes, block_plan(cfg)[-1][3]))
    return gemms


@dataclasses.dataclass(frozen=True)
class DaCapoEstimator:
    """Cycle-level model of the paper's 16x16 DPE prototype (Table IV)."""

    total_rows: int = 16
    cols: int = 16
    dot_width: int = 16
    freq_hz: float = 500e6

    def gemm_cycles(self, m: int, n: int, k: int, rows: int,
                    precision: str) -> float:
        """Output-stationary: tiles of rows x cols outputs; each output needs
        ceil(K/16) dot-steps at MX_CYCLES each; + pipeline fill per tile."""
        cyc_per_dot = MX_CYCLES[precision]
        k_steps = math.ceil(k / self.dot_width)
        tiles = math.ceil(m / rows) * math.ceil(n / self.cols)
        fill = rows + self.cols
        return tiles * (k_steps * cyc_per_dot + fill)

    def forward_time(self, cfg: VisionConfig, rows: int, precision: str,
                     batch: int = 1) -> float:
        cycles = sum(self.gemm_cycles(m, n, k, rows, precision)
                     for m, n, k in vision_gemms(cfg, batch))
        return cycles / self.freq_hz

    def train_step_time(self, cfg: VisionConfig, rows: int, precision: str,
                        batch: int) -> float:
        # fwd + 2 backward GEMMs per forward GEMM (dX and dW).
        return 3.0 * self.forward_time(cfg, rows, precision, batch)

    def inference_fps(self, cfg: VisionConfig, rows: int,
                      precision: str) -> float:
        return 1.0 / self.forward_time(cfg, rows, precision, batch=1)


@dataclasses.dataclass(frozen=True)
class TPUEstimator:
    """Roofline model per TPU v5e chip; ``rows``==chips for the allocator.

    ``fractional_rows`` switches the meaning of ``rows`` from whole chips
    (peak scales linearly with row count) to fractions of a single fixed
    device (peak scales with rows/total_rows) — the mode device-sharing
    estimators like the Jetson Orin model in benchmarks/common.py use.
    """

    total_rows: int = 1  # chips available to the CL system
    peak_flops: float = TPU_PEAK_FLOPS
    hbm_bw: float = TPU_HBM_BW
    fractional_rows: bool = False
    mx_speedup = {"mx4": 4.0, "mx6": 2.0, "mx9": 1.0}  # bandwidth-side gain

    def _units(self, rows: int) -> float:
        return rows / self.total_rows if self.fractional_rows else rows

    def forward_time(self, cfg: VisionConfig, rows: int, precision: str,
                     batch: int = 1) -> float:
        flops = sum(2 * m * n * k for m, n, k in vision_gemms(cfg, batch))
        bytes_moved = sum(m * k + k * n + m * n
                          for m, n, k in vision_gemms(cfg, batch)) * 4
        bytes_moved /= self.mx_speedup[precision]
        units = self._units(rows)
        t_c = flops / (units * self.peak_flops)
        t_m = bytes_moved / (units * self.hbm_bw)
        return max(t_c, t_m)

    def train_step_time(self, cfg, rows, precision, batch):
        return 3.0 * self.forward_time(cfg, rows, precision, batch)

    def inference_fps(self, cfg, rows, precision):
        return 1.0 / self.forward_time(cfg, rows, precision, batch=1)


@dataclasses.dataclass(frozen=True)
class CalibratedEstimator:
    """An estimator prior corrected by measured trace wall times.

    Wraps any backend with the same surface (``forward_time`` /
    ``train_step_time`` / ``inference_fps`` / ``total_rows``) and scales
    its predictions by per-kernel factors — typically the Σwall/Σcost
    ratios a :meth:`~repro.core.replay.TraceReplayer.calibrate` fit from a
    recorded trace (``forward_scale`` from the forward-pass programs,
    ``train_scale`` from the retraining charges). Scale 1.0 is the
    uncorrected prior; the wrapper stays frozen/hashable like the backends
    so allocators can hold it exactly where they held the base estimator.
    """

    base: object = dataclasses.field(default_factory=DaCapoEstimator)
    forward_scale: float = 1.0
    train_scale: float = 1.0

    @property
    def total_rows(self) -> int:
        return self.base.total_rows

    def forward_time(self, cfg: VisionConfig, rows: int, precision: str,
                     batch: int = 1) -> float:
        return self.forward_scale * self.base.forward_time(
            cfg, rows, precision, batch)

    def train_step_time(self, cfg: VisionConfig, rows: int, precision: str,
                        batch: int) -> float:
        return self.train_scale * self.base.train_step_time(
            cfg, rows, precision, batch)

    def inference_fps(self, cfg: VisionConfig, rows: int,
                      precision: str) -> float:
        return 1.0 / self.forward_time(cfg, rows, precision, batch=1)


@dataclasses.dataclass(frozen=True)
class PlacementCostModel:
    """Manager-tier placement economics on the overlapped execution model.

    With overlapped shard stepping (``FleetManager(parallel_shards=N)``)
    the manager's wall per round is ``max`` over shards of the per-shard
    phase load — not the sum — so placement quality is measured in seconds
    shaved off that max:

    * a candidate **migration**'s value is the per-round reduction of the
      load maximum it buys, amortized over ``horizon_rounds`` (a lane's
      cost is its last phase's T-SA seconds); the move itself costs
      ``migration_cost_s`` (snapshot + re-home + re-jit, in virtual
      seconds — the same figure the manager charges its ledger);
    * **admission** control compares a shard's predicted T-SA
      *utilization* — T-SA seconds per phase over the phase's modeled
      wall — against ``oversub_limit``: above it, the shard's T-SA cannot
      keep up with real time and a new lane would degrade every tenant,
      so the fleet turns the camera away instead
      (``PlacementAction(kind="reject")``).
    """

    migration_cost_s: float = 0.0
    horizon_rounds: int = 4
    oversub_limit: float = 1.5

    @staticmethod
    def round_time_s(loads: Sequence[float]) -> float:
        """Modeled manager wall per round: the slowest shard's load."""
        return max(loads) if len(loads) else 0.0

    def migration_gain_s(self, loads: Sequence[float], src: int, dst: int,
                         lane_cost_s: float) -> float:
        """T-SA seconds the move saves over ``horizon_rounds`` rounds."""
        after = list(loads)
        after[src] -= lane_cost_s
        after[dst] += lane_cost_s
        return (self.round_time_s(loads)
                - self.round_time_s(after)) * self.horizon_rounds

    def worth_migrating(self, loads: Sequence[float], src: int, dst: int,
                        lane_cost_s: float) -> bool:
        return (self.migration_gain_s(loads, src, dst, lane_cost_s)
                > self.migration_cost_s)

    @staticmethod
    def utilization(t_tsa_s: float, phase_s: float) -> float:
        """T-SA occupancy of one phase window (>1: can't keep up)."""
        return t_tsa_s / phase_s if phase_s > 0 else 0.0

    def admits(self, t_tsa_s: float, phase_s: float,
               lane_cost_s: float) -> bool:
        """Would a shard at (t_tsa_s, phase_s) absorb one more lane?"""
        return (self.utilization(t_tsa_s + lane_cost_s, phase_s)
                <= self.oversub_limit)


def spatial_allocation(estimator, student: VisionConfig, fps: float,
                       precision: str) -> Tuple[int, int]:
    """GetSpatialAllocation (Alg. 1 line 1): minimum B-SA rows sustaining the
    input frame rate for student inference; the rest go to T-SA.

    Always returns (R_tsa, R_bsa) with R_tsa + R_bsa == total_rows. When no
    proper split sustains the frame rate, rows == total is considered before
    falling back: if the whole array is needed (or it is a single-row array),
    B-SA takes every row and T-SA time-shares (R_tsa = 0, the paper's R=0
    fallback); only when even the full array misses the frame rate does one
    row stay with T-SA so retraining is never starved entirely.
    """
    total = estimator.total_rows
    for rows in range(1, total):
        if estimator.inference_fps(student, rows, precision) >= fps:
            return total - rows, rows  # (R_tsa, R_bsa)
    if total == 1 or estimator.inference_fps(student, total,
                                             precision) >= fps:
        return 0, total  # whole array to inference; T-SA time-shares
    return 1, total - 1  # overloaded even at full width
