"""DaCapo continuous-learning system (paper Fig. 4 + Algorithm 1).

Methodology mirrors the paper's evaluation split (§VII-A): the *virtual
clock* advances by phase durations computed from the performance estimator on
the FULL model configs (Table III / Table IV hardware), while the *learning
dynamics* (inference, labeling, retraining, accuracy) execute on reduced
same-family twins over the synthetic drift stream — "integrating hardware
simulation and GPU kernel execution" exactly as the paper's system simulator
does, with JAX/CPU in the GPU role.

Three concurrent kernels:
  inference  — student, every frame, B-SA, MX6;
  labeling   — teacher pseudo-labels on sampled frames, T-SA, MX6;
  retraining — student SGD on the sample buffer, T-SA, MX9.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.dacapo_pairs import VisionConfig
from repro.core import mx as mx_lib
from repro.core.estimator import DaCapoEstimator, spatial_allocation
from repro.core.sample_buffer import SampleBuffer
from repro.core.scheduler import (
    CLHyperParams,
    EkyaScheduler,
    EOMUScheduler,
    PhasePlan,
    SCHEDULERS,
)
from repro.data.stream import DriftStream
from repro.models.registry import make_vision_model


@dataclasses.dataclass
class CLResult:
    name: str
    accuracy_timeline: List[Tuple[float, float]]  # (t, acc on [t-dt, t))
    phase_log: List[dict]
    avg_accuracy: float
    retrain_time: float
    label_time: float
    drift_events: int


class ContinuousLearningSystem:
    def __init__(
        self,
        student_cfg: VisionConfig,
        teacher_cfg: VisionConfig,
        hp: Optional[CLHyperParams] = None,
        estimator=None,
        allocator: str = "dacapo-spatiotemporal",
        precision_policy: mx_lib.PrecisionPolicy = mx_lib.DEFAULT_POLICY,
        apply_mx_numerics: bool = True,
        seed: int = 0,
        eval_fps: float = 2.0,
    ):
        self.hp = hp or CLHyperParams()
        self.estimator = estimator or DaCapoEstimator()
        self.scheduler = SCHEDULERS[allocator](self.hp)
        self.policy = precision_policy
        self.apply_mx = apply_mx_numerics
        self.eval_fps = eval_fps  # accuracy-scoring subsample rate
        self.full_student, self.full_teacher = student_cfg, teacher_cfg
        self.student_cfg = student_cfg.reduced()
        self.teacher_cfg = teacher_cfg.reduced()
        self.student = make_vision_model(self.student_cfg)
        self.teacher = make_vision_model(self.teacher_cfg)
        self.key = jax.random.PRNGKey(seed)
        self.rng = np.random.default_rng(seed)

        # Offline spatial allocation (Alg. 1 lines 1-2).
        self.r_tsa, self.r_bsa = spatial_allocation(
            self.estimator, self.full_student, self.hp.fps,
            precision_policy.inference)

        # Jitted kernels.
        self._infer = jax.jit(self.student.apply)
        self._teach = jax.jit(self.teacher.apply)
        self._train_step = jax.jit(self._sgd_step)

    # ----------------------------------------------------------- pretraining
    def pretrain(self, stream: DriftStream, teacher_steps: int = 300,
                 student_steps: int = 80, batch: int = 64):
        """Teacher: pretrained across the whole attribute space (general).
        Student: narrow slice only (first segment's context) -> must adapt."""
        t_params = pretrain_model(self.teacher, stream, teacher_steps, batch,
                                  rng=self.rng)
        s_params = pretrain_model(self.student, stream, student_steps, batch,
                                  rng=self.rng, segments=stream.segments[:1],
                                  seed=8)
        self.set_pretrained(t_params, s_params)

    def set_pretrained(self, teacher_params, student_params):
        """Install (shared) pretrained weights; benches pretrain once per
        (pair, scenario) and clone into every allocator variant."""
        self.teacher_params = teacher_params
        self.student_params = jax.tree_util.tree_map(
            lambda x: x.copy(), student_params)
        self._opt = _sgd_state(self.student_params)

    # ---------------------------------------------------------------- kernels
    def _sgd_step(self, params, opt, x, y):
        def loss_fn(p):
            logits = self.student.apply(p, x)
            logp = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_opt = jax.tree_util.tree_map(
            lambda m, g: 0.9 * m + g, opt, grads)
        new_params = jax.tree_util.tree_map(
            lambda p, m: p - self.hp.lr * m, params, new_opt)
        return new_params, new_opt, loss

    def _serving_params(self):
        if self.apply_mx:
            return mx_lib.quantize_tree(self.student_params,
                                        self.policy.inference)
        return self.student_params

    def _label(self, x: np.ndarray) -> np.ndarray:
        params = self.teacher_params
        if self.apply_mx:
            params = mx_lib.quantize_tree(params, self.policy.labeling)
        return np.asarray(jnp.argmax(self._teach(params, x), -1))

    # -------------------------------------------------------------- main loop
    def run(self, stream: DriftStream,
            duration: Optional[float] = None) -> CLResult:
        hp = self.hp
        duration = duration or stream.duration
        buffer = SampleBuffer(hp.c_b, seed=3)
        est = self.estimator
        pol = self.policy

        # Per-sample costs on the FULL configs (virtual clock).
        t_label = est.forward_time(self.full_teacher, self.r_tsa,
                                   pol.labeling, batch=1)
        t_train_batch = est.train_step_time(
            self.full_student, self.r_tsa, pol.retraining, hp.sgd_batch)
        t_valid = est.forward_time(self.full_student, self.r_tsa,
                                   pol.inference, batch=1)
        # B-SA inference rate -> frame-drop fraction (paper Fig. 2 metric).
        bsa_fps = est.inference_fps(self.full_student, self.r_bsa,
                                    pol.inference)
        keep_frac = min(1.0, bsa_fps / hp.fps)

        serving = self._serving_params()
        clock = 0.0
        eval_cursor = 0.0
        acc_timeline: List[Tuple[float, float]] = []
        phase_log: List[dict] = []
        retrain_time = label_time = 0.0
        drift_events = 0
        plan: PhasePlan = self.scheduler.initial_plan()
        window = getattr(self.scheduler, "window_s", None)

        def score_until(t_end: float, serving_params):
            """Student inference accuracy on [eval_cursor, t_end)."""
            nonlocal eval_cursor
            if t_end <= eval_cursor + 1e-9:
                return
            n_eval = max(1, int((t_end - eval_cursor) * self.eval_fps))
            x, y = stream.frames(eval_cursor, t_end, max_frames=n_eval)
            pred = np.asarray(jnp.argmax(self._infer(serving_params, x), -1))
            acc = float((pred == y).mean()) * keep_frac
            acc_timeline.append((t_end, acc))
            eval_cursor = t_end

        while clock < duration:
            phase_start = clock
            # ---------------- Retraining (Alg. 1 lines 4-7) ----------------
            acc_v = 1.0
            if len(buffer) >= hp.sgd_batch and plan.retrain_samples > 0:
                xt, yt, xv, yv = buffer.get_data(plan.retrain_samples,
                                                 plan.valid_samples)
                n_batches = max(1, len(xt) // hp.sgd_batch) * hp.epochs
                for e in range(hp.epochs):
                    perm = self.rng.permutation(len(xt))
                    for i in range(0, len(xt) - hp.sgd_batch + 1,
                                   hp.sgd_batch):
                        idx = perm[i: i + hp.sgd_batch]
                        self.student_params, self._opt, _ = self._train_step(
                            self.student_params, self._opt, xt[idx], yt[idx])
                t_phase = n_batches * t_train_batch
                clock += t_phase
                retrain_time += t_phase
                # UpdateWeight + Valid (lines 6-7).
                serving = self._serving_params()
                pv = np.asarray(jnp.argmax(self._infer(serving, xv), -1))
                acc_v = float((pv == yv).mean())
                clock += len(xv) * t_valid
            score_until(min(clock, duration), serving)
            if clock >= duration:
                break

            # ---------------- Labeling (lines 8-10) ------------------------
            n_label = plan.label_samples + plan.extra_label_samples
            if plan.reset_buffer:
                buffer.reset()  # line 12
                drift_events += 1
            t_lab0 = clock
            x_l, y_true = stream.frames(clock, clock + n_label / hp.fps,
                                        max_frames=n_label)
            y_l = self._label(x_l)
            clock += n_label * t_label
            label_time += clock - t_lab0
            pred_l = np.asarray(jnp.argmax(self._infer(serving, x_l), -1))
            acc_l = float((pred_l == y_l).mean())
            buffer.update(x_l, y_l)  # line 14
            score_until(min(clock, duration), serving)

            # Window pacing for fixed-window baselines (Ekya/EOMU).
            if window is not None:
                next_boundary = (int(phase_start / window) + 1) * window
                if clock < next_boundary:
                    score_until(min(next_boundary, duration), serving)
                    clock = next_boundary

            # ---------------- Next plan (lines 11-13) ----------------------
            plan = self.scheduler.next_phase(acc_v, acc_l, clock)
            phase_log.append({
                "t": clock, "acc_valid": acc_v, "acc_label": acc_l,
                "drift": plan.reset_buffer, "retrain_time": retrain_time,
                "label_time": label_time})

        score_until(duration, serving)
        accs = [a for _, a in acc_timeline]
        return CLResult(
            name=self.scheduler.name,
            accuracy_timeline=acc_timeline,
            phase_log=phase_log,
            avg_accuracy=float(np.mean(accs)) if accs else 0.0,
            retrain_time=retrain_time,
            label_time=label_time,
            drift_events=drift_events,
        )


# ------------------------------------------------------------------ helpers
def _sgd_state(params):
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def pretrain_model(model, stream: DriftStream, steps: int, batch: int,
                   rng: np.random.Generator, segments=None, seed: int = 7,
                   lr: float = 3e-3):
    """Jitted SGD-momentum pretraining over IID stream samples."""
    params = model.init(jax.random.PRNGKey(seed))
    opt = _sgd_state(params)

    @jax.jit
    def update(params, opt, x, y):
        def loss_fn(p):
            logp = jax.nn.log_softmax(model.apply(p, x))
            return -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()

        grads = jax.grad(loss_fn)(params)
        opt = jax.tree_util.tree_map(lambda m, g: 0.9 * m + g, opt, grads)
        params = jax.tree_util.tree_map(lambda p, m: p - lr * m, params, opt)
        return params, opt

    for _ in range(steps):
        x, y = stream.sample_dataset(batch, rng, segments=segments)
        params, opt = update(params, opt, x, y)
    return params
