"""Legacy front door — thin compatibility wrapper over the Kernel/Session API.

The monolithic ``ContinuousLearningSystem`` was decomposed into three layers
(see ROADMAP.md "Architecture"):

* kernels (core/kernel.py)      — inference / labeling / retraining, each
  owning its jitted apply, MX precision and virtual-clock cost, reading
  rows/precisions off the decision's spatial plane;
* decisions (core/decision.py)  — the two-plane surface: ``SpatialPlan`` ×
  ``TemporalPlan`` combined by the frozen ``Decision`` engines consume
  (``AllocationDecision`` is the flat facade over it);
* policies (core/allocation.py) — Algorithm 1 and the §III baselines as
  decision emitters;
* engine (core/session.py)      — ``CLSession`` executes decisions
  phase-by-phase; ``CLSystemSpec`` is the declarative builder.

New code should use ``CLSystemSpec(...).build()``. This wrapper keeps the
seed-era constructor and attribute surface and is verified numerically
equivalent to the pre-refactor implementation by the fixed-seed golden test
in tests/test_session.py.
"""
from __future__ import annotations

from typing import Optional

from repro.configs.dacapo_pairs import VisionConfig
from repro.core import mx as mx_lib
from repro.core.allocation import CLHyperParams
from repro.core.session import (  # noqa: F401  (re-exports)
    CLResult,
    CLSession,
    CLSystemSpec,
    pretrain_model,
)


class ContinuousLearningSystem:
    """Seed-compatible facade delegating to a :class:`CLSession`."""

    def __init__(
        self,
        student_cfg: VisionConfig,
        teacher_cfg: VisionConfig,
        hp: Optional[CLHyperParams] = None,
        estimator=None,
        allocator: str = "dacapo-spatiotemporal",
        precision_policy: mx_lib.PrecisionPolicy = mx_lib.DEFAULT_POLICY,
        apply_mx_numerics: bool = True,
        seed: int = 0,
        eval_fps: float = 2.0,
    ):
        self._session = CLSystemSpec(
            student=student_cfg,
            teacher=teacher_cfg,
            allocator=allocator,
            estimator=estimator,
            policy=precision_policy,
            hp=hp,
            apply_mx=apply_mx_numerics,
            seed=seed,
            eval_fps=eval_fps,
        ).build()

    @property
    def session(self) -> CLSession:
        return self._session

    @property
    def scheduler(self):  # legacy name for the allocation policy
        return self._session.allocator

    @property
    def apply_mx(self) -> bool:
        return self._session.apply_mx

    def pretrain(self, stream, teacher_steps: int = 300,
                 student_steps: int = 80, batch: int = 64):
        return self._session.pretrain(stream, teacher_steps, student_steps,
                                      batch)

    def set_pretrained(self, teacher_params, student_params):
        return self._session.set_pretrained(teacher_params, student_params)

    def run(self, stream, duration: Optional[float] = None) -> CLResult:
        return self._session.run(stream, duration=duration)

    def __getattr__(self, item):
        # hp, estimator, policy, student/teacher (+cfgs), r_tsa/r_bsa,
        # kernels, params, rng ... all live on the session.
        if item == "_session":  # not yet set (e.g. during unpickling)
            raise AttributeError(item)
        return getattr(self._session, item)
