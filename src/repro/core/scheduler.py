"""Deprecated shim — the scheduler API moved to ``repro.core.allocation``.

``PhasePlan`` grew into ``AllocationDecision`` (same leading fields plus
spatial rows, per-kernel precision and window pacing), which is itself now
a facade over the two-plane decision API (``SpatialPlan`` /
``TemporalPlan`` / ``Decision`` in ``repro.core.decision``), and the
scheduler classes became ``AllocationPolicy`` implementations whose
decisions the ``CLSession`` engine executes. The legacy names below keep
old imports and positional constructions working; importing this module
emits a ``DeprecationWarning`` — new code should import from
``repro.core.allocation`` (or ``repro.core.decision`` for the planes).
"""
import warnings

warnings.warn(
    "repro.core.scheduler is deprecated: import AllocationPolicy/"
    "AllocationDecision from repro.core.allocation (or the two-plane "
    "SpatialPlan/TemporalPlan/Decision API from repro.core.decision)",
    DeprecationWarning, stacklevel=2)

from repro.core.allocation import (  # noqa: F401,E402
    ALLOCATORS as SCHEDULERS,
    AllocationDecision as PhasePlan,
    CLHyperParams,
    EkyaAllocator as EkyaScheduler,
    EOMUAllocator as EOMUScheduler,
    SpatialAllocator as SpatialScheduler,
    SpatiotemporalAllocator as SpatiotemporalScheduler,
)

__all__ = [
    "CLHyperParams",
    "PhasePlan",
    "SCHEDULERS",
    "SpatiotemporalScheduler",
    "SpatialScheduler",
    "EkyaScheduler",
    "EOMUScheduler",
]
