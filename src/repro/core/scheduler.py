"""Spatiotemporal resource allocation — Algorithm 1, faithfully.

``SpatiotemporalScheduler.next_phase`` is the paper's while-loop body as a
pure decision function; the CL system (core/cl_system.py) executes its
decisions against models and the virtual clock. Baseline allocators (Ekya-
like fixed-window, EOMU-like short-window triggers, DaCapo-Spatial) share the
interface so every system variant runs on the identical substrate.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.drift import DriftDetector


@dataclasses.dataclass
class CLHyperParams:
    """Table I notation."""

    n_t: int = 256  # samples per retraining phase
    n_l: int = 128  # samples labeled at usual
    n_ldd_mult: int = 4  # N_ldd = 4 * N_l (paper §VI-B)
    c_b: int = 1024  # sample buffer capacity
    v_thr: float = -0.10  # drift threshold on acc_l - acc_v (tuned offline
    # per paper §VI-D; -0.05 false-positives on n_l=32..48 estimates)
    fps: float = 30.0
    epochs: int = 1
    sgd_batch: int = 16  # paper §VII-A
    lr: float = 1e-3  # paper §VII-A

    @property
    def n_v(self) -> int:  # N_v = N_t / 4 (paper §VI-B)
        return max(1, self.n_t // 4)

    @property
    def n_ldd(self) -> int:
        return self.n_ldd_mult * self.n_l


@dataclasses.dataclass
class PhasePlan:
    """What the system should do next."""

    retrain_samples: int
    valid_samples: int
    label_samples: int
    reset_buffer: bool = False
    extra_label_samples: int = 0  # N_ldd - N_l on drift (Alg. 1 line 13)


class SpatiotemporalScheduler:
    """DaCapo-Spatiotemporal (DC-ST): drift-adaptive temporal allocation."""

    name = "dacapo-spatiotemporal"

    def __init__(self, hp: CLHyperParams):
        self.hp = hp
        self.detector = DriftDetector(v_thr=hp.v_thr)

    def initial_plan(self) -> PhasePlan:
        return PhasePlan(self.hp.n_t, self.hp.n_v, self.hp.n_l)

    def next_phase(self, acc_valid: float, acc_label: float,
                   t: float) -> PhasePlan:
        """Alg. 1 lines 11-13: on drift, reset the buffer and extend the
        labeling phase to N_ldd samples."""
        drift = self.detector.check(acc_label, acc_valid, t)
        if drift:
            return PhasePlan(
                self.hp.n_t, self.hp.n_v, self.hp.n_l, reset_buffer=True,
                extra_label_samples=self.hp.n_ldd - self.hp.n_l)
        return PhasePlan(self.hp.n_t, self.hp.n_v, self.hp.n_l)


class SpatialScheduler(SpatiotemporalScheduler):
    """DaCapo-Spatial (DC-S): static spatial split, fixed temporal
    alternation — never resets the buffer nor boosts labeling."""

    name = "dacapo-spatial"

    def next_phase(self, acc_valid, acc_label, t) -> PhasePlan:
        self.detector.check(acc_label, acc_valid, t)  # logged, unused
        return PhasePlan(self.hp.n_t, self.hp.n_v, self.hp.n_l)


class EkyaScheduler(SpatiotemporalScheduler):
    """Idealized Ekya: fixed retraining window; per-window label quota then
    retraining for the rest of the window (profiling cost idealized away, as
    in the paper's baseline §III-A)."""

    name = "ekya"
    window_s = 120.0

    def next_phase(self, acc_valid, acc_label, t) -> PhasePlan:
        return PhasePlan(self.hp.n_t, self.hp.n_v, self.hp.n_l)


class EOMUScheduler(SpatiotemporalScheduler):
    """EOMU-like: short (10 s) windows; retraining triggered by a logged
    accuracy drop, otherwise the window only labels."""

    name = "eomu"
    window_s = 10.0
    drop_eps = 0.02

    def __init__(self, hp: CLHyperParams):
        super().__init__(hp)
        self._last_acc: Optional[float] = None

    def next_phase(self, acc_valid, acc_label, t) -> PhasePlan:
        self.detector.check(acc_label, acc_valid, t)
        trigger = (self._last_acc is None
                   or acc_label < self._last_acc - self.drop_eps)
        self._last_acc = acc_label
        n_t = self.hp.n_t if trigger else 0
        return PhasePlan(n_t, self.hp.n_v, self.hp.n_l)


SCHEDULERS = {
    "dacapo-spatiotemporal": SpatiotemporalScheduler,
    "dacapo-spatial": SpatialScheduler,
    "ekya": EkyaScheduler,
    "eomu": EOMUScheduler,
}
