"""Deprecated shim — the scheduler API moved to ``repro.core.allocation``.

``PhasePlan`` grew into ``AllocationDecision`` (same leading fields plus
spatial rows, per-kernel precision and window pacing), and the scheduler
classes became ``AllocationPolicy`` implementations whose decisions the
``CLSession`` engine executes. The legacy names below keep old imports and
positional constructions working; new code should import from
``repro.core.allocation``.
"""
from repro.core.allocation import (  # noqa: F401
    ALLOCATORS as SCHEDULERS,
    AllocationDecision as PhasePlan,
    CLHyperParams,
    EkyaAllocator as EkyaScheduler,
    EOMUAllocator as EOMUScheduler,
    SpatialAllocator as SpatialScheduler,
    SpatiotemporalAllocator as SpatiotemporalScheduler,
)

__all__ = [
    "CLHyperParams",
    "PhasePlan",
    "SCHEDULERS",
    "SpatiotemporalScheduler",
    "SpatialScheduler",
    "EkyaScheduler",
    "EOMUScheduler",
]
