"""Per-program execution tracing for the dispatch layer (trace spine).

Every phase the engine executes flows through a
:class:`~repro.core.dispatch.PhasePlan`: device programs are *dispatched*
(``dispatch`` / ``dispatch_multi``) and bare virtual-time *charges* land on
the role ledgers (``charge``).  A :class:`TraceRecorder` attached to the
:class:`~repro.core.dispatch.KernelDispatcher` observes exactly that stream
and records one :class:`TraceEvent` per device program (and per bare
charge), in issue order, per phase:

* the **virtual-clock cost** the program charged (and to which role/lane),
* the **host wall time** its issue took (``time.perf_counter`` around the
  async thunk — issue latency, not device occupancy: JAX dispatch is
  asynchronous, so this is the host-side cost the phase actually paid),
* the **kernel path** that served it — the dominant
  :func:`repro.kernels.ops.kernel_stats` path (``pallas`` / ``interpret`` /
  ``ref``) incremented while the thunk ran,
* the **unit count** the cost was computed from (frames scored, samples
  labeled, SGD batches) — what lets the replayer re-scale a recorded cost
  to a *candidate* decision's budgets.

Recording is strictly observational: no numeric state of the plan is
touched, so a traced run is bit-identical to an untraced one, and with no
recorder attached (the default) the dispatch layer takes its original code
path — zero overhead, pinned by tests/test_trace.py.

The recorded :class:`SessionTrace` is the input to
:class:`~repro.core.replay.TraceReplayer` (what-if phase-time prediction
and estimator calibration) and round-trips to JSON losslessly
(``save``/``load`` — floats survive bit-exactly via repr round-trip), so
traces can be analyzed offline (``examples/continuous_learning_drive.py
--trace``).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence

from repro.kernels.ops import kernel_stats

TRACE_FORMAT = "dacapo-trace-v1"


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One dispatched device program (or bare ledger charge) of a phase.

    ``kind`` is ``"program"`` for ``dispatch``/``dispatch_multi`` issues
    (``wall_s``/``path`` measured) and ``"charge"`` for bare ``charge``
    calls (retraining SGD, profiling overhead, score windows). ``fan`` is
    the number of lanes the issuing device program served (> 1 for one
    ``dispatch_multi`` program fanned across the fleet; its measured wall
    is split evenly across the per-lane events).
    """

    kind: str  # "program" | "charge"
    role: str  # "t_sa" | "b_sa"
    label: str  # dispatch label: "valid", "label", "score", "retrain", ...
    cost_s: float  # virtual-clock seconds charged
    lane: Optional[int] = None  # fleet stream lane (None: single-stream)
    wall_s: float = 0.0  # host wall seconds of the issue
    path: str = ""  # kernel_stats() path that served it ("" if none fired)
    units: float = 0.0  # quantity the cost scales with (samples/batches)
    fan: int = 1

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TraceEvent":
        return cls(**d)


@dataclasses.dataclass
class PhaseTrace:
    """One phase's recorded execution: ordered events + clock boundaries.

    ``start``/``end``/``floor`` are the plan's virtual-clock start, its
    ``finish()`` value and its pacing floor; replaying ``events`` through
    the same float-add sequence reconstructs ``end`` bit-exactly (the
    sequential SUM and the concurrent MAX both — see core/replay.py).
    ``decisions`` summarizes the per-lane two-plane decisions the phase
    executed; ``shard`` is stamped by the manager tier when shard traces
    merge at the round barrier.
    """

    index: int
    mode: str  # dispatch mode: "sequential" | "concurrent"
    start: float
    events: List[TraceEvent] = dataclasses.field(default_factory=list)
    end: float = 0.0
    floor: float = 0.0
    decisions: List[dict] = dataclasses.field(default_factory=list)
    shard: Optional[int] = None

    @property
    def duration(self) -> float:
        return self.end - self.start

    def as_dict(self) -> dict:
        return {"index": self.index, "mode": self.mode, "start": self.start,
                "end": self.end, "floor": self.floor, "shard": self.shard,
                "decisions": self.decisions,
                "events": [e.as_dict() for e in self.events]}

    @classmethod
    def from_dict(cls, d: dict) -> "PhaseTrace":
        return cls(index=d["index"], mode=d["mode"], start=d["start"],
                   end=d["end"], floor=d["floor"], shard=d.get("shard"),
                   decisions=list(d.get("decisions", [])),
                   events=[TraceEvent.from_dict(e) for e in d["events"]])


@dataclasses.dataclass
class SessionTrace:
    """A whole recorded run: the ordered phase traces + free-form meta."""

    phases: List[PhaseTrace] = dataclasses.field(default_factory=list)
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.phases)

    def events(self) -> List[TraceEvent]:
        """All events across phases, in phase/issue order."""
        return [e for ph in self.phases for e in ph.events]

    # ------------------------------------------------------------- JSON I/O
    def as_dict(self) -> dict:
        return {"format": TRACE_FORMAT, "meta": self.meta,
                "phases": [p.as_dict() for p in self.phases]}

    def to_json(self, **kwargs) -> str:
        return json.dumps(self.as_dict(), **kwargs)

    @classmethod
    def from_dict(cls, d: dict) -> "SessionTrace":
        if d.get("format") != TRACE_FORMAT:
            raise ValueError(
                f"not a {TRACE_FORMAT} document: format={d.get('format')!r}")
        return cls(phases=[PhaseTrace.from_dict(p) for p in d["phases"]],
                   meta=dict(d.get("meta", {})))

    @classmethod
    def from_json(cls, text: str) -> "SessionTrace":
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(indent=1))

    @classmethod
    def load(cls, path: str) -> "SessionTrace":
        with open(path) as f:
            return cls.from_json(f.read())


def summarize_decision(decision) -> dict:
    """The replayer-facing summary of one lane's two-plane decision: the
    spatial rows (possibly ``None`` — the engine's offline split) and the
    temporal budgets every decision-dependent cost scales with."""
    if decision is None:
        return {}
    s, t = decision.spatial, decision.temporal
    return {"rows_tsa": s.rows_tsa, "rows_bsa": s.rows_bsa,
            "inference_precision": s.precisions.inference,
            "labeling_precision": s.precisions.labeling,
            "retrain_samples": t.retrain_samples,
            "valid_samples": t.valid_samples,
            "label_samples": t.label_samples,
            "extra_label_samples": t.extra_label_samples,
            "total_label_samples": t.total_label_samples,
            "reset_buffer": t.reset_buffer,
            "retrain_epochs": t.retrain_epochs,
            "pace_window_s": t.pace_window_s,
            "profile_cost_s": t.profile_cost_s}


def _path_totals() -> Dict[str, int]:
    """Aggregate :func:`kernel_stats` counters per serving path."""
    totals: Dict[str, int] = {}
    for paths in kernel_stats().values():
        for path, n in paths.items():
            totals[path] = totals.get(path, 0) + n
    return totals


class TraceRecorder:
    """Collects :class:`PhaseTrace`s from the dispatch layer.

    Attach one to a session via ``CLSystemSpec(trace=True)`` (or hand a
    ready recorder instance to share it); the
    :class:`~repro.core.dispatch.KernelDispatcher` opens one
    :class:`PhaseTrace` per ``begin_phase`` and the plan's traced overrides
    append events as programs issue. ``capture_paths=False`` skips the
    (locked) kernel-stats snapshots around each issue when only costs and
    wall times are wanted.
    """

    def __init__(self, capture_paths: bool = True,
                 meta: Optional[dict] = None):
        self.capture_paths = capture_paths
        self.phases: List[PhaseTrace] = []
        self.meta: Dict[str, object] = dict(meta or {})

    def __len__(self) -> int:
        return len(self.phases)

    @property
    def trace(self) -> SessionTrace:
        return SessionTrace(phases=self.phases, meta=self.meta)

    # ------------------------------------------------------------ recording
    def begin_phase(self, start: float, mode: str,
                    decisions: Sequence = ()) -> PhaseTrace:
        phase = PhaseTrace(
            index=len(self.phases), mode=mode, start=start,
            decisions=[summarize_decision(d) for d in decisions])
        self.phases.append(phase)
        return phase

    def paths_before(self) -> Optional[Dict[str, int]]:
        """Kernel-path snapshot before an issue (None when not captured)."""
        return _path_totals() if self.capture_paths else None

    @staticmethod
    def dominant_path(before: Optional[Dict[str, int]]) -> str:
        """The kernel path most incremented since ``before`` ('' if none)."""
        if before is None:
            return ""
        after = _path_totals()
        deltas = {p: n - before.get(p, 0) for p, n in after.items()
                  if n - before.get(p, 0) > 0}
        if not deltas:
            return ""
        return max(sorted(deltas), key=lambda p: deltas[p])

    # ----------------------------------------------------- manager merging
    def drain_since(self, cursor: int) -> List[PhaseTrace]:
        """Completed phases recorded after ``cursor`` — the manager pulls
        these at its round barrier, in shard-index order, to build the
        deterministic merged manager trace."""
        return self.phases[cursor:]
