"""Async kernel dispatch: the plan → dispatch → collect execution layer.

The paper's core system claim (Fig. 4) is that inference on the B-SA runs
*concurrently* with labeling/retraining on the T-SA once the array is
spatially partitioned. This module is the execution layer that realizes that
overlap for the engine (core/session.py): instead of calling kernels inline
and forcing a host sync (``np.asarray``) after every call, the session builds
a per-phase :class:`PhasePlan`, *dispatches* device programs through it — JAX
async dispatch returns device arrays immediately, so programs enqueued on the
disjoint T-SA / B-SA sub-meshes overlap on device — and *collects* host
values only at the phase-end barrier where :class:`~repro.core.allocation.\
PhaseFeedback` genuinely needs them.

Virtual-clock semantics (``dispatch=`` on ``CLSystemSpec`` / ``CLSession``):

``"sequential"`` (default)
    The seed accounting, preserved bit-for-bit: everything time-shares one
    serial chain, so the phase clock advances by the **sum** of the charged
    program costs in issue order — retraining batches, validation inference
    (charged at the T-SA rows, as the seed did), labeling. The B-SA-side
    measurement programs (accuracy scoring of the serving stream,
    labeled-frame predictions) are tracked in the phase ledger but never
    gate the serial chain — exactly the seed numbers the golden test in
    ``tests/test_session.py`` pins.

``"concurrent"``
    The paper's spatial-concurrency model: T-SA and B-SA programs execute in
    parallel on their disjoint sub-accelerators, so the phase advances by
    ``max(t_TSA, t_BSA)`` — the **max** of the per-role cost totals — instead
    of the sum. Programs follow their kernel's placement: the T-SA chain is
    retraining + teacher labeling; the inference kernel's programs
    (post-update validation, labeled-frame serving predictions, accuracy
    scoring) are B-SA work charged at the B-SA's own throughput
    (``rows_bsa`` rows, the decision's inference precision). Fixed-window
    pacing (``pace_window_s``) still floors the phase end on the window grid.

Host-side, both modes issue every program eagerly (``dispatch`` calls the
program's thunk immediately); the difference is purely in clock accounting.
Because JAX dispatch is asynchronous, eager issue + deferred ``collect()`` is
what lets XLA overlap the B-SA scoring stream with T-SA work — the session
never blocks between programs of one phase.

Fleet sessions (core/fleet.py) bind N pipelines to one plan — one data-plane
lane per camera stream — and attribute every charge to a lane ledger next to
the fleet ledger, so the shared T-SA is charged once for the fleet while
per-stream shares stay auditable (``lane_time``). ``dispatch_multi`` issues
one device program on behalf of several lanes (cross-stream batched labeling)
and fans its per-lane results out into individual handles.

Trace spine (core/trace.py): the plan's program/charge stream IS the
execution trace. With a :class:`~repro.core.trace.TraceRecorder` attached
to the dispatcher (``CLSystemSpec(trace=...)``), every ``dispatch`` /
``dispatch_multi`` issue is recorded as a ``"program"``
:class:`~repro.core.trace.TraceEvent` — role, label, lane, virtual cost,
measured host wall time of the issue, the kernel path that served it, and
the unit count (samples/batches) the cost scales with — and every bare
``charge`` as a ``"charge"`` event, all in issue order. Recording is
observational only (no numeric plan state is touched), so traced runs are
bit-identical to untraced ones; with no recorder (the default) the traced
overrides reduce to a single ``is None`` check and the original code path.
The per-phase event order, the phase start/end/floor and the per-role
float-add sequence are exactly what
:class:`~repro.core.replay.TraceReplayer` replays to reconstruct — and
predict — phase times.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.trace import TraceEvent, TraceRecorder

import numpy as np

SEQUENTIAL = "sequential"
CONCURRENT = "concurrent"
DISPATCH_MODES = (SEQUENTIAL, CONCURRENT)

ROLES = ("t_sa", "b_sa")


def _as_pipelines(pipeline) -> Tuple:
    """Normalize ``begin_phase``'s pipeline argument: None, a single
    FramePipeline, or a sequence of them (one lane per fleet stream)."""
    if pipeline is None:
        return ()
    if isinstance(pipeline, (list, tuple)):
        return tuple(pipeline)
    return (pipeline,)


class ProgramHandle:
    """Deferred result of an issued device program.

    Holds the device value returned by the program's thunk; ``collect()`` is
    the only point that blocks (materializes to host numpy). Collect is
    idempotent — repeated calls return the cached host value.
    """

    __slots__ = ("_value", "_host", "_collected")

    def __init__(self, value: Any):
        self._value = value
        self._host: Any = None
        self._collected = False

    @property
    def issued(self) -> Any:
        """The raw (device-side) value, without forcing a sync."""
        return self._value

    def collect(self) -> np.ndarray:
        if not self._collected:
            self._host = np.asarray(self._value)
            self._value = None  # drop the device reference
            self._collected = True
        return self._host


@dataclasses.dataclass(frozen=True)
class DeviceProgram:
    """One dispatched unit of device work, with its virtual-clock cost."""

    role: str  # "t_sa" | "b_sa"
    label: str  # e.g. "valid", "label", "score", "acc_label"
    cost_s: float
    handle: Optional[ProgramHandle]
    lane: Optional[int] = None  # fleet stream lane this program serves


class PhasePlan:
    """Clock + program ledger for one phase, built as the session executes.

    The running T-SA clock (``now()``) reproduces the seed's float-add
    sequence exactly: each T-SA charge is a single ``+=`` on the same
    accumulator the seed used, so sequential-mode boundaries (score windows,
    pacing, loop exits) see bit-identical times.
    """

    def __init__(self, mode: str, start: float, pipeline=None):
        self.mode = mode
        self.start = start
        # Bound data plane(s): one FramePipeline per stream lane. A single
        # pipeline (the CLSession case) is lane 0 of a one-lane plan.
        self.pipelines: Tuple = _as_pipelines(pipeline)
        # The two-plane Decision(s) this phase executes (one per lane),
        # when the session hands them to begin_phase — the plan's view of
        # the phase's intent (label hints derive from the temporal plane).
        self.decisions: Tuple = ()
        self.programs: List[DeviceProgram] = []
        self.totals: Dict[str, float] = {role: 0.0 for role in ROLES}
        # Per-lane ledgers: plain sums from 0.0 (the same addends that feed
        # ``totals``), so a one-lane plan's lane ledger is bit-identical to
        # the fleet ledger — the fleet golden test relies on that.
        self.lane_totals: Dict[int, Dict[str, float]] = {}
        self._now = start  # T-SA running clock (seed accumulator)
        self._floor = start  # pacing floor on the phase end

    @property
    def pipeline(self):
        """Lane-0 pipeline (back-compat single-stream handle)."""
        return self.pipelines[0] if self.pipelines else None

    @property
    def traced(self) -> bool:
        """Is a TraceRecorder observing this plan? (Engines use this to
        gate wall-time measurement of host-side work like retraining SGD,
        keeping the untraced path free of even a ``perf_counter`` call.)"""
        return False

    # ----------------------------------------------------------- dispatch
    def dispatch(self, role: str, label: str, issue: Callable[[], Any],
                 cost_s: float = 0.0,
                 lane: Optional[int] = None,
                 units: float = 0.0) -> ProgramHandle:
        """Issue a device program *now* (async — the thunk must not block)
        and charge its cost; returns a handle to ``collect()`` later.
        ``units`` is the trace-facing quantity the cost was computed from
        (frames scored, samples labeled) — ignored untraced."""
        del units
        handle = ProgramHandle(issue())
        self.programs.append(DeviceProgram(role, label, cost_s, handle, lane))
        self.charge(role, cost_s, lane=lane)
        return handle

    def dispatch_multi(self, role: str, label: str,
                       issue: Callable[[], Sequence[Any]],
                       costs: Sequence[float],
                       lanes: Sequence[int],
                       units: Optional[Sequence[float]] = None
                       ) -> List[ProgramHandle]:
        """Issue ONE device program serving several stream lanes (e.g. a
        labeling burst batched across the fleet on the shared T-SA) and
        split its per-lane results into individual handles. The thunk must
        return one device value per lane; each lane's cost is charged to
        both the fleet ledger and that lane's ledger, in lane order — for a
        one-lane plan this is exactly a single ``dispatch``."""
        del units
        values = issue()
        if len(values) != len(lanes) or len(costs) != len(lanes):
            raise ValueError(
                f"dispatch_multi: {len(values)} values / {len(costs)} costs "
                f"for {len(lanes)} lanes")
        handles = []
        for value, cost_s, lane in zip(values, costs, lanes):
            handle = ProgramHandle(value)
            self.programs.append(
                DeviceProgram(role, label, cost_s, handle, lane))
            self.charge(role, cost_s, lane=lane)
            handles.append(handle)
        return handles

    def fetch(self, t0: float, t1: float, max_frames: int = 0,
              lane: int = 0, tag: Optional[str] = None):
        """Pipeline-aware plan step: pull a frame window for this phase's
        programs through the bound :class:`~repro.data.pipeline.\
FramePipeline` of ``lane``, so dispatch issues device programs against
        prefetched, host-ready windows (speculation hits) instead of
        stalling on inline frame synthesis. Reconciliation keeps results
        bit-identical either way. ``tag`` marks the window's role in the
        phase layout (e.g. ``"label"``) for decision-aware speculation."""
        if not self.pipelines:
            raise ValueError(
                "no FramePipeline bound to this plan; pass one to "
                "KernelDispatcher.begin_phase")
        return self.pipelines[lane].frames(t0, t1, max_frames=max_frames,
                                           tag=tag)

    def charge(self, role: str, seconds: float,
               lane: Optional[int] = None, label: Optional[str] = None,
               units: float = 0.0, wall_s: float = 0.0) -> None:
        """Charge virtual time without an attached program (e.g. retraining
        SGD, whose cost is known only after the batch count is). With a
        ``lane``, the charge is also attributed to that stream's ledger.
        ``label``/``units``/``wall_s`` annotate the charge for the trace
        spine (kernel name, quantity the cost scales with, measured host
        wall) — ignored untraced."""
        del label, units, wall_s
        self.totals[role] += seconds
        if lane is not None:
            lane_led = self.lane_totals.setdefault(
                lane, {r: 0.0 for r in ROLES})
            lane_led[role] += seconds
        if role == "t_sa":
            self._now += seconds

    def lane_time(self, role: str, lane: int) -> float:
        """This phase's virtual seconds charged to ``lane`` on ``role``."""
        return self.lane_totals.get(lane, {}).get(role, 0.0)

    def pad_to(self, t: float) -> None:
        """Floor the phase end on a pacing-grid boundary (pace_window_s)."""
        if t > self._floor:
            self._floor = t

    # -------------------------------------------------------------- clock
    def now(self) -> float:
        """Running clock while the phase is being built: the T-SA chain
        drives phase structure in both modes (the B-SA overlaps it)."""
        return self._now

    @property
    def t_tsa(self) -> float:
        # Reported from the role ledger (a plain sum from 0.0) rather than
        # as ``_now - start``: mathematically identical, but the ledger form
        # is bitwise-reproducible by per-lane accounting, which the fleet's
        # 1-stream degeneracy golden pins.
        return self.totals["t_sa"]

    @property
    def t_bsa(self) -> float:
        return self.totals["b_sa"]

    def finish(self) -> float:
        """Phase-end clock. Sequential: the T-SA sum (seed semantics);
        concurrent: start + max(t_TSA, t_BSA). Both respect the pacing
        floor, matching the seed's ``clock = next_boundary`` assignment."""
        end = self._now
        if self.mode == CONCURRENT:
            end = max(end, self.start + self.totals["b_sa"])
        return max(end, self._floor)

    # ------------------------------------------------------------ collect
    def collect_all(self) -> None:
        """Barrier: materialize every outstanding program of this phase."""
        for prog in self.programs:
            if prog.handle is not None:
                prog.handle.collect()


class KernelDispatcher:
    """Factory + bookkeeping for per-phase plans.

    One dispatcher lives on a :class:`~repro.core.session.CLSession`; its
    mode decides the clock semantics of every :class:`PhasePlan` it opens
    (see module docstring). ``phases_dispatched`` / ``programs_dispatched``
    are cumulative counters for benchmarks and tests;
    ``programs_by_label`` breaks the program count down by dispatch label
    (e.g. one batched ``"acc_label"`` program per fleet labeling burst).

    ``recorder`` (a :class:`~repro.core.trace.TraceRecorder`, default
    None) turns on the trace spine: each ``begin_phase`` opens a
    :class:`~repro.core.trace.PhaseTrace` and the plan's traced overrides
    record every program issue and ledger charge as
    :class:`~repro.core.trace.TraceEvent`s (see core/trace.py).
    """

    def __init__(self, mode: str = SEQUENTIAL,
                 recorder: Optional[TraceRecorder] = None):
        if mode not in DISPATCH_MODES:
            raise ValueError(
                f"unknown dispatch mode {mode!r}; known: {DISPATCH_MODES}")
        self.mode = mode
        self.recorder = recorder
        self.phases_dispatched = 0
        self.programs_dispatched = 0
        self.windows_fetched = 0
        self.programs_by_label: Dict[str, int] = {}

    @property
    def concurrent(self) -> bool:
        return self.mode == CONCURRENT

    def begin_phase(self, start: float, pipeline=None,
                    label_hints: Optional[Sequence] = None,
                    decisions: Optional[Sequence] = None,
                    fps: Optional[float] = None) -> PhasePlan:
        """Open a phase plan. With a ``pipeline``
        (:class:`~repro.data.pipeline.FramePipeline`, or a sequence of them
        — one lane per fleet stream), the plan becomes the phase's
        data-plane handle too: opening the plan rotates each pipeline's
        speculation onto this phase start, and ``plan.fetch(lane=i)`` serves
        the phase's frame windows from that lane's speculative prefetcher.

        ``decisions`` (one two-plane
        :class:`~repro.core.decision.Decision` per lane) is how the plan
        consumes the phase's intent: with a stream ``fps``, each lane's
        label hint — the decision-aware speculation signal — derives from
        its temporal plane's labeling budget, so drift-phase bursts are
        pre-sized instead of replayed from the last layout (``fps=None``
        records the decisions without hinting). ``label_hints`` (one
        ``(n_samples, fps)`` per lane, or None entries) is the pre-plane
        spelling of the same signal, kept for direct callers."""
        pipelines = _as_pipelines(pipeline)
        if label_hints is None and decisions is not None:
            label_hints = [
                (None if d is None or fps is None
                 else (d.temporal.total_label_samples, fps))
                for d in decisions]
        for i, pipe in enumerate(pipelines):
            hint = (label_hints[i]
                    if label_hints is not None and i < len(label_hints)
                    else None)
            pipe.begin_phase(start, label_hint=hint)
        plan = _TrackedPlan(self, self.mode, start, pipelines)
        plan.decisions = tuple(decisions) if decisions is not None else ()
        if self.recorder is not None:
            plan._trace = self.recorder.begin_phase(
                start, self.mode, decisions=plan.decisions)
        self.phases_dispatched += 1
        return plan


class _TrackedPlan(PhasePlan):
    """PhasePlan that feeds the dispatcher's cumulative counters — and,
    when the dispatcher carries a :class:`~repro.core.trace.TraceRecorder`,
    records the phase's program/charge stream as
    :class:`~repro.core.trace.TraceEvent`s. Recording never touches the
    numeric plan state (ledgers, clock, floor), so traced runs stay
    bit-identical; with ``_trace is None`` every override falls straight
    through to the untraced code path."""

    def __init__(self, dispatcher: KernelDispatcher, mode: str, start: float,
                 pipeline=None):
        super().__init__(mode, start, pipeline)
        self._dispatcher = dispatcher
        self._trace = None  # open PhaseTrace when the dispatcher records
        self._in_program = False  # suppress charge events inside dispatch

    @property
    def traced(self) -> bool:
        return self._trace is not None

    def dispatch(self, role: str, label: str, issue: Callable[[], Any],
                 cost_s: float = 0.0,
                 lane: Optional[int] = None,
                 units: float = 0.0) -> ProgramHandle:
        self._dispatcher.programs_dispatched += 1
        by_label = self._dispatcher.programs_by_label
        by_label[label] = by_label.get(label, 0) + 1
        tr = self._trace
        if tr is None:
            return super().dispatch(role, label, issue, cost_s, lane=lane)
        recorder = self._dispatcher.recorder
        before = recorder.paths_before()
        t0 = time.perf_counter()
        self._in_program = True
        try:
            handle = super().dispatch(role, label, issue, cost_s, lane=lane)
        finally:
            self._in_program = False
        wall = time.perf_counter() - t0
        tr.events.append(TraceEvent(
            kind="program", role=role, label=label, cost_s=cost_s,
            lane=lane, wall_s=wall, path=recorder.dominant_path(before),
            units=units))
        return handle

    def dispatch_multi(self, role: str, label: str,
                       issue: Callable[[], Sequence[Any]],
                       costs: Sequence[float],
                       lanes: Sequence[int],
                       units: Optional[Sequence[float]] = None
                       ) -> List[ProgramHandle]:
        self._dispatcher.programs_dispatched += 1
        by_label = self._dispatcher.programs_by_label
        by_label[label] = by_label.get(label, 0) + 1
        tr = self._trace
        if tr is None:
            return super().dispatch_multi(role, label, issue, costs, lanes)
        recorder = self._dispatcher.recorder
        before = recorder.paths_before()
        t0 = time.perf_counter()
        self._in_program = True
        try:
            handles = super().dispatch_multi(role, label, issue, costs,
                                             lanes)
        finally:
            self._in_program = False
        # One device program fanned across the lanes: the measured wall is
        # split evenly over the per-lane events (``fan`` marks the group).
        wall = (time.perf_counter() - t0) / max(1, len(lanes))
        path = recorder.dominant_path(before)
        for i, (cost_s, lane) in enumerate(zip(costs, lanes)):
            tr.events.append(TraceEvent(
                kind="program", role=role, label=label, cost_s=cost_s,
                lane=lane, wall_s=wall, path=path,
                units=(units[i] if units is not None else 0.0),
                fan=len(lanes)))
        return handles

    def charge(self, role: str, seconds: float,
               lane: Optional[int] = None, label: Optional[str] = None,
               units: float = 0.0, wall_s: float = 0.0) -> None:
        super().charge(role, seconds, lane=lane)
        tr = self._trace
        if tr is not None and not self._in_program:
            tr.events.append(TraceEvent(
                kind="charge", role=role, label=label or "charge",
                cost_s=seconds, lane=lane, wall_s=wall_s, units=units))

    def finish(self) -> float:
        end = super().finish()
        tr = self._trace
        if tr is not None:
            tr.end = end
            tr.floor = self._floor
        return end

    def fetch(self, t0: float, t1: float, max_frames: int = 0,
              lane: int = 0, tag: Optional[str] = None):
        self._dispatcher.windows_fetched += 1
        return super().fetch(t0, t1, max_frames, lane=lane, tag=tag)
