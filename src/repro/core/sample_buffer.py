"""Fixed-capacity labeled sample buffer (Algorithm 1 state).

Host-side numpy storage: the buffer lives across retraining/labeling phases
and is the unit the scheduler draws D_t/D_v from and resets on drift.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


class SampleBuffer:
    def __init__(self, capacity: int, seed: int = 0):
        self.capacity = int(capacity)
        self._x: Optional[np.ndarray] = None
        self._y: Optional[np.ndarray] = None
        self._rng = np.random.default_rng(seed)

    def __len__(self) -> int:
        return 0 if self._x is None else len(self._x)

    @property
    def full(self) -> bool:
        return len(self) >= self.capacity

    def update(self, x: np.ndarray, y: np.ndarray) -> None:
        """UpdateBuffer (Alg. 1 line 14): append, evict oldest beyond C_b."""
        assert len(x) == len(y)
        if self._x is None:
            self._x, self._y = np.asarray(x).copy(), np.asarray(y).copy()
        else:
            self._x = np.concatenate([self._x, x])
            self._y = np.concatenate([self._y, y])
        if len(self._x) > self.capacity:
            self._x = self._x[-self.capacity:]
            self._y = self._y[-self.capacity:]

    def reset(self) -> None:
        """ResetBuffer (Alg. 1 line 12): drop outdated samples on drift."""
        self._x, self._y = None, None

    def get_data(self, n_train: int,
                 n_valid: int) -> Tuple[np.ndarray, np.ndarray,
                                        np.ndarray, np.ndarray]:
        """GetData (Alg. 1 line 4): disjoint D_t / D_v draws."""
        n = len(self)
        if n == 0:
            raise ValueError("empty sample buffer")
        idx = self._rng.permutation(n)
        n_valid = min(n_valid, max(1, n // 5))
        n_train = min(n_train, n - n_valid)
        ti, vi = idx[:n_train], idx[n_train:n_train + n_valid]
        return self._x[ti], self._y[ti], self._x[vi], self._y[vi]
