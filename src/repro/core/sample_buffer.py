"""Fixed-capacity labeled sample buffer (Algorithm 1 state).

Host-side numpy storage: the buffer lives across retraining/labeling phases
and is the unit the scheduler draws D_t/D_v from and resets on drift. The
buffer is also a unit of lane state the fleet tier checkpoints and
migrates: ``state_dict``/``load_state_dict`` round-trip both the stored
samples and the draw RNG's bit-generator state, so a restored lane's future
``get_data`` permutations and evictions are bit-identical to the lane that
was snapshotted.
"""
from __future__ import annotations

import copy
from typing import Dict, Optional, Tuple

import numpy as np


class SampleBuffer:
    def __init__(self, capacity: int, seed: int = 0):
        self.capacity = int(capacity)
        self._x: Optional[np.ndarray] = None
        self._y: Optional[np.ndarray] = None
        self._rng = np.random.default_rng(seed)

    def __len__(self) -> int:
        return 0 if self._x is None else len(self._x)

    @property
    def full(self) -> bool:
        return len(self) >= self.capacity

    def update(self, x: np.ndarray, y: np.ndarray) -> None:
        """UpdateBuffer (Alg. 1 line 14): append, evict oldest beyond C_b."""
        assert len(x) == len(y)
        if self._x is None:
            self._x, self._y = np.asarray(x).copy(), np.asarray(y).copy()
        else:
            self._x = np.concatenate([self._x, x])
            self._y = np.concatenate([self._y, y])
        if len(self._x) > self.capacity:
            self._x = self._x[-self.capacity:]
            self._y = self._y[-self.capacity:]

    def reset(self) -> None:
        """ResetBuffer (Alg. 1 line 12): drop outdated samples on drift."""
        self._x, self._y = None, None

    def state_dict(self) -> Dict[str, object]:
        """Snapshot for lane checkpoint/migration: stored samples plus the
        draw RNG's bit-generator state (a plain dict, deep-copied so later
        mutation of the live buffer can't alias into the snapshot)."""
        return {
            "x": None if self._x is None else self._x.copy(),
            "y": None if self._y is None else self._y.copy(),
            "capacity": self.capacity,
            "rng_state": copy.deepcopy(self._rng.bit_generator.state),
        }

    def load_state_dict(self, state: Dict[str, object]) -> None:
        """Restore a :meth:`state_dict` snapshot bit-exactly — the next
        ``get_data``/``update`` behaves as on the snapshotted buffer."""
        self.capacity = int(state["capacity"])
        x, y = state["x"], state["y"]
        self._x = None if x is None else np.asarray(x).copy()
        self._y = None if y is None else np.asarray(y).copy()
        self._rng.bit_generator.state = copy.deepcopy(state["rng_state"])

    def get_data(self, n_train: int,
                 n_valid: int) -> Tuple[np.ndarray, np.ndarray,
                                        np.ndarray, np.ndarray]:
        """GetData (Alg. 1 line 4): disjoint D_t / D_v draws."""
        n = len(self)
        if n == 0:
            raise ValueError("empty sample buffer")
        idx = self._rng.permutation(n)
        n_valid = min(n_valid, max(1, n // 5))
        n_train = min(n_train, n - n_valid)
        ti, vi = idx[:n_train], idx[n_train:n_train + n_valid]
        return self._x[ti], self._y[ti], self._x[vi], self._y[vi]
