"""Spatial partitioning: mesh fission into T-SA / B-SA sub-meshes.

The paper splits a systolic array's rows into a top (training+labeling) and
bottom (inference) sub-accelerator (§V-A). The TPU-pod analogue splits the
device mesh along its first axis into two sub-meshes; JAX dispatches onto
disjoint device sets concurrently, which is exactly the paper's concurrency
model. On a single device the partition degenerates to time-sharing (the
paper's own fallback when R_tsa or R_bsa is 0).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax
from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class SpatialPartition:
    t_sa: Optional[Mesh]  # retraining + labeling (time-shared, Alg. 1)
    b_sa: Optional[Mesh]  # inference, sized to the input frame rate
    time_shared: bool  # single-resource fallback

    @property
    def t_devices(self):
        return None if self.t_sa is None else self.t_sa.devices

    @property
    def b_devices(self):
        return None if self.b_sa is None else self.b_sa.devices


def partition_mesh(mesh: Mesh, rows_bsa: int,
                   row_axis: Optional[str] = None) -> SpatialPartition:
    """Split ``mesh`` along ``row_axis`` (default: first axis): the last
    ``rows_bsa`` rows become B-SA, the rest T-SA.

    Mirrors the paper's row-granular fission — the 'programmable memory
    interface' reprogramming becomes the NamedShardings of each sub-mesh.
    """
    axis = row_axis or mesh.axis_names[0]
    ax_idx = mesh.axis_names.index(axis)
    n_rows = mesh.devices.shape[ax_idx]
    if n_rows < 2 or rows_bsa <= 0 or rows_bsa >= n_rows:
        return SpatialPartition(t_sa=mesh, b_sa=mesh, time_shared=True)
    dev = np.moveaxis(mesh.devices, ax_idx, 0)
    t_dev = np.moveaxis(dev[: n_rows - rows_bsa], 0, ax_idx)
    b_dev = np.moveaxis(dev[n_rows - rows_bsa:], 0, ax_idx)
    t_sa = Mesh(t_dev, mesh.axis_names)
    b_sa = Mesh(b_dev, mesh.axis_names)
    return SpatialPartition(t_sa=t_sa, b_sa=b_sa, time_shared=False)


def single_device_partition() -> SpatialPartition:
    return SpatialPartition(t_sa=None, b_sa=None, time_shared=True)


def forced_row_mesh(n_rows: int) -> Mesh:
    """An ``n_rows x 1`` mesh for exercising mesh fission anywhere: real
    devices when the host has enough, the first device repeated otherwise
    (benchmarks, tests and examples on single-device containers)."""
    devices = jax.devices()
    rows = (devices[:n_rows] if len(devices) >= n_rows
            else devices[:1] * n_rows)
    return Mesh(np.array(rows).reshape(n_rows, 1), ("data", "model"))
