"""Resource-allocation policies — Algorithm 1 and the §III baselines as data.

This module is the *policy* layer of the continuous-learning stack: an
``AllocationPolicy`` looks at per-phase feedback (validation vs. fresh-label
accuracy, the engine-side drift flag, the virtual clock) and emits a
decision describing everything the engine (core/session.py) should do next.
The decision surface is two composable planes (core/decision.py): a
``SpatialPlan`` (T-SA/B-SA rows, per-kernel MX precisions, mesh re-fission
intent) and a ``TemporalPlan`` (sample budgets, pacing window, retraining
depth, profiling cost), combined by a frozen ``Decision`` the engine
consumes. The flat ``AllocationDecision`` below is the thin bidirectional
facade over those planes (``.split()`` / ``.from_decision()``) that every
pre-plane policy, golden and benchmark still targets — the round trip is
the identity, so both surfaces are equivalent. The engine executes either
mechanically; every behavioural difference between DaCapo-Spatiotemporal,
DaCapo-Spatial, Ekya and EOMU lives here, not in the engine loop.

Policies are constructed from hyper-parameters only and later ``bind``-ed to
a performance estimator + student config, at which point they compute their
offline spatial split (GetSpatialAllocation, Alg. 1 line 1). Because every
decision carries its own spatial plane, a policy is free to re-allocate
spatially *online* — the paper's DC-ST does so temporally;
``OnlineSpatiotemporalAllocator`` (DC-ST-Online) exercises the spatial axis
too, shifting rows from B-SA to T-SA at drift time under a hysteresis
window and returning them as validation accuracy recovers.

Fleets add one more layer: ``FleetAllocator`` wraps a per-stream policy per
camera lane and emits ``FleetDecision``s — N per-lane ``TemporalPlan``s
(the re-proportioned temporal budgets) plus ONE fleet-wide ``SpatialPlan``
resolved by a pluggable ``FleetRowPolicy`` (resolve-max / drift-surge /
weighted-vote, see core/decision.py).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, List, Optional, Sequence, Tuple, Type

from repro.configs.dacapo_pairs import VisionConfig
from repro.core.decision import (
    Decision,
    FleetDecision,
    FleetRowContext,
    SpatialPlan,
    TemporalPlan,
    make_fleet_row_policy,
)
from repro.core.drift import DriftDetector
from repro.core.estimator import spatial_allocation
from repro.core.mx import DEFAULT_POLICY, PrecisionPolicy


@dataclasses.dataclass
class CLHyperParams:
    """Table I notation."""

    n_t: int = 256  # samples per retraining phase
    n_l: int = 128  # samples labeled at usual
    n_ldd_mult: int = 4  # N_ldd = 4 * N_l (paper §VI-B)
    c_b: int = 1024  # sample buffer capacity
    v_thr: float = -0.10  # drift threshold on acc_l - acc_v (tuned offline
    # per paper §VI-D; -0.05 false-positives on n_l=32..48 estimates)
    fps: float = 30.0
    epochs: int = 1
    sgd_batch: int = 16  # paper §VII-A
    lr: float = 1e-3  # paper §VII-A

    @property
    def n_v(self) -> int:  # N_v = N_t / 4 (paper §VI-B)
        return max(1, self.n_t // 4)

    @property
    def n_ldd(self) -> int:
        return self.n_ldd_mult * self.n_l


@dataclasses.dataclass(frozen=True)
class AllocationDecision:
    """One phase of work, flat — the facade over the two decision planes.

    The leading five fields match the legacy ``PhasePlan`` layout so old
    positional constructions keep working; the trailing fields are the richer
    spatial/precision/pacing surface this API adds. :meth:`split` lifts the
    flat decision into a two-plane :class:`~repro.core.decision.Decision`
    (what the engines actually consume) and :meth:`from_decision` flattens
    one back; ``d.split().to_legacy() == d`` for every decision.
    """

    retrain_samples: int
    valid_samples: int
    label_samples: int
    reset_buffer: bool = False
    extra_label_samples: int = 0  # N_ldd - N_l on drift (Alg. 1 line 13)
    rows_tsa: Optional[int] = None  # None -> engine's offline split
    rows_bsa: Optional[int] = None
    precisions: PrecisionPolicy = DEFAULT_POLICY
    pace_window_s: Optional[float] = None  # fixed-window grid period
    retrain_epochs: Optional[int] = None  # None -> hp.epochs (fleet knob)
    profile_cost_s: float = 0.0  # T-SA seconds of profiling overhead

    @property
    def total_label_samples(self) -> int:
        return self.label_samples + self.extra_label_samples

    # ------------------------------------------------- two-plane facade
    def split(self) -> Decision:
        """Lift into the two-plane API: (SpatialPlan, TemporalPlan)."""
        return Decision.from_legacy(self)

    @classmethod
    def from_decision(cls, decision: Decision) -> "AllocationDecision":
        """Flatten a two-plane decision back into the legacy layout."""
        return decision.to_legacy()


@dataclasses.dataclass(frozen=True)
class PhaseFeedback:
    """What the engine reports back to the policy after each phase.

    ``drifted`` is the engine-side drift verdict for the phase — the single
    source of truth every policy (DC-ST, DC-ST-Online, the fleet
    drift-weighted signal) reads instead of re-deriving drift from
    ``acc_label - acc_valid`` itself. ``None`` means the feedback came
    through a path that predates the field (the legacy ``next_phase`` API,
    hand-built feedbacks in tests); policies then fall back to their own
    detector via :meth:`AllocationPolicy._drift`.
    """

    acc_valid: float
    acc_label: float
    t: float  # virtual clock at phase end
    phase_start: float = 0.0
    retrain_time: float = 0.0
    label_time: float = 0.0
    drifted: Optional[bool] = None  # engine-side drift verdict


class AllocationPolicy:
    """Base policy: fixed Table-I temporal budgets, offline spatial split.

    Subclasses override :meth:`next_decision` (and optionally
    ``pace_window_s``). ``initial_plan``/``next_phase`` are deprecated
    aliases kept for the legacy scheduler API.
    """

    name = "base"
    pace_window_s: Optional[float] = None
    # Trace-scored policies set True: the session then auto-creates a
    # TraceRecorder (core/trace.py) and hands it over via attach_trace.
    needs_trace = False

    def __init__(self, hp: CLHyperParams,
                 precision: PrecisionPolicy = DEFAULT_POLICY):
        self.hp = hp
        self.precision = precision
        self.detector = DriftDetector(v_thr=hp.v_thr)
        self._rows: Tuple[Optional[int], Optional[int]] = (None, None)
        self._trace_recorder = None

    def attach_trace(self, recorder) -> None:
        """Receive the session's TraceRecorder (called at construction
        when tracing is on; a no-op source of replay context for policies
        that don't score by replay)."""
        self._trace_recorder = recorder

    # -------------------------------------------------------------- binding
    def bind(self, estimator, student_cfg: VisionConfig) -> "AllocationPolicy":
        """GetSpatialAllocation (Alg. 1 line 1): compute the offline
        T-SA/B-SA split this policy's decisions will carry."""
        self._rows = spatial_allocation(estimator, student_cfg, self.hp.fps,
                                        self.precision.inference)
        return self

    @property
    def rows(self) -> Tuple[Optional[int], Optional[int]]:
        return self._rows

    # ------------------------------------------------------------ decisions
    def _decision(self, retrain_samples: int, *, reset: bool = False,
                  extra_label: int = 0) -> AllocationDecision:
        r_tsa, r_bsa = self._rows
        return AllocationDecision(
            retrain_samples=retrain_samples,
            valid_samples=self.hp.n_v,
            label_samples=self.hp.n_l,
            reset_buffer=reset,
            extra_label_samples=extra_label,
            rows_tsa=r_tsa,
            rows_bsa=r_bsa,
            precisions=self.precision,
            pace_window_s=self.pace_window_s,
        )

    def initial_decision(self) -> AllocationDecision:
        return self._decision(self.hp.n_t)

    def next_decision(self, feedback: PhaseFeedback) -> AllocationDecision:
        raise NotImplementedError

    # ---------------------------------------------------------------- drift
    def observe_drift(self, acc_label: float, acc_valid: float,
                      t: float) -> bool:
        """The drift verdict for a phase — called once by the engine at the
        phase barrier, and handed to the policy on ``feedback.drifted``.
        Delegates to this policy's detector, so scripted/custom detectors
        keep steering the run."""
        return self.detector.check(acc_label, acc_valid, t)

    def _drift(self, feedback: PhaseFeedback) -> bool:
        """The phase's drift flag: the engine-set source of truth when
        present, else this policy's own detector (legacy feedback paths)."""
        if feedback.drifted is not None:
            return feedback.drifted
        return self.observe_drift(feedback.acc_label, feedback.acc_valid,
                                  feedback.t)

    # ------------------------------------------------- legacy scheduler API
    def initial_plan(self) -> AllocationDecision:
        warnings.warn(
            "AllocationPolicy.initial_plan() is deprecated; use "
            "initial_decision() (or the two-plane Decision API via "
            ".split())", DeprecationWarning, stacklevel=2)
        return self.initial_decision()

    def next_phase(self, acc_valid: float, acc_label: float,
                   t: float) -> AllocationDecision:
        warnings.warn(
            "AllocationPolicy.next_phase() is deprecated; use "
            "next_decision(PhaseFeedback(...)) (or the two-plane Decision "
            "API via .split())", DeprecationWarning, stacklevel=2)
        return self.next_decision(
            PhaseFeedback(acc_valid=acc_valid, acc_label=acc_label, t=t))


class SpatiotemporalAllocator(AllocationPolicy):
    """DaCapo-Spatiotemporal (DC-ST): drift-adaptive temporal allocation.

    Alg. 1 lines 11-13: on drift, reset the buffer and extend the labeling
    phase to N_ldd samples."""

    name = "dacapo-spatiotemporal"

    def next_decision(self, feedback: PhaseFeedback) -> AllocationDecision:
        drift = self._drift(feedback)
        if drift:
            return self._decision(self.hp.n_t, reset=True,
                                  extra_label=self.hp.n_ldd - self.hp.n_l)
        return self._decision(self.hp.n_t)


class SpatialAllocator(SpatiotemporalAllocator):
    """DaCapo-Spatial (DC-S): static spatial split, fixed temporal
    alternation — never resets the buffer nor boosts labeling."""

    name = "dacapo-spatial"

    def next_decision(self, feedback: PhaseFeedback) -> AllocationDecision:
        self._drift(feedback)  # logged, unused
        return self._decision(self.hp.n_t)


class OnlineSpatiotemporalAllocator(SpatiotemporalAllocator):
    """DaCapo-Spatiotemporal-Online (DC-ST-Online): drift-reactive *online
    spatial* re-allocation on top of DC-ST's temporal boost.

    ECCO-style (PAPERS.md): when drift fires, ``boost_rows`` rows move from
    the B-SA to the T-SA so labeling the N_ldd burst and retraining on the
    fresh buffer run wider, at the cost of serving throughput (the engine's
    ``keep_frac`` drops while boosted). The boost is bounded by a
    *hysteresis window* — at least ``hysteresis_phases`` phases pass before
    rows may return — and rows are handed back once ``acc_valid`` recovers
    to its pre-drift running level (tracked as an EMA over un-boosted
    phases) within ``recover_margin``. A fresh drift while boosted re-arms
    the window.

    ``boost_rows=0`` disables re-allocation entirely, making the policy
    decision-for-decision identical to DC-ST (the golden guard in
    tests/test_pipeline.py pins that). ``boost_rows=None`` picks a default
    at ``bind`` time: a quarter of the offline B-SA rows, at least one, and
    never draining the B-SA below one row.
    """

    name = "dacapo-spatiotemporal-online"

    def __init__(self, hp: CLHyperParams,
                 precision: PrecisionPolicy = DEFAULT_POLICY,
                 boost_rows: Optional[int] = None,
                 hysteresis_phases: int = 2,
                 recover_margin: float = 0.05):
        super().__init__(hp, precision)
        self._boost_cfg = boost_rows
        self.hysteresis_phases = hysteresis_phases
        self.recover_margin = recover_margin
        self.boost_rows = 0
        self._boosted = False
        self._hold = 0
        self._acc_ema: Optional[float] = None

    def bind(self, estimator, student_cfg: VisionConfig) -> "AllocationPolicy":
        super().bind(estimator, student_cfg)
        r_tsa, r_bsa = self._rows
        if not r_tsa or not r_bsa:
            # R=0 fallback regime: one side already time-shares the whole
            # array (rows=0 means "all rows" to the engine), so shifting
            # rows would *shrink* it to a tiny exclusive slice. Disable.
            self.boost_rows = 0
            return self
        avail = max(0, r_bsa - 1)  # never drain the B-SA entirely
        want = (max(1, r_bsa // 4) if self._boost_cfg is None
                else self._boost_cfg)
        self.boost_rows = min(want, avail)
        return self

    def _current_rows(self) -> Tuple[Optional[int], Optional[int]]:
        r_tsa, r_bsa = self._rows
        if self._boosted and r_tsa is not None:
            return r_tsa + self.boost_rows, r_bsa - self.boost_rows
        return r_tsa, r_bsa

    def _decision(self, retrain_samples: int, *, reset: bool = False,
                  extra_label: int = 0) -> AllocationDecision:
        base = super()._decision(retrain_samples, reset=reset,
                                 extra_label=extra_label)
        r_tsa, r_bsa = self._current_rows()
        return dataclasses.replace(base, rows_tsa=r_tsa, rows_bsa=r_bsa)

    def next_decision(self, feedback: PhaseFeedback) -> AllocationDecision:
        drift = self._drift(feedback)
        if not self._boosted and not drift:
            # Healthy-state acc_valid baseline the recovery check targets
            # (drift-phase feedback is contaminated and never enters it).
            self._acc_ema = (feedback.acc_valid if self._acc_ema is None
                             else 0.5 * self._acc_ema
                             + 0.5 * feedback.acc_valid)
        if drift and self.boost_rows > 0:
            self._boosted = True
            self._hold = self.hysteresis_phases
        elif self._boosted:
            self._hold -= 1
            recovered = (feedback.acc_valid
                         >= (self._acc_ema or 0.0) - self.recover_margin)
            if self._hold <= 0 and recovered:
                self._boosted = False
        if drift:
            return self._decision(self.hp.n_t, reset=True,
                                  extra_label=self.hp.n_ldd - self.hp.n_l)
        return self._decision(self.hp.n_t)


class EkyaAllocator(SpatiotemporalAllocator):
    """Ekya: fixed 120 s retraining window; per-window label quota then
    retraining for the rest of the window. Window pacing is declared on
    every decision via ``pace_window_s`` — the engine pads the virtual clock
    to the next window-grid boundary, with no Ekya-specific branch.

    The real Ekya microprofiles candidate retraining configurations at each
    window on the shared retraining accelerator; the paper's baseline (and
    this class's default, ``profile_cost=0.0``) idealizes that cost away.
    A positive ``profile_cost`` (seconds per retraining window) rides on
    every decision as ``profile_cost_s`` and is charged to the T-SA ledger
    by the engine before the window's retraining starts — the non-idealized
    variant eats into each window's retraining/labeling time exactly as
    microprofiling does."""

    name = "ekya"
    pace_window_s = 120.0

    def __init__(self, hp: CLHyperParams,
                 precision: PrecisionPolicy = DEFAULT_POLICY,
                 profile_cost: float = 0.0):
        super().__init__(hp, precision)
        self.profile_cost = profile_cost

    def _decision(self, retrain_samples: int, *, reset: bool = False,
                  extra_label: int = 0) -> AllocationDecision:
        base = super()._decision(retrain_samples, reset=reset,
                                 extra_label=extra_label)
        if not self.profile_cost:
            return base  # idealized default: decisions identical to seed
        return dataclasses.replace(base, profile_cost_s=self.profile_cost)

    def next_decision(self, feedback: PhaseFeedback) -> AllocationDecision:
        return self._decision(self.hp.n_t)


class EOMUAllocator(SpatiotemporalAllocator):
    """EOMU-like: short (10 s) windows; retraining triggered by a logged
    accuracy drop, otherwise the window only labels."""

    name = "eomu"
    pace_window_s = 10.0
    drop_eps = 0.02

    def __init__(self, hp: CLHyperParams,
                 precision: PrecisionPolicy = DEFAULT_POLICY):
        super().__init__(hp, precision)
        self._last_acc: Optional[float] = None

    def next_decision(self, feedback: PhaseFeedback) -> AllocationDecision:
        self._drift(feedback)  # logged, unused (EOMU triggers on drops)
        trigger = (self._last_acc is None
                   or feedback.acc_label < self._last_acc - self.drop_eps)
        self._last_acc = feedback.acc_label
        return self._decision(self.hp.n_t if trigger else 0)


class ReplayAllocator(SpatiotemporalAllocator):
    """DaCapo-Replay: DC-ST with replay-scored retraining boosts.

    The first allocator whose profiling cost is *measured*, not assumed:
    each phase it builds K candidate decisions (DC-ST's choice with the
    retraining budget boosted by ``boost_factors``, quantized to SGD-batch
    multiples and capped at the buffer capacity), prices each by
    :meth:`~repro.core.replay.TraceReplayer.predict` against the just-
    recorded phase instead of executing it, and picks the largest boost
    whose predicted phase time stays within ``slack_tol`` of the
    unboosted prediction. Under concurrent dispatch that fills the T-SA
    slack of B-SA-bound phases with extra retraining for free; under
    sequential dispatch (no slack by construction) every boost extends
    the phase and the policy degenerates to DC-ST. The wall time the
    replay scoring itself took is charged to the decision's
    ``profile_cost_s`` — the Ekya microprofiling cost, made real.

    ``needs_trace`` makes the session auto-create a
    :class:`~repro.core.trace.TraceRecorder` when none is configured.
    """

    name = "dacapo-replay"
    needs_trace = True

    def __init__(self, hp: CLHyperParams,
                 precision: PrecisionPolicy = DEFAULT_POLICY,
                 boost_factors: Sequence[float] = (3.0, 2.0, 1.5),
                 slack_tol: float = 0.02):
        super().__init__(hp, precision)
        self.boost_factors = tuple(sorted(boost_factors, reverse=True))
        self.slack_tol = slack_tol

    def next_decision(self, feedback: PhaseFeedback) -> AllocationDecision:
        from repro.core.replay import TraceReplayer

        base = super().next_decision(feedback)
        recorder = self._trace_recorder
        if recorder is None or len(recorder) == 0:
            return base
        phases = recorder.phases
        last = len(phases) - 1
        if not any(e.label == "retrain" for e in phases[last].events):
            return base  # no retraining recorded: nothing to re-price
        t0 = time.perf_counter()
        replayer = TraceReplayer(recorder.trace, hp=self.hp)
        budget = replayer.predict(last, base) * (1.0 + self.slack_tol)
        pick = base
        for factor in self.boost_factors:  # descending: largest fit wins
            n = self.hp.sgd_batch * int(
                base.retrain_samples * factor // self.hp.sgd_batch)
            n = min(n, self.hp.c_b)
            if n <= base.retrain_samples:
                continue
            cand = dataclasses.replace(base, retrain_samples=n)
            if replayer.predict(last, cand) <= budget:
                pick = cand
                break
        # The replay scoring's measured wall IS the profiling cost.
        return dataclasses.replace(
            pick, profile_cost_s=time.perf_counter() - t0)


FLEET_MODES = ("uniform", "round-robin", "drift-weighted", "isolated")


class FleetAllocator(AllocationPolicy):
    """Cross-stream T-SA allocator: wraps one per-stream policy per camera
    and splits the fleet's shared labeling/retraining budget across streams
    each phase (Ekya's multi-tenant scheduling problem, ECCO's cross-camera
    budget sharing — PAPERS.md).

    Each stream lane keeps an ordinary :class:`AllocationPolicy` (its own
    drift detector, its own online row state), so DC-ST / DC-ST-Online /
    Ekya / EOMU compose unchanged; the fleet layer *re-proportions* the
    temporal budgets the lane policies emit, and resolves their spatial
    requests into ONE fleet :class:`~repro.core.decision.SpatialPlan` via
    the pluggable ``row_policy``
    (:class:`~repro.core.decision.FleetRowPolicy`: ``resolve-max`` — the
    bit-identical default — / ``drift-surge`` / ``weighted-vote``), emitted
    together as a per-phase :class:`~repro.core.decision.FleetDecision`
    (``initial_fleet_decision`` / ``next_fleet_decision``). The fleet-wide
    budget per
    phase is ``budget_streams`` sessions' worth of T-SA work (default 1.0:
    an N-stream fleet spends the same per-phase T-SA time a single session
    would, keeping the phase cadence — and thus each stream's update
    latency — independent of N).

    Modes (``FLEET_MODES``):

    * ``uniform`` — every stream gets ``1/N`` of the budget every phase;
    * ``round-robin`` — one focus stream per phase gets the whole budget,
      the rest label at the ``label_floor`` and retrain at the heartbeat
      minimum (drift stays detectable on every camera);
    * ``drift-weighted`` — shares follow each stream's accuracy-loss
      signal: the drift gap ``max(0, acc_valid - acc_label)`` (spikes at
      drift onset, before the buffer reset) plus the *recovery deficit*
      ``max(0, best_acc - acc_label)`` — how far the lane currently runs
      below its own healthy fresh-label accuracy (an EMA-tracked high-water
      mark), which keeps budget on a drifted camera through retraining,
      after the reset has collapsed the gap term — with a ``× drift_bias``
      boost on phases whose lane policy fired drift;
    * ``isolated`` — no re-proportioning at all: every stream keeps its
      full per-session budget, so the fleet phase costs ~N× the T-SA time
      (the naive "N sessions time-sharing one accelerator" baseline the
      fleet bench compares against).

    Per-stream decisions are emitted as ordinary ``AllocationDecision``s
    (scaled via ``dataclasses.replace``), and a weight of exactly 1 returns
    the lane decision object untouched — a 1-stream fleet is decision-for-
    decision identical to the wrapped policy, which the degeneracy golden
    pins. With ``scale_epochs``, retraining depth is proportioned too: a
    lane at ``k×`` its uniform share retrains for ``round(k × hp.epochs)``
    epochs (≥ 1).

    Scaled sample budgets are quantized to multiples of ``bucket`` (labels/
    retraining; validation to ``bucket // 2``): continuously drift-varying
    budgets would otherwise give every phase a unique batch shape and make
    XLA recompile the (expensive) teacher/student applies per phase —
    bucketing keeps the shape set small, which is what makes drift-weighted
    fleets run at uniform-split wall speed.
    """

    name = "fleet"

    def __init__(self, hp: CLHyperParams,
                 precision: PrecisionPolicy = DEFAULT_POLICY,
                 policy="dacapo-spatiotemporal",
                 mode: str = "drift-weighted",
                 budget_streams: float = 1.0,
                 label_floor: float = 0.25,
                 drift_bias: float = 4.0,
                 gap_eps: float = 0.02,
                 gap_ema: float = 0.5,
                 scale_epochs: bool = False,
                 bucket: int = 8,
                 row_policy="resolve-max"):
        super().__init__(hp, precision)
        if mode not in FLEET_MODES:
            raise ValueError(
                f"unknown fleet mode {mode!r}; known: {FLEET_MODES}")
        if isinstance(policy, FleetAllocator) or policy is FleetAllocator:
            raise ValueError("FleetAllocator cannot wrap itself")
        self._policy_spec = policy
        self.mode = mode
        self.row_policy = make_fleet_row_policy(row_policy)
        self.name = f"fleet-{mode}"
        if self.row_policy.name != "resolve-max":
            self.name = f"fleet-{mode}+{self.row_policy.name}"
        self.budget_streams = budget_streams
        self.label_floor = label_floor
        self.drift_bias = drift_bias
        self.gap_eps = gap_eps
        self.gap_ema = gap_ema
        self.scale_epochs = scale_epochs
        self.bucket = max(1, bucket)
        self.policies: List[AllocationPolicy] = []
        self._estimator = None
        self._student_cfg: Optional[VisionConfig] = None
        self._rr = 0  # round-robin focus cursor
        self._gaps: List[float] = []  # per-stream drift-gap EMA
        self._acc_ema: List[Optional[float]] = []  # fresh-label acc EMA
        self._acc_best: List[float] = []  # healthy-acc high-water mark
        self._last_weights: Optional[List[float]] = None  # last split shares
        self._last_base: Optional[List[AllocationDecision]] = None

    # -------------------------------------------------------------- binding
    def bind(self, estimator, student_cfg: VisionConfig) -> "FleetAllocator":
        super().bind(estimator, student_cfg)
        self._estimator, self._student_cfg = estimator, student_cfg
        for p in self.policies:
            p.precision = self.precision
            p.bind(estimator, student_cfg)
        return self

    def lanes(self, n: int) -> List[AllocationPolicy]:
        """(Re)create the per-stream policies for an ``n``-stream run —
        fresh drift detectors and round-robin/EMA state every run."""
        if isinstance(self._policy_spec, AllocationPolicy):
            if n > 1:
                raise ValueError(
                    "FleetAllocator needs a policy name/class for n > 1 "
                    "streams (a shared instance would share detector state)")
            self.policies = [self._policy_spec][:n]
        else:
            self.policies = [make_allocator(self._policy_spec, self.hp,
                                            self.precision)
                             for _ in range(n)]
        for p in self.policies:
            p.precision = self.precision
            if self._estimator is not None:
                p.bind(self._estimator, self._student_cfg)
        self._rr = 0
        self._gaps = [0.0] * n
        self._acc_ema = [None] * n
        self._acc_best = [0.0] * n
        self._last_weights = None
        self._last_base = None
        self.row_policy.reset(n)
        return self.policies

    def begin_empty(self) -> None:
        """Start a zero-lane fleet that ``admit_lane`` will populate — the
        manager's restore path (an empty shard receiving re-homed lanes).
        Fresh fleet-side state, with the base-decision ledger open so the
        first ``rebuild_fleet_decision`` sees the admitted lanes."""
        self.lanes(0)
        self._last_base = []

    # ------------------------------------------------------------ decisions
    _SINGLE_STREAM_MSG = (
        "FleetAllocator emits per-stream decision lists "
        "(initial_decisions/next_decisions) and must run inside a "
        "FleetSession — build one via FleetSpec, not CLSystemSpec")

    def initial_decision(self) -> AllocationDecision:
        raise TypeError(self._SINGLE_STREAM_MSG)

    def next_decision(self, feedback: PhaseFeedback) -> AllocationDecision:
        raise TypeError(self._SINGLE_STREAM_MSG)

    def initial_decisions(self, n: int) -> List[AllocationDecision]:
        self.lanes(n)  # fresh per-lane policies/state every run
        base = [p.initial_decision() for p in self.policies]
        self._last_base = list(base)
        self._last_weights = self._weights(base, None)
        return self._split(base, self._last_weights)

    def next_decisions(self, feedbacks: Sequence[PhaseFeedback]
                       ) -> List[AllocationDecision]:
        if len(feedbacks) != len(self.policies):
            raise ValueError(
                f"{len(feedbacks)} feedbacks for {len(self.policies)} lanes")
        base = [p.next_decision(fb)
                for p, fb in zip(self.policies, feedbacks)]
        self._last_base = list(base)
        self._last_weights = self._weights(base, feedbacks)
        return self._split(base, self._last_weights)

    # ------------------------------------------------------ fleet decisions
    def initial_fleet_decision(self, n: int) -> FleetDecision:
        """The fleet phase as a first-class decision: N per-lane temporal
        planes + ONE fleet spatial plane from the bound row policy."""
        return self._fleet_decision(self.initial_decisions(n), None)

    def next_fleet_decision(self, feedbacks: Sequence[PhaseFeedback]
                            ) -> FleetDecision:
        return self._fleet_decision(self.next_decisions(feedbacks),
                                    feedbacks)

    def _fleet_decision(self, lane_decisions: Sequence[AllocationDecision],
                        feedbacks: Optional[Sequence[PhaseFeedback]]
                        ) -> FleetDecision:
        if self._estimator is None:
            raise RuntimeError(
                "FleetAllocator must be bound (estimator + student config) "
                "before emitting FleetDecisions")
        n = len(lane_decisions)
        total = self._estimator.total_rows
        planes = [d.split() for d in lane_decisions]
        spatials = [p.spatial.resolve(self._rows[0], self._rows[1], total)
                    for p in planes]
        # The fleet executes ONE spatial plane, so one PrecisionPolicy:
        # lane precisions are forced to the fleet's at bind/lanes() time —
        # refuse loudly if a custom lane policy diverged anyway, rather
        # than silently charging every lane at lane 0's precisions.
        first = spatials[0].precisions
        if any(s.precisions != first for s in spatials[1:]):
            raise ValueError(
                "heterogeneous per-lane precisions are not supported at "
                "the fleet level: the FleetDecision carries ONE fleet "
                "SpatialPlan (and ledger) for the whole array")
        # Engine-side drift truth when the feedback carries it; a lane
        # policy's reset flag is the pre-`drifted` fallback (identical for
        # DC-ST-family lanes, where reset fires exactly on drift).
        drifted = tuple(
            (fb.drifted if fb is not None and fb.drifted is not None
             else d.reset_buffer)
            for fb, d in zip(feedbacks or [None] * n, lane_decisions))
        weights = tuple(self._last_weights or [1.0 / n] * n)
        ctx = FleetRowContext(drifted=drifted, weights=weights,
                              total_rows=total)
        return FleetDecision(
            spatial=self.row_policy.fleet_spatial(spatials, ctx),
            temporal=tuple(p.temporal for p in planes),
            lane_decisions=tuple(lane_decisions))

    # ------------------------------------------------------ lane membership
    # The fleet-manager tier changes membership mid-run: a camera is
    # admitted, a lane migrates between shards, a dead shard's lanes are
    # re-homed onto survivors. These hooks keep every per-lane parallel
    # list (policy, drift-gap EMA, fresh-label EMA, high-water mark, last
    # base decision) consistent without resetting the surviving lanes'
    # state the way ``lanes()`` would.

    def lane_policy_state(self, i: int) -> tuple:
        """The fleet-side state of lane ``i``, as ``admit_lane`` re-accepts
        it: (gap EMA, fresh-label EMA, high-water mark, last base
        decision). Part of a lane snapshot — restoring it on the target
        fleet makes the drift-weighted split treat the migrated lane
        exactly as the source fleet would have."""
        base = None if self._last_base is None else self._last_base[i]
        return (self._gaps[i], self._acc_ema[i], self._acc_best[i], base)

    def admit_lane(self, policy: Optional[AllocationPolicy] = None,
                   lane_state: Optional[tuple] = None) -> int:
        """Grow the fleet by one lane mid-run (admission, or a migrated
        lane re-homing here). ``policy`` is the migrating lane's live
        :class:`AllocationPolicy` — carrying its drift detector — or None
        for a fresh camera; ``lane_state`` is :meth:`lane_policy_state`
        from the source fleet. Returns the new lane index."""
        if policy is None:
            if isinstance(self._policy_spec, AllocationPolicy):
                raise ValueError(
                    "cannot admit a fresh lane into a FleetAllocator built "
                    "around a shared policy instance — pass a policy "
                    "name/class, or hand admit_lane the lane's policy")
            policy = make_allocator(self._policy_spec, self.hp,
                                    self.precision)
        policy.precision = self.precision
        if self._estimator is not None:
            policy.bind(self._estimator, self._student_cfg)
        self.policies.append(policy)
        gap, ema, best, base = lane_state or (0.0, None, 0.0, None)
        self._gaps.append(gap)
        self._acc_ema.append(ema)
        self._acc_best.append(best)
        if self._last_base is not None:
            self._last_base.append(base if base is not None
                                   else policy.initial_decision())
        return len(self.policies) - 1

    def remove_lane(self, i: int) -> AllocationPolicy:
        """Shrink the fleet by lane ``i`` (migration out / lane retired),
        returning its live policy so a migration can carry it along."""
        policy = self.policies.pop(i)
        self._gaps.pop(i)
        self._acc_ema.pop(i)
        self._acc_best.pop(i)
        if self._last_base is not None:
            self._last_base.pop(i)
        if self._last_weights is not None and i < len(self._last_weights):
            self._last_weights.pop(i)
        return policy

    def rebuild_fleet_decision(self) -> FleetDecision:
        """Re-emit a :class:`FleetDecision` for the *current* membership
        from the lanes' last base decisions — the phase-boundary refresh
        after ``admit_lane``/``remove_lane``, without advancing any lane
        policy (no feedback is consumed). Drift-weighted fleets degrade to
        a uniform split for this one rebuilt phase (the weights are
        feedback-driven); round-robin keeps its focus cursor unmoved."""
        if self._last_base is None:
            return self.initial_fleet_decision(len(self.policies))
        rr = self._rr  # a rebuild is not a phase: don't advance the focus
        self._last_weights = self._weights(self._last_base, None)
        self._rr = rr
        return self._fleet_decision(
            self._split(self._last_base, self._last_weights), None)

    # -------------------------------------------------------------- weights
    def _weights(self, base: Sequence[AllocationDecision],
                 feedbacks: Optional[Sequence[PhaseFeedback]]
                 ) -> Optional[List[float]]:
        n = len(base)
        if self.mode == "isolated":
            return None  # no re-proportioning
        if self.mode == "round-robin":
            focus = self._rr % n
            self._rr += 1
            return [1.0 if i == focus else 0.0 for i in range(n)]
        if self.mode == "drift-weighted" and feedbacks is not None:
            raw = []
            for i, (d, fb) in enumerate(zip(base, feedbacks)):
                # Drift gap: buffer-vs-fresh mismatch (fires at drift
                # onset, collapses once the buffer resets to fresh data).
                gap = max(0.0, fb.acc_valid - fb.acc_label)
                self._gaps[i] = (self.gap_ema * self._gaps[i]
                                 + (1.0 - self.gap_ema) * gap)
                # Recovery deficit: distance below the lane's own healthy
                # fresh-label accuracy — keeps budget on a drifted camera
                # through retraining, after the gap term has collapsed.
                self._acc_ema[i] = (fb.acc_label
                                    if self._acc_ema[i] is None
                                    else self.gap_ema * self._acc_ema[i]
                                    + (1.0 - self.gap_ema) * fb.acc_label)
                self._acc_best[i] = max(self._acc_best[i],
                                        self._acc_ema[i])
                deficit = max(0.0, self._acc_best[i] - fb.acc_label)
                w = self.gap_eps + self._gaps[i] + deficit
                # Engine-set drift truth (feedback.drifted); the lane's
                # reset flag is the legacy fallback — identical for the
                # DC-ST family, where resets fire exactly on drift.
                if (fb.drifted if fb.drifted is not None
                        else d.reset_buffer):
                    w *= self.drift_bias
                raw.append(w)
            total = sum(raw)
            if total <= 0.0:  # e.g. gap_eps=0 on an all-healthy fleet
                return [1.0 / n] * n
            return [w / total for w in raw]
        # uniform (and drift-weighted's first phase, before any feedback)
        return [1.0 / n] * n

    # -------------------------------------------------------------- scaling
    def _split(self, base: Sequence[AllocationDecision],
               weights: Optional[Sequence[float]]
               ) -> List[AllocationDecision]:
        if weights is None:
            return list(base)
        n = len(base)
        return [self._scale(d, w, n) for d, w in zip(base, weights)]

    def _scale(self, d: AllocationDecision, weight: float,
               n: int) -> AllocationDecision:
        share = weight * self.budget_streams
        if abs(share - 1.0) < 1e-12 and not (self.scale_epochs and n > 1):
            return d  # exact degeneracy: 1-stream fleets reuse the decision

        def q(x: float, b: int) -> int:  # quantize to a shape bucket
            return int(round(x / b)) * b

        b = self.bucket
        label_floor = max(1, int(round(self.label_floor * self.hp.n_l)))
        # Retraining heartbeat: a lane that retrains at all runs at least
        # one SGD batch. Scaling into (0, sgd_batch) would draw data and
        # refresh serving while executing zero steps, and scaling to zero
        # makes the engine report the acc_valid=1.0 sentinel — either way
        # the lane's drift detector sees noise and fires false resets.
        retrain = q(d.retrain_samples * share, b)
        if d.retrain_samples > 0:
            retrain = max(self.hp.sgd_batch, retrain)
        # Validation is detection infrastructure, not adaptation budget:
        # a retraining lane keeps its full N_v (cheap student inference)
        # so acc_valid — half of the drift signal — stays low-variance.
        valid = (d.valid_samples if retrain > 0
                 else q(d.valid_samples * share, max(1, b // 2)))
        label = max(label_floor, q(d.label_samples * share, b))
        extra = q(d.extra_label_samples * share, b)
        epochs = d.retrain_epochs
        if self.scale_epochs and retrain > 0:
            # k× the uniform share -> k× the retraining depth (>= 1 epoch).
            epochs = max(1, int(round((epochs or self.hp.epochs)
                                      * weight * n)))
        return dataclasses.replace(
            d, retrain_samples=retrain, valid_samples=valid,
            label_samples=label, extra_label_samples=extra,
            retrain_epochs=epochs)


ALLOCATORS: Dict[str, Type[AllocationPolicy]] = {
    "dacapo-spatiotemporal": SpatiotemporalAllocator,
    "dacapo-spatiotemporal-online": OnlineSpatiotemporalAllocator,
    "dacapo-spatial": SpatialAllocator,
    "dacapo-replay": ReplayAllocator,
    "ekya": EkyaAllocator,
    "eomu": EOMUAllocator,
}


def make_allocator(allocator, hp: CLHyperParams,
                   precision: PrecisionPolicy = DEFAULT_POLICY
                   ) -> AllocationPolicy:
    """Resolve a policy from a registry name, class, or ready instance."""
    if isinstance(allocator, AllocationPolicy):
        return allocator
    if isinstance(allocator, str):
        try:
            cls = ALLOCATORS[allocator]
        except KeyError:
            raise KeyError(
                f"unknown allocator {allocator!r}; "
                f"known: {sorted(ALLOCATORS)}") from None
        return cls(hp, precision)
    return allocator(hp, precision)
