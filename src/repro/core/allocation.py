"""Resource-allocation policies — Algorithm 1 and the §III baselines as data.

This module is the *decision* layer of the continuous-learning stack: an
``AllocationPolicy`` looks at per-phase feedback (validation vs. fresh-label
accuracy, the virtual clock) and emits an ``AllocationDecision`` describing
everything the engine (core/session.py) should do next — temporal sample
budgets, spatial T-SA/B-SA row split, per-kernel MX precision, and optional
fixed-window pacing. The engine executes decisions mechanically; every
behavioural difference between DaCapo-Spatiotemporal, DaCapo-Spatial, Ekya
and EOMU lives here, not in the engine loop.

Policies are constructed from hyper-parameters only and later ``bind``-ed to
a performance estimator + student config, at which point they compute their
offline spatial split (GetSpatialAllocation, Alg. 1 line 1). Because every
decision carries its own row split, a policy is free to re-allocate
spatially *online* — the paper's DC-ST does so temporally;
``OnlineSpatiotemporalAllocator`` (DC-ST-Online) exercises the spatial axis
too, shifting rows from B-SA to T-SA at drift time under a hysteresis
window and returning them as validation accuracy recovers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Type

from repro.configs.dacapo_pairs import VisionConfig
from repro.core.drift import DriftDetector
from repro.core.estimator import spatial_allocation
from repro.core.mx import DEFAULT_POLICY, PrecisionPolicy


@dataclasses.dataclass
class CLHyperParams:
    """Table I notation."""

    n_t: int = 256  # samples per retraining phase
    n_l: int = 128  # samples labeled at usual
    n_ldd_mult: int = 4  # N_ldd = 4 * N_l (paper §VI-B)
    c_b: int = 1024  # sample buffer capacity
    v_thr: float = -0.10  # drift threshold on acc_l - acc_v (tuned offline
    # per paper §VI-D; -0.05 false-positives on n_l=32..48 estimates)
    fps: float = 30.0
    epochs: int = 1
    sgd_batch: int = 16  # paper §VII-A
    lr: float = 1e-3  # paper §VII-A

    @property
    def n_v(self) -> int:  # N_v = N_t / 4 (paper §VI-B)
        return max(1, self.n_t // 4)

    @property
    def n_ldd(self) -> int:
        return self.n_ldd_mult * self.n_l


@dataclasses.dataclass(frozen=True)
class AllocationDecision:
    """One phase of work, fully described.

    The leading five fields match the legacy ``PhasePlan`` layout so old
    positional constructions keep working; the trailing fields are the richer
    spatial/precision/pacing surface this API adds.
    """

    retrain_samples: int
    valid_samples: int
    label_samples: int
    reset_buffer: bool = False
    extra_label_samples: int = 0  # N_ldd - N_l on drift (Alg. 1 line 13)
    rows_tsa: Optional[int] = None  # None -> engine's offline split
    rows_bsa: Optional[int] = None
    precisions: PrecisionPolicy = DEFAULT_POLICY
    pace_window_s: Optional[float] = None  # fixed-window grid period

    @property
    def total_label_samples(self) -> int:
        return self.label_samples + self.extra_label_samples


@dataclasses.dataclass(frozen=True)
class PhaseFeedback:
    """What the engine reports back to the policy after each phase."""

    acc_valid: float
    acc_label: float
    t: float  # virtual clock at phase end
    phase_start: float = 0.0
    retrain_time: float = 0.0
    label_time: float = 0.0


class AllocationPolicy:
    """Base policy: fixed Table-I temporal budgets, offline spatial split.

    Subclasses override :meth:`next_decision` (and optionally
    ``pace_window_s``). ``initial_plan``/``next_phase`` are deprecated
    aliases kept for the legacy scheduler API.
    """

    name = "base"
    pace_window_s: Optional[float] = None

    def __init__(self, hp: CLHyperParams,
                 precision: PrecisionPolicy = DEFAULT_POLICY):
        self.hp = hp
        self.precision = precision
        self.detector = DriftDetector(v_thr=hp.v_thr)
        self._rows: Tuple[Optional[int], Optional[int]] = (None, None)

    # -------------------------------------------------------------- binding
    def bind(self, estimator, student_cfg: VisionConfig) -> "AllocationPolicy":
        """GetSpatialAllocation (Alg. 1 line 1): compute the offline
        T-SA/B-SA split this policy's decisions will carry."""
        self._rows = spatial_allocation(estimator, student_cfg, self.hp.fps,
                                        self.precision.inference)
        return self

    @property
    def rows(self) -> Tuple[Optional[int], Optional[int]]:
        return self._rows

    # ------------------------------------------------------------ decisions
    def _decision(self, retrain_samples: int, *, reset: bool = False,
                  extra_label: int = 0) -> AllocationDecision:
        r_tsa, r_bsa = self._rows
        return AllocationDecision(
            retrain_samples=retrain_samples,
            valid_samples=self.hp.n_v,
            label_samples=self.hp.n_l,
            reset_buffer=reset,
            extra_label_samples=extra_label,
            rows_tsa=r_tsa,
            rows_bsa=r_bsa,
            precisions=self.precision,
            pace_window_s=self.pace_window_s,
        )

    def initial_decision(self) -> AllocationDecision:
        return self._decision(self.hp.n_t)

    def next_decision(self, feedback: PhaseFeedback) -> AllocationDecision:
        raise NotImplementedError

    # ------------------------------------------------- legacy scheduler API
    def initial_plan(self) -> AllocationDecision:
        return self.initial_decision()

    def next_phase(self, acc_valid: float, acc_label: float,
                   t: float) -> AllocationDecision:
        return self.next_decision(
            PhaseFeedback(acc_valid=acc_valid, acc_label=acc_label, t=t))


class SpatiotemporalAllocator(AllocationPolicy):
    """DaCapo-Spatiotemporal (DC-ST): drift-adaptive temporal allocation.

    Alg. 1 lines 11-13: on drift, reset the buffer and extend the labeling
    phase to N_ldd samples."""

    name = "dacapo-spatiotemporal"

    def next_decision(self, feedback: PhaseFeedback) -> AllocationDecision:
        drift = self.detector.check(feedback.acc_label, feedback.acc_valid,
                                    feedback.t)
        if drift:
            return self._decision(self.hp.n_t, reset=True,
                                  extra_label=self.hp.n_ldd - self.hp.n_l)
        return self._decision(self.hp.n_t)


class SpatialAllocator(SpatiotemporalAllocator):
    """DaCapo-Spatial (DC-S): static spatial split, fixed temporal
    alternation — never resets the buffer nor boosts labeling."""

    name = "dacapo-spatial"

    def next_decision(self, feedback: PhaseFeedback) -> AllocationDecision:
        self.detector.check(feedback.acc_label, feedback.acc_valid,
                            feedback.t)  # logged, unused
        return self._decision(self.hp.n_t)


class OnlineSpatiotemporalAllocator(SpatiotemporalAllocator):
    """DaCapo-Spatiotemporal-Online (DC-ST-Online): drift-reactive *online
    spatial* re-allocation on top of DC-ST's temporal boost.

    ECCO-style (PAPERS.md): when drift fires, ``boost_rows`` rows move from
    the B-SA to the T-SA so labeling the N_ldd burst and retraining on the
    fresh buffer run wider, at the cost of serving throughput (the engine's
    ``keep_frac`` drops while boosted). The boost is bounded by a
    *hysteresis window* — at least ``hysteresis_phases`` phases pass before
    rows may return — and rows are handed back once ``acc_valid`` recovers
    to its pre-drift running level (tracked as an EMA over un-boosted
    phases) within ``recover_margin``. A fresh drift while boosted re-arms
    the window.

    ``boost_rows=0`` disables re-allocation entirely, making the policy
    decision-for-decision identical to DC-ST (the golden guard in
    tests/test_pipeline.py pins that). ``boost_rows=None`` picks a default
    at ``bind`` time: a quarter of the offline B-SA rows, at least one, and
    never draining the B-SA below one row.
    """

    name = "dacapo-spatiotemporal-online"

    def __init__(self, hp: CLHyperParams,
                 precision: PrecisionPolicy = DEFAULT_POLICY,
                 boost_rows: Optional[int] = None,
                 hysteresis_phases: int = 2,
                 recover_margin: float = 0.05):
        super().__init__(hp, precision)
        self._boost_cfg = boost_rows
        self.hysteresis_phases = hysteresis_phases
        self.recover_margin = recover_margin
        self.boost_rows = 0
        self._boosted = False
        self._hold = 0
        self._acc_ema: Optional[float] = None

    def bind(self, estimator, student_cfg: VisionConfig) -> "AllocationPolicy":
        super().bind(estimator, student_cfg)
        r_tsa, r_bsa = self._rows
        if not r_tsa or not r_bsa:
            # R=0 fallback regime: one side already time-shares the whole
            # array (rows=0 means "all rows" to the engine), so shifting
            # rows would *shrink* it to a tiny exclusive slice. Disable.
            self.boost_rows = 0
            return self
        avail = max(0, r_bsa - 1)  # never drain the B-SA entirely
        want = (max(1, r_bsa // 4) if self._boost_cfg is None
                else self._boost_cfg)
        self.boost_rows = min(want, avail)
        return self

    def _current_rows(self) -> Tuple[Optional[int], Optional[int]]:
        r_tsa, r_bsa = self._rows
        if self._boosted and r_tsa is not None:
            return r_tsa + self.boost_rows, r_bsa - self.boost_rows
        return r_tsa, r_bsa

    def _decision(self, retrain_samples: int, *, reset: bool = False,
                  extra_label: int = 0) -> AllocationDecision:
        base = super()._decision(retrain_samples, reset=reset,
                                 extra_label=extra_label)
        r_tsa, r_bsa = self._current_rows()
        return dataclasses.replace(base, rows_tsa=r_tsa, rows_bsa=r_bsa)

    def next_decision(self, feedback: PhaseFeedback) -> AllocationDecision:
        drift = self.detector.check(feedback.acc_label, feedback.acc_valid,
                                    feedback.t)
        if not self._boosted and not drift:
            # Healthy-state acc_valid baseline the recovery check targets
            # (drift-phase feedback is contaminated and never enters it).
            self._acc_ema = (feedback.acc_valid if self._acc_ema is None
                             else 0.5 * self._acc_ema
                             + 0.5 * feedback.acc_valid)
        if drift and self.boost_rows > 0:
            self._boosted = True
            self._hold = self.hysteresis_phases
        elif self._boosted:
            self._hold -= 1
            recovered = (feedback.acc_valid
                         >= (self._acc_ema or 0.0) - self.recover_margin)
            if self._hold <= 0 and recovered:
                self._boosted = False
        if drift:
            return self._decision(self.hp.n_t, reset=True,
                                  extra_label=self.hp.n_ldd - self.hp.n_l)
        return self._decision(self.hp.n_t)


class EkyaAllocator(SpatiotemporalAllocator):
    """Idealized Ekya: fixed 120 s retraining window; per-window label quota
    then retraining for the rest of the window (profiling cost idealized
    away, as in the paper's baseline §III-A). Window pacing is declared on
    every decision via ``pace_window_s`` — the engine pads the virtual clock
    to the next window-grid boundary, with no Ekya-specific branch."""

    name = "ekya"
    pace_window_s = 120.0

    def next_decision(self, feedback: PhaseFeedback) -> AllocationDecision:
        return self._decision(self.hp.n_t)


class EOMUAllocator(SpatiotemporalAllocator):
    """EOMU-like: short (10 s) windows; retraining triggered by a logged
    accuracy drop, otherwise the window only labels."""

    name = "eomu"
    pace_window_s = 10.0
    drop_eps = 0.02

    def __init__(self, hp: CLHyperParams,
                 precision: PrecisionPolicy = DEFAULT_POLICY):
        super().__init__(hp, precision)
        self._last_acc: Optional[float] = None

    def next_decision(self, feedback: PhaseFeedback) -> AllocationDecision:
        self.detector.check(feedback.acc_label, feedback.acc_valid,
                            feedback.t)
        trigger = (self._last_acc is None
                   or feedback.acc_label < self._last_acc - self.drop_eps)
        self._last_acc = feedback.acc_label
        return self._decision(self.hp.n_t if trigger else 0)


ALLOCATORS: Dict[str, Type[AllocationPolicy]] = {
    "dacapo-spatiotemporal": SpatiotemporalAllocator,
    "dacapo-spatiotemporal-online": OnlineSpatiotemporalAllocator,
    "dacapo-spatial": SpatialAllocator,
    "ekya": EkyaAllocator,
    "eomu": EOMUAllocator,
}


def make_allocator(allocator, hp: CLHyperParams,
                   precision: PrecisionPolicy = DEFAULT_POLICY
                   ) -> AllocationPolicy:
    """Resolve a policy from a registry name, class, or ready instance."""
    if isinstance(allocator, AllocationPolicy):
        return allocator
    if isinstance(allocator, str):
        try:
            cls = ALLOCATORS[allocator]
        except KeyError:
            raise KeyError(
                f"unknown allocator {allocator!r}; "
                f"known: {sorted(ALLOCATORS)}") from None
        return cls(hp, precision)
    return allocator(hp, precision)
