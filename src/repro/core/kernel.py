"""The three concurrent CL kernels as first-class objects (paper Fig. 4).

Each kernel owns its model apply (jitted once per kernel), its MX precision
handling, its virtual-clock cost on the performance estimator, and — when a
multi-device mesh is available — its sub-accelerator placement from a
``SpatialPartition``:

* ``InferenceKernel``  — student, every frame, B-SA;
* ``LabelingKernel``   — teacher pseudo-labels on sampled frames, T-SA;
* ``RetrainKernel``    — student SGD on the sample buffer, T-SA.

The engine (core/session.py) never touches models or estimators directly; it
executes ``AllocationDecision``s by calling kernel methods with the rows and
precisions the decision carries. On a single device the partition binding is
a no-op and the three kernels time-share — the paper's own fallback.

Entry points come in two flavors so the dispatch layer (core/dispatch.py)
can overlap T-SA and B-SA work: the ``*_async`` methods return **device
arrays** without forcing a host sync (JAX async dispatch keeps running), and
the classic host-returning methods are thin ``np.asarray`` wrappers kept for
callers outside the hot path. ``predict_batched`` fuses several frame
windows into one jitted apply; ``label_async`` optionally microbatches large
labeling bursts so each chunk starts executing while the next is staged.
Every jitted apply invocation bumps ``n_apply_calls`` (bench/test counter).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import List, Optional, Protocol, Sequence, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.dacapo_pairs import VisionConfig
from repro.core import mx as mx_lib
from repro.core.partition import SpatialPartition


class _CacheSlot:
    """One (tree, precision) cache line.

    ``quantized`` is the RESIDENT copy — the tree with weight leaves held
    as actual MX representations (``mx_lib.MXLeaf``: int8 mantissas +
    shared exponents, ~3.5× smaller than fp32). ``value`` memoizes the
    lazily-dequantized fake-quant fp32 tree legacy ``model.apply`` callers
    consume (bit-identical to ``quantize_tree`` on the source). The
    slot's own lock serializes the fill and the lazy dequantize for THIS
    key only — the cache-wide lock is never held across either."""

    __slots__ = ("lock", "quantized", "value")

    def __init__(self):
        self.lock = threading.Lock()
        self.quantized = None
        self.value = None


class ServingParamsCache:
    """Version-keyed cache of RESIDENT quantized serving copies.

    Quantizing a serving tree — one jitted call per weight leaf — is the
    expensive step, yet between retrain steps the source tree is the same
    immutable object (JAX never mutates arrays in place; ``fit`` returns a
    fresh tree), and the teacher tree never changes at all: before this
    cache, every labeling burst re-quantized the whole teacher from
    scratch. Entries key on (source-tree identity, precision); the entry
    holds a strong reference to the source tree, pinning its ``id`` for
    the entry's lifetime, which makes identity a sound version key — a
    retrained tree is a NEW object, so its serving copy can never be
    served stale. :meth:`RetrainKernel.fit` additionally invalidates the
    tree it supersedes explicitly. ``maxsize=0`` disables caching (the
    benches' uncached baseline); eviction is LRU.

    Entries store the QUANTIZED representation (``quantize_tree_mx``), not
    a fake-quant fp32 tree: :meth:`get_quantized` hands the resident copy
    to weight-resident consumers (``ops.mx_matmul_prequant``), while
    :meth:`get` lazily dequantizes — once, memoized — for legacy apply
    paths, bit-identical to the former ``quantize_tree`` output.

    Locking: the cache-wide lock covers BOOKKEEPING ONLY (hit/miss
    counters, LRU order, slot claim/eviction) and is never held across a
    quantization. Each slot carries its own fill lock, so under
    overlapped shard stepping (``FleetManager(parallel_shards=N)``) a
    slow fill of one lane's tree no longer serializes every other lane's
    lookup; racing getters of the SAME key still produce exactly one fill
    (``fills`` counts the whole-tree quantizations actually executed).
    """

    def __init__(self, maxsize: int = 8):
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0
        self.fills = 0  # whole-tree quantizations actually executed
        self._lock = threading.RLock()
        # id(source tree) -> (source tree, {precision: _CacheSlot})
        self._entries: "OrderedDict[int, tuple]" = OrderedDict()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def _claim(self, params, precision: str) -> _CacheSlot:
        """Return the slot for (params, precision), creating and publishing
        it on a miss — bookkeeping only, constant-time under the cache
        lock. The caller fills the slot under the slot's own lock."""
        key = id(params)
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None and entry[0] is params:
                slot = entry[1].get(precision)
                if slot is not None:
                    self.hits += 1
                    self._entries.move_to_end(key)
                    return slot
            self.misses += 1
            slot = _CacheSlot()
            if self.maxsize <= 0:
                return slot  # unpublished: the uncached baseline refills
            if entry is None or entry[0] is not params:
                entry = (params, {})
                self._entries[key] = entry
            entry[1][precision] = slot
            self._entries.move_to_end(key)
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)
            return slot

    def _count_fill(self) -> None:
        with self._lock:
            self.fills += 1

    def get(self, params, precision: str, quantize=None):
        """The fake-quant fp32 serving tree for unmodified ``model.apply``
        callers. Default path: fill the resident quantized rep (once per
        key), lazily dequantize (once per key, memoized) — bit-identical
        to ``quantize_tree(params, precision)``. A custom ``quantize``
        callable stores its return value directly (test/bench hook)."""
        slot = self._claim(params, precision)
        with slot.lock:
            if slot.value is None and slot.quantized is None:
                self._count_fill()
                if quantize is not None:
                    slot.value = quantize(params, precision)
                else:
                    slot.quantized = mx_lib.quantize_tree_mx(params,
                                                             precision)
            if slot.value is None:
                slot.value = mx_lib.dequantize_tree_mx(slot.quantized)
            return slot.value

    def get_quantized(self, params, precision: str):
        """The RESIDENT copy — weight leaves as ``mx_lib.MXLeaf`` — for
        consumers that feed quantized operands straight to the kernels."""
        slot = self._claim(params, precision)
        with slot.lock:
            if slot.quantized is None:
                self._count_fill()
                slot.quantized = mx_lib.quantize_tree_mx(params, precision)
            return slot.quantized

    def invalidate(self, params=None) -> None:
        """Drop the entries of ``params`` — or everything when ``None``."""
        with self._lock:
            if params is None:
                self._entries.clear()
                return
            entry = self._entries.get(id(params))
            if entry is not None and entry[0] is params:
                del self._entries[id(params)]

    def stats(self) -> dict:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "entries": len(self._entries)}


@runtime_checkable
class Kernel(Protocol):
    """What the engine requires of a kernel."""

    name: str
    role: str  # "t_sa" | "b_sa" — which sub-accelerator it runs on

    def bind_partition(self, partition: SpatialPartition) -> None:
        """Adopt a sub-mesh placement (no-op when time-shared)."""

    def time_per_sample(self, rows: int, precision: str) -> float:
        """Virtual-clock seconds per sample at the given row count."""


class _PlacedKernel:
    """Shared placement logic: hold this kernel's sub-mesh and stage inputs
    onto its first device when a real (non-time-shared) partition is bound.

    Kernels also know how to read a resolved
    :class:`~repro.core.decision.SpatialPlan`: each kernel picks its own
    rows (by ``role``) and precision (by ``precision_field``) off the
    plane, so the engine never unpacks rows/precisions itself — the
    ``plan_*`` entry points below are the spatial-plane view of the classic
    ``time_per_sample``-style cost methods.
    """

    role = "t_sa"
    precision_field = "retraining"  # which PrecisionPolicy field this reads

    def __init__(self):
        self.submesh = None
        self._device = None
        self.n_apply_calls = 0  # jitted-dispatch counter (bench/tests)

    # --------------------------------------------------- spatial-plane view
    def plan_rows(self, spatial, role: Optional[str] = None) -> int:
        """This kernel's row count on a resolved spatial plane. ``role``
        overrides the kernel's home sub-accelerator (sequential dispatch
        charges validation inference on the T-SA chain)."""
        role = role or self.role
        return spatial.rows_bsa if role == "b_sa" else spatial.rows_tsa

    def plan_precision(self, spatial) -> str:
        return getattr(spatial.precisions, self.precision_field)

    def plan_time_per_sample(self, spatial,
                             role: Optional[str] = None) -> float:
        """Virtual-clock seconds per sample at the plane's rows/precision."""
        return self.time_per_sample(self.plan_rows(spatial, role),
                                    self.plan_precision(spatial))

    def bind_partition(self, partition: SpatialPartition) -> None:
        if partition.time_shared:
            self.submesh, self._device = None, None
            return
        self.submesh = partition.b_sa if self.role == "b_sa" else partition.t_sa
        self._device = (None if self.submesh is None
                        else self.submesh.devices.flat[0])

    def _put(self, x):
        return x if self._device is None else jax.device_put(x, self._device)

    def _run_apply(self, params, x):
        self.n_apply_calls += 1
        return self._apply(params, self._put(x))


class InferenceKernel(_PlacedKernel):
    """Student inference on the B-SA: serves every frame, scores accuracy."""

    name = "inference"
    role = "b_sa"
    precision_field = "inference"

    def __init__(self, model, full_cfg: VisionConfig, estimator,
                 apply_mx: bool):
        super().__init__()
        self.model = model
        self.full_cfg = full_cfg
        self.estimator = estimator
        self.apply_mx = apply_mx
        self._apply = jax.jit(model.apply)
        self._apply_fleet = None  # lazily-built vmapped multi-lane apply
        self.serving_cache = ServingParamsCache()

    def serving_params(self, params, precision: str):
        """UpdateWeight (Alg. 1 line 6): the serving copy at the inference
        precision; the retraining master stays fp32. Served from the
        version-keyed :class:`ServingParamsCache`, which keeps the tree
        RESIDENT in quantized form — re-requesting the serving copy of an
        unchanged tree is a hit, not a re-quantize, and the fp32 view the
        apply consumes is dequantized lazily exactly once per version."""
        if self.apply_mx:
            return self.serving_cache.get(params, precision)
        return params

    def serving_quantized(self, params, precision: str):
        """The RESIDENT quantized serving copy (weight leaves as
        ``mx_lib.MXLeaf``) — for weight-resident consumers that feed
        ``ops.mx_matmul_prequant`` directly instead of ``model.apply``."""
        if self.apply_mx:
            return self.serving_cache.get_quantized(params, precision)
        return params

    def predict_async(self, params, x) -> jax.Array:
        """Class ids as a device array — no host sync; the dispatch layer
        collects when (and if) feedback needs the values."""
        return jnp.argmax(self._run_apply(params, x), -1)

    def predict(self, params, x) -> np.ndarray:
        return np.asarray(self.predict_async(params, x))

    def predict_batched(self, params,
                        windows: Sequence[np.ndarray]) -> List[jax.Array]:
        """Fuse several frame windows into ONE jitted apply.

        The seed path issued one jitted call per score window; fusing
        concatenates the windows on the batch axis, applies once, and splits
        the predictions back per window (device-side slices, still async).
        Per-sample models (GroupNorm, no cross-batch stats) make the fused
        predictions equal to the per-window ones.
        """
        if not windows:
            return []
        if len(windows) == 1:
            return [self.predict_async(params, windows[0])]
        sizes = [len(w) for w in windows]
        fused = self.predict_async(params, np.concatenate(windows, axis=0))
        out, off = [], 0
        for size in sizes:
            out.append(fused[off: off + size])
            off += size
        return out

    def predict_fleet_async(self, params_list: Sequence,
                            windows: Sequence[np.ndarray]
                            ) -> List[jax.Array]:
        """Serve several lanes' frame windows in ONE device program — the
        B-SA mirror of :meth:`LabelingKernel.label_fleet_async`.

        Each fleet lane serves its own (quantized) student tree, so a
        single fused batch is not enough: the per-lane trees are stacked on
        a new leading axis, the windows zero-padded to the longest lane and
        stacked likewise, and one jitted ``vmap``-ped apply serves the
        whole fleet; per-lane predictions split back out as device-side
        slices (still async), pad rows dropped. A single lane takes the
        exact ``predict_async`` path. Note the vmapped apply may differ
        from per-lane applies in float ulps (different XLA lowering), which
        is why fleet batched serving is an opt-in knob — see
        ``FleetSpec.serve_batched``."""
        if not windows:
            return []
        if len(windows) == 1:
            return [self.predict_async(params_list[0], windows[0])]
        sizes = [len(w) for w in windows]
        n_max = max(sizes)
        padded = np.stack([
            w if len(w) == n_max else np.concatenate(
                [w, np.zeros((n_max - len(w),) + w.shape[1:], w.dtype)])
            for w in windows])
        stacked = jax.tree_util.tree_map(
            lambda *leaves: jnp.stack(leaves), *params_list)
        if self._apply_fleet is None:
            self._apply_fleet = jax.jit(jax.vmap(self.model.apply))
        self.n_apply_calls += 1
        logits = self._apply_fleet(stacked, self._put(padded))
        preds = jnp.argmax(logits, -1)
        return [preds[i, :size] for i, size in enumerate(sizes)]

    def time_per_sample(self, rows: int, precision: str) -> float:
        return self.estimator.forward_time(self.full_cfg, rows, precision,
                                           batch=1)

    def fps(self, rows: int, precision: str) -> float:
        return self.estimator.inference_fps(self.full_cfg, rows, precision)

    def keep_frac(self, rows: int, precision: str,
                  target_fps: float) -> float:
        """Fraction of stream frames the B-SA sustains (paper Fig. 2)."""
        return min(1.0, self.fps(rows, precision) / target_fps)

    def plan_keep_frac(self, spatial, target_fps: float) -> float:
        """Sustainable frame fraction at the spatial plane's B-SA rows and
        serving precision."""
        return self.keep_frac(spatial.rows_bsa, spatial.precisions.inference,
                              target_fps)


class LabelingKernel(_PlacedKernel):
    """Teacher pseudo-labeling on the T-SA (time-shared with retraining)."""

    name = "labeling"
    role = "t_sa"
    precision_field = "labeling"

    def __init__(self, model, full_cfg: VisionConfig, estimator,
                 apply_mx: bool):
        super().__init__()
        self.model = model
        self.full_cfg = full_cfg
        self.estimator = estimator
        self.apply_mx = apply_mx
        self._apply = jax.jit(model.apply)
        self.serving_cache = ServingParamsCache()

    def label_async(self, params, x, precision: str,
                    microbatch: Optional[int] = None) -> jax.Array:
        """Pseudo-labels as a device array (no host sync). With
        ``microbatch``, large labeling bursts (N_ldd on drift) are split into
        chunks so each starts executing on the T-SA while the next is staged
        — per-sample models make the result equal to one full-batch call.
        The teacher's serving copy comes from the version-keyed cache,
        which holds it RESIDENT in quantized form: the tree never changes,
        so every burst after the first is a hit on the already-dequantized
        view instead of a whole-tree re-quantize."""
        if self.apply_mx:
            params = self.serving_cache.get(params, precision)
        if microbatch and len(x) > microbatch:
            parts = [jnp.argmax(self._run_apply(params, x[i: i + microbatch]),
                                -1)
                     for i in range(0, len(x), microbatch)]
            return jnp.concatenate(parts)
        return jnp.argmax(self._run_apply(params, x), -1)

    def label(self, params, x, precision: str,
              microbatch: Optional[int] = None) -> np.ndarray:
        return np.asarray(self.label_async(params, x, precision, microbatch))

    def serving_quantized(self, params, precision: str):
        """The teacher's RESIDENT quantized copy (see
        :meth:`InferenceKernel.serving_quantized`)."""
        if self.apply_mx:
            return self.serving_cache.get_quantized(params, precision)
        return params

    def label_fleet_async(self, params, bursts: Sequence[np.ndarray],
                          precision: str,
                          microbatch: Optional[int] = None
                          ) -> List[jax.Array]:
        """Label several streams' bursts in ONE pass over the shared T-SA.

        The fleet's labeling work arrives as one burst per camera stream;
        issuing them separately would microbatch each burst on its own
        (``sum(ceil(n_i / mb))`` jitted calls and N tail fragments).
        Batching concatenates the bursts on the batch axis, microbatches the
        *combined* burst (``ceil(sum(n_i) / mb)`` calls — chunks freely
        cross stream boundaries), and splits the labels back per stream as
        device-side slices, still async. Per-sample models make the result
        equal to labeling each burst alone; a single-burst fleet takes the
        exact ``label_async`` path the single-stream goldens pin."""
        bursts = [b for b in bursts]
        if not bursts:
            return []
        if len(bursts) == 1:
            return [self.label_async(params, bursts[0], precision,
                                     microbatch)]
        sizes = [len(b) for b in bursts]
        fused = self.label_async(params, np.concatenate(bursts, axis=0),
                                 precision, microbatch)
        out, off = [], 0
        for size in sizes:
            out.append(fused[off: off + size])
            off += size
        return out

    def time_per_sample(self, rows: int, precision: str) -> float:
        return self.estimator.forward_time(self.full_cfg, rows, precision,
                                           batch=1)


class RetrainKernel(_PlacedKernel):
    """Student SGD-with-momentum retraining on the T-SA."""

    name = "retraining"
    role = "t_sa"
    precision_field = "retraining"

    def __init__(self, model, full_cfg: VisionConfig, estimator, hp):
        super().__init__()
        self.model = model
        self.full_cfg = full_cfg
        self.estimator = estimator
        self.hp = hp
        self._step = jax.jit(self._sgd_step)
        # Serving caches to invalidate when retraining supersedes a tree
        # (the session wires the inference kernel's cache in here).
        self.invalidates: Tuple[ServingParamsCache, ...] = ()

    def _sgd_step(self, params, opt, x, y):
        def loss_fn(p):
            logits = self.model.apply(p, x)
            logp = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_opt = jax.tree_util.tree_map(
            lambda m, g: 0.9 * m + g, opt, grads)
        new_params = jax.tree_util.tree_map(
            lambda p, m: p - self.hp.lr * m, params, new_opt)
        return new_params, new_opt, loss

    def init_state(self, params):
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def fit(self, params, opt, xt: np.ndarray, yt: np.ndarray,
            rng: np.random.Generator,
            epochs: Optional[int] = None) -> Tuple[object, object, int]:
        """Retrain (Alg. 1 line 5): epochs x minibatch SGD over D_t.
        Returns (params, opt, n_batches) — the engine charges
        n_batches * time_per_batch to the virtual clock, and n_batches is
        exactly the number of SGD steps executed (a D_t smaller than one
        SGD batch runs — and charges — zero steps). ``epochs`` overrides
        the hyper-parameter default — the knob cross-stream allocators use
        to proportion retraining depth per stream. Retraining supersedes
        the incoming tree: its cached serving copies are invalidated on
        every registered :class:`ServingParamsCache` (identity keys make
        stale hits impossible anyway — this reclaims the entries)."""
        for cache in self.invalidates:
            cache.invalidate(params)
        hp = self.hp
        n_batches = 0
        for _ in range(epochs if epochs is not None else hp.epochs):
            perm = rng.permutation(len(xt))
            for i in range(0, len(xt) - hp.sgd_batch + 1, hp.sgd_batch):
                idx = perm[i: i + hp.sgd_batch]
                params, opt, _ = self._step(params, opt, self._put(xt[idx]),
                                            self._put(yt[idx]))
                n_batches += 1
        return params, opt, n_batches

    def time_per_batch(self, rows: int, precision: str) -> float:
        return self.estimator.train_step_time(self.full_cfg, rows, precision,
                                              self.hp.sgd_batch)

    def plan_time_per_batch(self, spatial) -> float:
        """SGD-batch cost at the plane's T-SA rows/retraining precision."""
        return self.time_per_batch(spatial.rows_tsa,
                                   spatial.precisions.retraining)

    def time_per_sample(self, rows: int, precision: str) -> float:
        return self.time_per_batch(rows, precision) / self.hp.sgd_batch
