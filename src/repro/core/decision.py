"""The two-plane decision API: SpatialPlan / TemporalPlan / Decision.

DaCapo's contribution is *spatiotemporal* resource allocation, and the
decision surface mirrors that split into two composable planes:

* :class:`SpatialPlan` — where compute lives for a phase: the T-SA/B-SA
  row split on the spatially-partitioned accelerator, the per-kernel MX
  precisions, and the mesh re-fission intent (whether the engine may
  re-partition a multi-device mesh to honor the rows);
* :class:`TemporalPlan` — what the phase does with its time: sample
  budgets (retraining / validation / labeling and the N_ldd drift boost),
  buffer reset, fixed-window pacing, retraining depth, and profiling
  overhead charged to the T-SA ledger.

A frozen :class:`Decision` combines one plane of each and is what the
engines (:class:`~repro.core.session.CLSession`,
:class:`~repro.core.fleet.FleetSession`) consume; the legacy
``AllocationDecision`` (core/allocation.py) survives as a thin
bidirectional facade — ``AllocationDecision.split()`` lifts a flat legacy
decision into a :class:`Decision`, ``Decision.to_legacy()`` flattens back,
and the round trip is the identity (property-pinned in
tests/test_decision.py), so every existing policy, golden and benchmark
keeps working bit-for-bit.

Fleet decisions are first-class here too: a :class:`FleetDecision` carries
N per-lane :class:`TemporalPlan`s plus ONE fleet-wide :class:`SpatialPlan`
— the array is one, so the fleet has exactly one row split per phase —
produced by a pluggable :class:`FleetRowPolicy`:

* ``resolve-max`` — the most T-SA-hungry lane wins (``max`` of the T-SA
  requests, ``min`` of the B-SA ones): bit-identical to the pre-plane
  engine behaviour and golden-pinned against it;
* ``drift-surge`` — when a quorum of lanes drifts in the same phase, grow
  the fleet T-SA by ``surge_rows`` (bounded, never draining the B-SA) and
  hold the surge under a hysteresis window, mirroring
  ``OnlineSpatiotemporalAllocator``'s single-stream boost;
* ``weighted-vote`` — each lane votes its requested T-SA rows (plus a
  drift boost when its detector fired), and the fleet split is the
  drift-weighted average of the votes — rows follow the same temporal
  shares :class:`~repro.core.allocation.FleetAllocator` computes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Type

from repro.core.mx import DEFAULT_POLICY, PrecisionPolicy

ROLE_TSA = "t_sa"
ROLE_BSA = "b_sa"


@dataclasses.dataclass(frozen=True)
class SpatialPlan:
    """The *where* of one phase: rows, precisions, re-fission intent.

    ``rows_tsa`` / ``rows_bsa`` follow the legacy encoding: ``None`` defers
    to the engine's offline split, ``0`` means that side time-shares the
    whole array (the paper's R=0 fallback). :meth:`resolve` applies both
    conventions and returns a plan with concrete row counts.
    """

    rows_tsa: Optional[int] = None
    rows_bsa: Optional[int] = None
    precisions: PrecisionPolicy = DEFAULT_POLICY
    refission: bool = True  # may the engine re-fission the mesh for this?

    def resolve(self, default_tsa: Optional[int], default_bsa: Optional[int],
                total_rows: int) -> "SpatialPlan":
        """Concrete rows: ``None`` -> offline default, ``0`` -> whole array."""
        r_tsa = self.rows_tsa if self.rows_tsa is not None else default_tsa
        r_bsa = self.rows_bsa if self.rows_bsa is not None else default_bsa
        return dataclasses.replace(self, rows_tsa=(r_tsa or total_rows),
                                   rows_bsa=(r_bsa or total_rows))

    def rows_for(self, role: str) -> Optional[int]:
        return self.rows_bsa if role == ROLE_BSA else self.rows_tsa


@dataclasses.dataclass(frozen=True)
class TemporalPlan:
    """The *when/how-much* of one phase: budgets, pacing, depth, overhead."""

    retrain_samples: int
    valid_samples: int
    label_samples: int
    reset_buffer: bool = False
    extra_label_samples: int = 0  # N_ldd - N_l on drift (Alg. 1 line 13)
    pace_window_s: Optional[float] = None  # fixed-window grid period
    retrain_epochs: Optional[int] = None  # None -> hp.epochs
    profile_cost_s: float = 0.0  # T-SA seconds of profiling overhead

    @property
    def total_label_samples(self) -> int:
        return self.label_samples + self.extra_label_samples


@dataclasses.dataclass(frozen=True)
class Decision:
    """One phase of work as two composable planes — what engines execute."""

    spatial: SpatialPlan
    temporal: TemporalPlan

    @classmethod
    def from_legacy(cls, legacy) -> "Decision":
        """Lift a flat legacy ``AllocationDecision`` (duck-typed: anything
        with its fields) into the two planes."""
        return cls(
            spatial=SpatialPlan(rows_tsa=legacy.rows_tsa,
                                rows_bsa=legacy.rows_bsa,
                                precisions=legacy.precisions),
            temporal=TemporalPlan(
                retrain_samples=legacy.retrain_samples,
                valid_samples=legacy.valid_samples,
                label_samples=legacy.label_samples,
                reset_buffer=legacy.reset_buffer,
                extra_label_samples=legacy.extra_label_samples,
                pace_window_s=legacy.pace_window_s,
                retrain_epochs=legacy.retrain_epochs,
                profile_cost_s=legacy.profile_cost_s))

    def to_legacy(self):
        """Flatten back to the legacy facade (the exact inverse of
        ``AllocationDecision.split()`` — the round trip is the identity)."""
        from repro.core.allocation import AllocationDecision

        s, t = self.spatial, self.temporal
        return AllocationDecision(
            retrain_samples=t.retrain_samples,
            valid_samples=t.valid_samples,
            label_samples=t.label_samples,
            reset_buffer=t.reset_buffer,
            extra_label_samples=t.extra_label_samples,
            rows_tsa=s.rows_tsa,
            rows_bsa=s.rows_bsa,
            precisions=s.precisions,
            pace_window_s=t.pace_window_s,
            retrain_epochs=t.retrain_epochs,
            profile_cost_s=t.profile_cost_s)


def as_decision(decision) -> Decision:
    """Normalize a policy's output: pass a :class:`Decision` through, lift
    a legacy ``AllocationDecision`` (or any duck-typed flat decision)."""
    if isinstance(decision, Decision):
        return decision
    return Decision.from_legacy(decision)


@dataclasses.dataclass(frozen=True)
class FleetDecision:
    """One fleet phase: N per-lane temporal planes, ONE fleet spatial plane.

    ``spatial`` carries *resolved* rows (the engine executes them as-is);
    ``lane_decisions`` keeps the per-lane legacy facades so records,
    observers and the per-lane goldens stay on the exact objects the lane
    policies emitted.
    """

    spatial: SpatialPlan
    temporal: Tuple[TemporalPlan, ...]
    lane_decisions: Tuple = ()

    @property
    def n_lanes(self) -> int:
        return len(self.temporal)

    def per_lane(self) -> Tuple[Decision, ...]:
        """Per-lane :class:`Decision` views: the shared fleet spatial plane
        combined with each lane's temporal plane."""
        return tuple(Decision(spatial=self.spatial, temporal=t)
                     for t in self.temporal)


@dataclasses.dataclass(frozen=True)
class PlacementAction:
    """One lane-placement act in a manager round: an admission, a live
    migration, a fault-recovery re-home, or an admission *rejection*
    (the placement policy judged every shard oversubscribed — the camera
    is turned away rather than degrading the whole fleet). ``key`` is the
    lane's stable camera id; ``from_shard`` is ``None`` for admissions
    and rejections, ``to_shard`` is ``None`` for rejections only."""

    kind: str  # "admit" | "migrate" | "recover" | "reject"
    key: object
    to_shard: Optional[int]
    from_shard: Optional[int] = None
    reason: str = ""


@dataclasses.dataclass(frozen=True)
class ManagerDecision:
    """One manager round: :class:`FleetDecision` generalized to a
    per-shard tuple, plus the round's placement actions.

    The manager tier owns N shards (each one :class:`~repro.core.fleet
    .FleetSession` on its own sub-accelerator), and each round every live
    shard executes its own :class:`FleetDecision` — there is no
    manager-wide spatial plane because the arrays are disjoint; what the
    manager decides is *where lanes live* (``placements``, emitted by a
    pluggable :class:`~repro.core.manager.PlacementPolicy` mirroring the
    :class:`FleetRowPolicy` registry). ``shards[i]`` is ``None`` for a
    dead or drained shard.
    """

    shards: Tuple[Optional[FleetDecision], ...]
    placements: Tuple[PlacementAction, ...] = ()

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def n_lanes(self) -> int:
        return sum(d.n_lanes for d in self.shards if d is not None)


@dataclasses.dataclass(frozen=True)
class FleetRowContext:
    """What a :class:`FleetRowPolicy` may condition on, beyond the per-lane
    spatial requests: the engine-side drift flags and the drift-weighted
    temporal shares the :class:`~repro.core.allocation.FleetAllocator`
    computed for the same phase."""

    drifted: Tuple[bool, ...]
    weights: Tuple[float, ...]
    total_rows: int


class FleetRowPolicy:
    """Pluggable fleet-wide row policy: N per-lane spatial requests in, ONE
    fleet :class:`SpatialPlan` out.

    ``FleetRowPolicy("drift-surge", **kwargs)`` dispatches through the
    :data:`FLEET_ROW_POLICIES` registry (subclasses construct directly).
    Policies may be stateful across phases (hysteresis); :meth:`reset` is
    called once per fleet run.
    """

    name = "base"

    def __new__(cls, spec: Optional[str] = None, **kwargs):
        if cls is FleetRowPolicy:
            key = spec or "resolve-max"
            try:
                sub = FLEET_ROW_POLICIES[key]
            except KeyError:
                raise KeyError(
                    f"unknown fleet row policy {key!r}; "
                    f"known: {sorted(FLEET_ROW_POLICIES)}") from None
            return super().__new__(sub)
        return super().__new__(cls)

    def __init__(self, spec: Optional[str] = None, **kwargs):
        # ``spec`` is the registry key consumed by __new__; subclasses
        # accept (and ignore) it so both construction paths share one
        # signature. Unknown kwargs are rejected, not swallowed — a typo'd
        # tuning knob must not silently measure default behavior.
        del spec
        if kwargs:
            raise TypeError(
                f"{type(self).__name__} got unexpected keyword "
                f"arguments: {sorted(kwargs)}")

    def reset(self, n_lanes: int) -> None:
        """Fresh per-run state (hysteresis counters etc.)."""

    def fleet_spatial(self, spatials: Sequence[SpatialPlan],
                      ctx: FleetRowContext) -> SpatialPlan:
        raise NotImplementedError

    # ------------------------------------------------------------- helpers
    @staticmethod
    def _resolve_max(spatials: Sequence[SpatialPlan]) -> SpatialPlan:
        """The pre-plane engine rule: the most T-SA-hungry lane wins."""
        return dataclasses.replace(
            spatials[0],
            rows_tsa=max(s.rows_tsa for s in spatials),
            rows_bsa=min(s.rows_bsa for s in spatials))


class ResolveMaxRowPolicy(FleetRowPolicy):
    """``max`` of the T-SA requests, ``min`` of the B-SA ones — exactly the
    hard-coded resolution the fleet engine used before row policies were
    pluggable; golden-pinned bit-identical in tests/test_fleet.py."""

    name = "resolve-max"

    def fleet_spatial(self, spatials: Sequence[SpatialPlan],
                      ctx: FleetRowContext) -> SpatialPlan:
        return self._resolve_max(spatials)


class DriftSurgeRowPolicy(FleetRowPolicy):
    """Grow the fleet T-SA when many lanes drift *simultaneously*.

    A multi-lane drift means several N_ldd labeling bursts plus several
    buffer-refill retrains all contend for the one T-SA — exactly when
    extra T-SA rows shorten the fleet's recovery the most, and exactly when
    B-SA serving throughput is worth the least (the students are wrong
    anyway). When at least ``quorum`` of the lanes drift in one phase,
    ``surge_rows`` rows move from the B-SA to the T-SA (never draining the
    B-SA below one row); the surge holds for ``hysteresis_phases`` phases
    — a fresh quorum re-arms the window, like
    ``OnlineSpatiotemporalAllocator``'s single-stream hysteresis — and the
    rows return when the window expires with no new quorum.

    ``surge_rows=None`` defaults to a quarter of the resolved B-SA rows
    (at least one). In the time-shared regime (resolved rows don't sum to
    the array) the policy degenerates to ``resolve-max``.
    """

    name = "drift-surge"

    def __init__(self, spec: Optional[str] = None, *,
                 surge_rows: Optional[int] = None,
                 quorum: float = 0.5,
                 hysteresis_phases: int = 2):
        super().__init__(spec)
        self.surge_rows = surge_rows
        self.quorum = quorum
        self.hysteresis_phases = hysteresis_phases
        self._hold = 0

    def reset(self, n_lanes: int) -> None:
        self._hold = 0

    def fleet_spatial(self, spatials: Sequence[SpatialPlan],
                      ctx: FleetRowContext) -> SpatialPlan:
        base = self._resolve_max(spatials)
        if base.rows_tsa + base.rows_bsa != ctx.total_rows:
            return base  # R=0 / time-shared regime: nothing to shift
        n = max(1, len(ctx.drifted))
        if sum(ctx.drifted) / n >= self.quorum:
            self._hold = self.hysteresis_phases  # (re-)arm the window
        elif self._hold > 0:
            self._hold -= 1
        if self._hold <= 0:
            return base
        avail = max(0, base.rows_bsa - 1)
        want = (max(1, base.rows_bsa // 4) if self.surge_rows is None
                else self.surge_rows)
        boost = min(want, avail)
        return dataclasses.replace(base, rows_tsa=base.rows_tsa + boost,
                                   rows_bsa=base.rows_bsa - boost)


class WeightedVoteRowPolicy(FleetRowPolicy):
    """Row shares follow the drift-weighted temporal shares.

    Each lane casts a row vote from its own spatial request: a *drifted*
    lane votes retraining rows (its ``rows_tsa`` plus ``drift_boost``), a
    *healthy* lane votes serving rows (its ``rows_tsa`` minus
    ``healthy_relief`` — in an oversubscribed fleet the shared B-SA is the
    scarce resource between drifts, so a lane with nothing to learn wants
    its share of the array serving frames, exactly as its near-zero
    temporal share says). The fleet T-SA is the weight-averaged vote under
    the same normalized drift-weighted shares the ``FleetAllocator`` used
    to split the temporal budget, clamped to keep at least one row on each
    side. An all-healthy fleet therefore runs ``healthy_relief`` rows
    serving-heavier than the offline split; concentrated drift weight on
    boosted votes moves rows back (and past base) continuously, instead of
    through ``drift-surge``'s thresholded window.

    ``drift_boost=None`` defaults to an eighth of the array;
    ``healthy_relief=None`` to a quarter of the base T-SA rows (set 0 to
    pin the healthy-state split to ``resolve-max``).
    """

    name = "weighted-vote"

    def __init__(self, spec: Optional[str] = None, *,
                 drift_boost: Optional[int] = None,
                 healthy_relief: Optional[int] = None):
        super().__init__(spec)
        self.drift_boost = drift_boost
        self.healthy_relief = healthy_relief

    def fleet_spatial(self, spatials: Sequence[SpatialPlan],
                      ctx: FleetRowContext) -> SpatialPlan:
        base = self._resolve_max(spatials)
        if base.rows_tsa + base.rows_bsa != ctx.total_rows:
            return base  # time-shared regime
        boost = (max(1, ctx.total_rows // 8) if self.drift_boost is None
                 else self.drift_boost)
        relief = (max(1, base.rows_tsa // 4) if self.healthy_relief is None
                  else self.healthy_relief)
        votes = [(s.rows_tsa + boost) if d else (s.rows_tsa - relief)
                 for s, d in zip(spatials, ctx.drifted)]
        r_tsa = int(round(sum(w * v for w, v in zip(ctx.weights, votes))))
        r_tsa = max(1, min(ctx.total_rows - 1, r_tsa))
        return dataclasses.replace(base, rows_tsa=r_tsa,
                                   rows_bsa=ctx.total_rows - r_tsa)


FLEET_ROW_POLICIES: Dict[str, Type[FleetRowPolicy]] = {
    "resolve-max": ResolveMaxRowPolicy,
    "drift-surge": DriftSurgeRowPolicy,
    "weighted-vote": WeightedVoteRowPolicy,
}


def make_fleet_row_policy(policy, **kwargs) -> FleetRowPolicy:
    """Resolve a row policy from a registry name, class, or ready
    instance."""
    if isinstance(policy, FleetRowPolicy):
        return policy
    if isinstance(policy, str):
        return FleetRowPolicy(policy, **kwargs)
    return policy(**kwargs)
