"""Structural HLO analyzer: per-device FLOPs / HBM traffic / collective bytes
with while-loop trip-count multipliers.

XLA's built-in ``cost_analysis`` counts a while-loop body ONCE; with
scan-over-layers (+ microbatch scans + remat) that undercounts by the product
of trip counts (~500x for a 60-layer model). This module parses the compiled
(post-SPMD, per-device) HLO text into computations, builds the call graph
(entry -> while bodies / calls / conditionals), extracts loop trip counts
from the loop-condition compare-against-constant pattern, and accumulates:

* flops            — 2*out_elems*K for every ``dot`` (contracting dims from
                     the lhs operand shape); convolutions likewise;
* hbm traffic      — operand+output bytes of every non-fused top-level op
                     (each un-fused op boundary is an HBM materialization in
                     XLA; fusion-internal ops are free);
* collective bytes — operand bytes of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")
_SKIP_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id", "custom-call",
}


def _shapes_in(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        out.append((m.group(1), dims))
    return out


def _type_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _shapes_in(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        total += math.prod(dims) * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    out_type: str
    kind: str
    rhs: str
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    is_fusion_body: bool = False


_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-_]+)\s*\(.*\)\s*->\s*\S.*\{\s*$")
_NAME_RE = re.compile(r"^%?([\w.\-_]+)\s*=\s*")
_KIND_RE = re.compile(r"([\w\-]+)(\(.*)$")


def _balanced_prefix(s: str) -> int:
    """Index just past the balanced paren group starting at s[0] == '('."""
    depth = 0
    for i, ch in enumerate(s):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def _parse_op_line(line: str) -> Optional[Op]:
    line = line.strip()
    if line.startswith("ROOT "):
        line = line[5:]
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):  # tuple type (possibly nested)
        end = _balanced_prefix(rest)
        out_type = rest[:end]
        rest = rest[end:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        out_type = rest[:sp]
        rest = rest[sp + 1:]
    m2 = _KIND_RE.match(rest)
    if not m2:
        return None
    kind, rhs = m2.groups()
    args_end = _balanced_prefix(rhs)
    operands = []
    inner = rhs[1: args_end - 1]
    if inner.strip():
        depth = 0
        buf = ""
        parts = []
        for ch in inner:
            if ch in "({[":
                depth += 1
            elif ch in ")}]":
                depth -= 1
            if ch == "," and depth == 0:
                parts.append(buf)
                buf = ""
            else:
                buf += ch
        parts.append(buf)
        for a in parts:
            a = a.strip()
            operands.append(a.split(" ")[-1].lstrip("%"))
    return Op(name, out_type, kind, rhs, operands)


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEAD.match(line.strip())
            if m and "{" in line:
                cur = Computation(m.group(1), [])
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        op = _parse_op_line(line)
        if op is not None:
            cur.ops.append(op)
    return comps


def _op_types(comp: Computation) -> Dict[str, str]:
    return {op.name: op.out_type for op in comp.ops}


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Loop bound: the compare-against-constant in the loop condition (the
    compare may be wrapped in a fusion, so take the max integer constant
    defined in the condition computation)."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for op in cond.ops:
        if op.kind == "constant":
            m = re.match(r"\((\d+)\)", op.rhs)
            if m:
                best = max(best, int(m.group(1)))
    return best


_CALLEE_RE = {
    "while": [re.compile(r"body=%?([\w.\-_]+)")],
    "conditional": [re.compile(r"(?:true_computation|false_computation|branch_computations=\{)%?([\w.\-_]+)")],
    "call": [re.compile(r"to_apply=%?([\w.\-_]+)")],
    "fusion": [],  # fusion bodies' traffic is represented at the call site
    "reduce": [], "sort": [], "scatter": [], "map": [], "reduce-window": [],
    "select-and-scatter": [],
}


_FUSED_TRAFFIC_KINDS = {
    # TPU-fusion-aware traffic model: elementwise/reduce chains fuse into
    # producers (Pallas flash keeps the whole softmax in VMEM), so HBM
    # traffic happens only at these boundaries.
    "dot", "convolution", "fusion", "dynamic-slice", "dynamic-update-slice",
    "copy", "transpose", "gather", "scatter", "concatenate", "pad",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
}


@dataclasses.dataclass
class HLOCost:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    traffic_bytes_fused: float = 0.0
    collective_bytes: float = 0.0
    collective_ring_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_counts: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    dot_flops_by_shape: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    traffic_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    def merge_scaled(self, other: "HLOCost", k: float):
        self.flops += other.flops * k
        self.traffic_bytes += other.traffic_bytes * k
        self.traffic_bytes_fused += other.traffic_bytes_fused * k
        self.collective_bytes += other.collective_bytes * k
        self.collective_ring_bytes += other.collective_ring_bytes * k
        for kk, v in other.collective_by_kind.items():
            self.collective_by_kind[kk] = \
                self.collective_by_kind.get(kk, 0.0) + v * k
        for kk, v in other.collective_counts.items():
            self.collective_counts[kk] = \
                self.collective_counts.get(kk, 0) + int(v * k)
        for kk, v in other.dot_flops_by_shape.items():
            self.dot_flops_by_shape[kk] = \
                self.dot_flops_by_shape.get(kk, 0.0) + v * k
        for kk, v in other.traffic_by_kind.items():
            self.traffic_by_kind[kk] = \
                self.traffic_by_kind.get(kk, 0.0) + v * k


def _dot_flops(op: Op, types: Dict[str, str]) -> float:
    out_shapes = _shapes_in(op.out_type)
    if not out_shapes:
        return 0.0
    out_elems = math.prod(out_shapes[0][1]) if out_shapes[0][1] else 1
    # K = product of lhs contracting dim sizes.
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rhs)
    lhs_type = types.get(op.operands[0], "") if op.operands else ""
    lhs_shapes = _shapes_in(lhs_type)
    k = 1
    if m and m.group(1) and lhs_shapes:
        dims = lhs_shapes[0][1]
        for d in m.group(1).split(","):
            di = int(d)
            if di < len(dims):
                k *= dims[di]
    return 2.0 * out_elems * k


def _conv_flops(op: Op, types: Dict[str, str]) -> float:
    out_shapes = _shapes_in(op.out_type)
    rhs_type = types.get(op.operands[1], "") if len(op.operands) > 1 else ""
    rhs_shapes = _shapes_in(rhs_type)
    if not out_shapes or not rhs_shapes:
        return 0.0
    out_elems = math.prod(out_shapes[0][1]) if out_shapes[0][1] else 1
    m = re.search(r"dim_labels=\S*_(\S+?)->", op.rhs)
    kdims = rhs_shapes[0][1]
    feat = math.prod(kdims) / max(kdims[-1], 1) if kdims else 1
    # kernel elems / output-feature dim ~= K per output element
    return 2.0 * out_elems * feat


def _group_size(rhs: str) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", rhs)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rhs)
    if m:
        return int(m.group(2))
    m = re.search(r"source_target_pairs=", rhs)
    if m:
        return 2
    return 2


_ALIAS_KINDS = ("convert", "copy", "bitcast", "reshape", "transpose")


def _fusion_param_traffic(body: Computation) -> Tuple[Dict[int, float],
                                                      Optional[float]]:
    """(input overrides, output override) for a fusion body.

    A parameter that — following convert/copy/bitcast alias chains — is only
    ever the *source* of dynamic-slice / gather / dynamic-update-slice ops
    costs the slice bytes, not the full array. This covers both
    scan-over-layers weight slicing AND XLA:CPU's bf16->f32 legalization,
    which wraps in-place cache updates in whole-buffer convert round-trips
    that do not exist on TPU (bf16-native). If the fusion ROOT is such a DUS
    chain, the output traffic is likewise the update bytes (in-place write).
    """
    types = _op_types(body)
    param_idx: Dict[str, int] = {}
    for op in body.ops:
        if op.kind == "parameter":
            m = re.search(r"\((\d+)\)", op.rhs)
            if m:
                param_idx[op.name] = int(m.group(1))
    # Alias chains: convert(param) etc. count as the param itself; select
    # (the GSPMD sharded-DUS idiom select(in_shard?, dus(...), orig)) is a
    # pass-through over its data operands.
    origin: Dict[str, str] = {p: p for p in param_idx}
    changed = True
    while changed:
        changed = False
        for op in body.ops:
            if op.name in origin:
                continue
            if op.kind in _ALIAS_KINDS and op.operands \
                    and op.operands[0] in origin:
                origin[op.name] = origin[op.operands[0]]
                changed = True
            elif op.kind == "select" and len(op.operands) == 3:
                srcs = {origin.get(op.operands[1]), origin.get(op.operands[2])}
                srcs.discard(None)
                if len(srcs) == 1:
                    origin[op.name] = srcs.pop()
                    changed = True

    uses: Dict[str, List[Tuple[str, int]]] = {p: [] for p in param_idx}
    slice_bytes: Dict[str, float] = {p: 0.0 for p in param_idx}
    root_name = body.ops[-1].name if body.ops else None
    for op in body.ops:
        for i, o in enumerate(op.operands):
            if o not in origin:
                continue
            p = origin[o]
            if op.kind in _ALIAS_KINDS and i == 0:
                continue  # alias link, not a real use
            if op.kind == "select" and i in (1, 2):
                continue  # pass-through
            uses[p].append((op.kind, i))
            if op.kind in ("dynamic-slice", "gather") and i == 0:
                slice_bytes[p] += _type_bytes(op.out_type)
            elif op.kind == "dynamic-update-slice" and i == 0:
                upd = op.operands[1] if len(op.operands) > 1 else None
                ub = _type_bytes(types.get(upd, ""))
                if ub == 0 and upd in origin:
                    ub = 0.0
                slice_bytes[p] += ub

    overrides: Dict[int, float] = {}
    sliceable = set()
    for pname, ulist in uses.items():
        if ulist and all(
                kind in ("dynamic-slice", "gather", "dynamic-update-slice")
                and pos == 0 for kind, pos in ulist):
            overrides[param_idx[pname]] = slice_bytes[pname]
            sliceable.add(pname)
    # Output override: root is (an alias/select chain over) a DUS on a param.
    out_override = None
    by_name = {o.name: o for o in body.ops}
    if root_name is not None:
        frontier = [root_name]
        seen = set()
        while frontier:
            node = frontier.pop()
            if node in seen:
                continue
            seen.add(node)
            op = by_name.get(node)
            if op is None:
                continue
            if op.kind in _ALIAS_KINDS and op.operands:
                frontier.append(op.operands[0])
            elif op.kind == "select" and len(op.operands) == 3:
                frontier.extend(op.operands[1:])
            elif op.kind == "dynamic-update-slice" and op.operands and \
                    origin.get(op.operands[0]) in sliceable:
                upd = op.operands[1] if len(op.operands) > 1 else None
                out_override = _type_bytes(types.get(upd, "")) or None
    return overrides, out_override


def analyze_computation(comp: Computation, comps: Dict[str, Computation],
                        memo: Dict[str, HLOCost]) -> HLOCost:
    if comp.name in memo:
        return memo[comp.name]
    cost = HLOCost()
    types = _op_types(comp)
    for op in comp.ops:
        # --- recursion into called computations
        if op.kind == "while":
            body_m = re.search(r"body=%?([\w.\-]+)", op.rhs)
            cond_m = re.search(r"condition=%?([\w.\-]+)", op.rhs)
            if body_m and body_m.group(1) in comps:
                trips = _trip_count(comps, cond_m.group(1)) if cond_m else 1
                sub = analyze_computation(comps[body_m.group(1)], comps, memo)
                cost.merge_scaled(sub, trips)
            continue
        if op.kind in ("call", "conditional", "async-start"):
            for pat in (_CALLEE_RE.get(op.kind) or
                        [re.compile(r"to_apply=%?([\w.\-_]+)")]):
                for cm in pat.finditer(op.rhs):
                    if cm.group(1) in comps:
                        sub = analyze_computation(comps[cm.group(1)], comps,
                                                  memo)
                        cost.merge_scaled(sub, 1.0)
            continue
        # --- flops
        if op.kind == "dot":
            f = _dot_flops(op, types)
            cost.flops += f
            key = op.out_type
            cost.dot_flops_by_shape[key] = \
                cost.dot_flops_by_shape.get(key, 0.0) + f
        elif op.kind == "convolution":
            cost.flops += _conv_flops(op, types)
        elif op.kind == "fusion":
            # dots inside fusions (rare on TPU; CPU fuses aggressively).
            body_m = re.search(r"calls=%?([\w.\-_]+)", op.rhs)
            if body_m and body_m.group(1) in comps:
                sub = analyze_computation(comps[body_m.group(1)], comps, memo)
                cost.flops += sub.flops
                # fusion-internal collectives still count:
                cost.collective_bytes += sub.collective_bytes
                cost.collective_ring_bytes += sub.collective_ring_bytes
        # --- collectives
        for kind in COLLECTIVE_KINDS:
            if op.kind in (kind, kind + "-start"):
                operand_bytes = sum(
                    _type_bytes(types.get(o, "")) for o in op.operands
                    if o in types)
                if operand_bytes == 0.0:
                    operand_bytes = _type_bytes(op.out_type)
                    if kind == "all-gather":
                        operand_bytes /= max(_group_size(op.rhs), 1)
                ksz = _group_size(op.rhs)
                cost.collective_bytes += operand_bytes
                cost.collective_by_kind[kind] = \
                    cost.collective_by_kind.get(kind, 0.0) + operand_bytes
                cost.collective_counts[kind] = \
                    cost.collective_counts.get(kind, 0) + 1
                if kind == "all-gather":
                    ring = operand_bytes * max(ksz - 1, 1)
                elif kind == "all-reduce":
                    ring = 2.0 * operand_bytes * (ksz - 1) / max(ksz, 1)
                else:
                    ring = operand_bytes * (ksz - 1) / max(ksz, 1)
                cost.collective_ring_bytes += ring
                break
        # --- HBM traffic: materialized op boundaries
        if op.kind not in _SKIP_TRAFFIC:
            tb = _type_bytes(op.out_type)
            overrides: Dict[int, float] = {}
            if op.kind == "fusion":
                body_m = re.search(r"calls=%?([\w.\-]+)", op.rhs)
                if body_m and body_m.group(1) in comps:
                    overrides, out_over = _fusion_param_traffic(
                        comps[body_m.group(1)])
                    if out_over is not None:
                        tb = out_over
            elif op.kind in ("dynamic-slice", "gather"):
                overrides = {0: _type_bytes(op.out_type)}
            elif op.kind == "dynamic-update-slice":
                upd_bytes = _type_bytes(
                    types.get(op.operands[1], "")) if len(op.operands) > 1 \
                    else 0.0
                overrides = {0: 0.0, 1: upd_bytes}
                tb = upd_bytes  # write slice; read of update counted below
            for i, o in enumerate(op.operands):
                if o not in types:
                    continue
                tb += overrides.get(i, _type_bytes(types[o]))
            cost.traffic_bytes += tb
            cost.traffic_by_kind[op.kind] = \
                cost.traffic_by_kind.get(op.kind, 0.0) + tb
            if op.kind in _FUSED_TRAFFIC_KINDS:
                cost.traffic_bytes_fused += tb
    memo[comp.name] = cost
    return cost


def analyze_hlo_text(text: str) -> HLOCost:
    comps = parse_hlo(text)
    entry = None
    m = re.search(r"^ENTRY\s+%?([\w.\-_]+)", text, re.MULTILINE)
    if m:
        entry = m.group(1)
    if entry is None or entry not in comps:
        # fall back: the computation with the most ops
        entry = max(comps, key=lambda c: len(comps[c].ops))
    return analyze_computation(comps[entry], comps, {})
