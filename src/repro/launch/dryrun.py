import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract memory / cost / roofline analysis.

The two lines above MUST precede any other import (jax locks the device
count at first init); only this entry point ever sees 512 host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --out results.json
"""
import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro import configs  # noqa: E402
from repro.configs.base import flops_per_token, supports_shape  # noqa: E402
from repro.launch import roofline as roofline_lib  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.sharding import make_rules  # noqa: E402
from repro.launch.steps import build_bundle, lower_bundle  # noqa: E402


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, dump_hlo: str = None) -> dict:
    arch = configs.get_arch(arch_name)
    shape = configs.get_shape(shape_name)
    if not supports_shape(arch, shape):
        return {"arch": arch_name, "shape": shape_name,
                "mesh": "multipod" if multi_pod else "pod",
                "status": "skipped",
                "reason": "long_500k needs sub-quadratic attention "
                          "(DESIGN.md §4)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rules = make_rules(arch, shape, mesh)
    t0 = time.time()
    try:
        bundle = build_bundle(arch, shape, mesh, rules)
        lowered = lower_bundle(bundle, mesh, rules)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        if dump_hlo:
            with open(dump_hlo, "w") as f:
                f.write(hlo)
        rf = roofline_lib.analyze(compiled, hlo, chips)
        n_tokens = shape.global_batch * (
            shape.seq_len if shape.kind == "train" else
            shape.seq_len if shape.kind == "prefill" else 1)
        model_flops = flops_per_token(arch, shape.kind == "train") * n_tokens
        result = {
            "arch": arch_name, "shape": shape_name,
            "mesh": "multipod" if multi_pod else "pod",
            "status": "ok",
            "chips": chips,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "bytes_per_device": getattr(
                mem, "temp_size_in_bytes", 0) + getattr(
                mem, "argument_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "arg_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
            "roofline": rf.to_dict(),
            "model_flops": model_flops,
            # rf.flops is per-device (post-SPMD HLO): useful fraction of the
            # total compiled compute across the mesh.
            "useful_flops_ratio": (model_flops / (rf.flops * chips))
            if rf.flops else 0,
        }
        if verbose:
            print(f"[{arch_name} x {shape_name} x "
                  f"{'multipod' if multi_pod else 'pod'}] OK "
                  f"compile={t_compile:.0f}s "
                  f"mem/dev={result['bytes_per_device']/2**30:.2f}GiB "
                  f"bottleneck={rf.bottleneck} "
                  f"t=({rf.t_compute*1e3:.1f}, {rf.t_memory*1e3:.1f}, "
                  f"{rf.t_collective*1e3:.1f})ms "
                  f"useful={result['useful_flops_ratio']:.2f}",
                  flush=True)
        return result
    except Exception as e:  # noqa: BLE001
        if verbose:
            traceback.print_exc()
            print(f"[{arch_name} x {shape_name}] FAIL {e}", flush=True)
        return {"arch": arch_name, "shape": shape_name,
                "mesh": "multipod" if multi_pod else "pod",
                "status": "fail", "error": str(e)[:2000]}


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--out", default=None)
    p.add_argument("--dump-hlo", default=None)
    args = p.parse_args(argv)

    cells = []
    if args.all:
        for arch, shape, ok in configs.all_cells(include_skipped=True):
            cells.append((arch.name, shape.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    results = []
    for mp in meshes:
        for arch_name, shape_name in cells:
            results.append(run_cell(arch_name, shape_name, mp,
                                    dump_hlo=args.dump_hlo))
            if args.out:  # incremental flush: a crash loses nothing
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "fail" for r in results)
    print(f"dry-run: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
