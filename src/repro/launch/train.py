"""End-to-end LM training driver (example application (b) driver).

Runs any assigned arch (full or --reduced) on the host mesh with the full
substrate: sharded params, microbatched grads, checkpointing, fault-tolerant
resilient loop, drift-free token pipeline.

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --reduced \
      --steps 200 --batch 32 --seq 256
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.configs.base import ShapeConfig
from repro.data.tokens import TokenPipeline
from repro.distributed import init_params, use_rules
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import make_rules
from repro.launch.steps import build_train_bundle
from repro.runtime.fault import Heartbeat, StragglerDetector
from repro.training.optimizer import OptimizerConfig
from repro.training.train_state import TrainState


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="xlstm-125m")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    p.add_argument("--checkpoint-every", type=int, default=100)
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--model-parallel", type=int, default=1)
    args = p.parse_args(argv)

    arch = configs.get_arch(args.arch)
    if args.reduced:
        arch = arch.reduced()
    arch = dataclasses.replace(arch, dtype="float32")
    shape = ShapeConfig("custom_train", args.seq, args.batch, "train")
    mesh = make_host_mesh(model_parallel=args.model_parallel)
    rules = make_rules(arch, shape, mesh)
    opt_cfg = OptimizerConfig(name="adamw", lr=args.lr, warmup_steps=20,
                              total_steps=args.steps)
    bundle = build_train_bundle(arch, shape, mesh, rules, opt_cfg=opt_cfg,
                                num_microbatches=1)
    from repro.models.transformer import LMModel

    model = LMModel(arch)
    ckpt = CheckpointManager(args.checkpoint_dir, max_to_keep=2)
    pipe = TokenPipeline(arch.vocab_size, args.seq, args.batch, seed=0)

    with mesh, use_rules(rules, mesh):
        params = init_params(model.param_defs(), jax.random.PRNGKey(0))
        state = TrainState.create(params, opt_cfg)
        step_fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                          out_shardings=bundle.out_shardings, donate_argnums=0)
        hb, sd = Heartbeat(), StragglerDetector()
        t0 = time.time()
        for step in range(args.steps):
            batch = pipe.batch(step)
            if arch.input_mode == "embeddings":
                rng = np.random.default_rng(step)
                batch["inputs"] = rng.normal(size=(
                    args.batch, args.seq, arch.d_model)).astype(np.float32)
            state, metrics = step_fn(state, batch)
            dur = hb.beat()
            sd.observe(step, dur, hb.median())
            if step % args.log_every == 0 or step == args.steps - 1:
                m = jax.device_get(metrics)
                print(f"step {step:5d} loss {float(m['loss']):7.4f} "
                      f"acc {float(m['accuracy']):5.3f} "
                      f"lr {float(m['lr']):.2e} "
                      f"({dur*1e3:6.1f} ms/step)", flush=True)
            if (step + 1) % args.checkpoint_every == 0:
                ckpt.save(step + 1, state, blocking=False)
        ckpt.wait()
        elapsed = time.time() - t0
        toks = args.steps * args.batch * args.seq
        print(f"done: {toks/elapsed:,.0f} tok/s, stragglers: "
              f"{len(sd.events)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
