"""Serving driver: prefill + batched autoregressive decode on the host mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import ShapeConfig
from repro.distributed import init_params, use_rules
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import make_rules
from repro.models.transformer import LMModel


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="yi-6b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--model-parallel", type=int, default=1)
    args = p.parse_args(argv)

    arch = configs.get_arch(args.arch)
    if args.reduced:
        arch = arch.reduced()
    arch = dataclasses.replace(arch, dtype="float32")
    capacity = args.prompt_len + args.gen
    shape = ShapeConfig("serve", capacity, args.batch, "decode")
    mesh = make_host_mesh(model_parallel=args.model_parallel)
    rules = make_rules(arch, shape, mesh)
    model = LMModel(arch)

    rng = np.random.default_rng(0)
    if arch.input_mode == "embeddings":
        prompts = rng.normal(size=(args.batch, args.prompt_len,
                                   arch.d_model)).astype(np.float32)
    else:
        prompts = rng.integers(0, arch.vocab_size,
                               size=(args.batch, args.prompt_len))
        prompts = prompts.astype(np.int32)

    with mesh, use_rules(rules, mesh):
        params = init_params(model.param_defs(), jax.random.PRNGKey(0))
        prefill = jax.jit(lambda p, x: model.prefill(
            p, x, cache_capacity=capacity))
        decode = jax.jit(model.decode_step)
        t0 = time.time()
        logits, caches = prefill(params, prompts)
        logits = jax.block_until_ready(logits)
        t_prefill = time.time() - t0
        toks = jnp.argmax(logits, -1)
        generated = [np.asarray(toks)]
        t0 = time.time()
        for i in range(args.gen - 1):
            t = jnp.asarray(args.prompt_len + i, jnp.int32)
            if arch.input_mode == "embeddings":
                step_in = jnp.asarray(rng.normal(size=(
                    args.batch, 1, arch.d_model)), jnp.float32)
            else:
                step_in = toks.reshape(args.batch, 1)
            logits, caches = decode(params, step_in, t, caches)
            if logits.ndim == 3:  # multi-head outputs: take head 0
                logits = logits[:, 0]
            toks = jnp.argmax(logits, -1)
            generated.append(np.asarray(toks))
        jax.block_until_ready(logits)
        t_decode = time.time() - t0
    gen = np.stack(generated, 1)
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f} ms")
    print(f"decode:  {args.gen - 1} steps x {args.batch} seqs in "
          f"{t_decode*1e3:.1f} ms "
          f"({(args.gen - 1) * args.batch / max(t_decode, 1e-9):,.0f} tok/s)")
    print("sample tokens:", gen[0, :16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
