"""Step builders: train_step / prefill_step / serve_step per (arch, shape).

``abstract_inputs``/``abstract_state`` produce ShapeDtypeStruct stand-ins
(weak-type-correct, shardable, zero allocation) for the dry-run; the same
builders drive real training in launch/train.py on host meshes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed import (
    ShardingRules,
    param_shapes,
    param_specs,
    use_rules,
)
from repro.models.transformer import LMModel
from repro.training.grad import microbatched_grads
from repro.training.optimizer import OptimizerConfig, apply_updates
from repro.training.train_state import TrainState

DEFAULT_MICROBATCHES = {"train": 16}


@dataclasses.dataclass(frozen=True)
class StepBundle:
    """Everything needed to lower one (arch x shape) cell."""

    fn: Any  # the jittable step function
    in_shardings: Any
    out_shardings: Any
    abstract_args: Tuple  # ShapeDtypeStructs matching fn's signature
    donate_argnums: Tuple = ()  # train: state; decode: caches (in-place)


def _sharding_tree(spec_tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _shape_of(defs):
    return param_shapes(defs)


# ------------------------------------------------------------------- inputs
def input_specs(arch: ArchConfig, shape: ShapeConfig,
                rules: ShardingRules) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step inputs + their specs."""
    b, s = shape.global_batch, shape.seq_len
    batch_axes = rules.get("batch")
    if shape.kind == "train":
        if arch.input_mode == "embeddings":
            inputs = jax.ShapeDtypeStruct((b, s, arch.d_model), jnp.bfloat16)
            in_spec = P(batch_axes, None, None)
        else:
            inputs = jax.ShapeDtypeStruct((b, s), jnp.int32)
            in_spec = P(batch_axes, None)
        if arch.num_output_heads > 1:
            labels = jax.ShapeDtypeStruct((b, s, arch.num_output_heads),
                                          jnp.int32)
            lbl_spec = P(batch_axes, None, None)
        else:
            labels = jax.ShapeDtypeStruct((b, s), jnp.int32)
            lbl_spec = P(batch_axes, None)
        return {"batch": {"inputs": inputs, "labels": labels},
                "specs": {"inputs": in_spec, "labels": lbl_spec}}
    if shape.kind == "prefill":
        if arch.input_mode == "embeddings":
            inputs = jax.ShapeDtypeStruct((b, s, arch.d_model), jnp.bfloat16)
            in_spec = P(batch_axes, None, None)
        else:
            inputs = jax.ShapeDtypeStruct((b, s), jnp.int32)
            in_spec = P(batch_axes, None)
        return {"batch": {"inputs": inputs}, "specs": {"inputs": in_spec}}
    # decode: one new token against a cache of seq_len.
    if arch.input_mode == "embeddings":
        inputs = jax.ShapeDtypeStruct((b, 1, arch.d_model), jnp.bfloat16)
        in_spec = P(batch_axes, None, None)
    else:
        inputs = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        in_spec = P(batch_axes, None)
    t = jax.ShapeDtypeStruct((), jnp.int32)
    return {"batch": {"inputs": inputs, "t": t},
            "specs": {"inputs": in_spec, "t": P()}}


# -------------------------------------------------------------------- train
def build_train_bundle(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                       rules: ShardingRules,
                       opt_cfg: Optional[OptimizerConfig] = None,
                       num_microbatches: Optional[int] = None,
                       zero2_gather: bool = False) -> StepBundle:
    # zero2_gather measured NEGATIVE on this workload (EXPERIMENTS.md §Perf
    # B-H2): collective -1%, memory 2x — kept as an option, off by default.
    from repro.distributed import mesh_axis_size

    model = LMModel(arch)
    opt_cfg = opt_cfg or OptimizerConfig(name="adamw", lr=3e-4)
    if num_microbatches is None:
        # >100B models need small microbatches to fit gathered weights.
        num_microbatches = 16 if arch.param_count() > 8e10 \
            else DEFAULT_MICROBATCHES["train"] // 2
    nmb = num_microbatches
    dp = mesh_axis_size(mesh, rules.get("batch"))
    nmb = max(1, min(nmb, shape.global_batch // max(dp, 1)))
    while shape.global_batch % nmb:
        nmb -= 1

    # ZeRO-2: gather FSDP-sharded weights ONCE per step (not per microbatch
    # per direction) and reduce-scatter grads into sharded accumulators.
    gather_rules = ShardingRules(rules)
    gather_rules["embed"] = None
    gather_rules["expert_in"] = None

    def train_step(state: TrainState, batch):
        with use_rules(rules, mesh):
            defs_in = model.param_defs()
            fsdp_shardings = _sharding_tree(param_specs(defs_in), mesh)
        with use_rules(gather_rules, mesh):
            gathered_shardings = _sharding_tree(param_specs(defs_in), mesh)

        if zero2_gather and nmb > 1:
            params_g = jax.tree_util.tree_map(
                jax.lax.with_sharding_constraint, state.params,
                gathered_shardings)
            constrain_grads = lambda g: jax.tree_util.tree_map(
                jax.lax.with_sharding_constraint, g, fsdp_shardings)
        else:
            params_g = state.params
            constrain_grads = None

        def loss_fn(p, b):
            return model.loss(p, b)

        loss, metrics, grads = microbatched_grads(
            loss_fn, params_g, batch, nmb, constrain_grads=constrain_grads)
        params, opt, om = apply_updates(
            state.params, grads, state.opt_state, state.step, opt_cfg)
        return (TrainState(params, opt, state.step + 1),
                {**metrics, **om})

    with use_rules(rules, mesh):
        defs = model.param_defs()
        p_specs = param_specs(defs)
        state_specs = TrainState(
            params=p_specs,
            opt_state={"mu": p_specs, "nu": p_specs},
            step=P())
        p_shapes = _shape_of(defs)
        opt_shapes = jax.tree_util.tree_map(
            lambda sd: jax.ShapeDtypeStruct(sd.shape, jnp.float32), p_shapes)
        state_shapes = TrainState(
            params=p_shapes,
            opt_state={"mu": opt_shapes, "nu": opt_shapes},
            step=jax.ShapeDtypeStruct((), jnp.int32))
        io = input_specs(arch, shape, rules)

    state_sh = _sharding_tree(state_specs, mesh)
    batch_sh = _sharding_tree(io["specs"], mesh)
    metrics_sh = NamedSharding(mesh, P())
    return StepBundle(
        fn=train_step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        abstract_args=(state_shapes, io["batch"]),
        donate_argnums=(0,),
    )


# ------------------------------------------------------------------ prefill
def build_prefill_bundle(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                         rules: ShardingRules) -> StepBundle:
    model = LMModel(arch)

    def prefill_step(params, batch):
        logits, caches = model.prefill(params, batch["inputs"],
                                       cache_capacity=shape.seq_len)
        return logits, caches

    with use_rules(rules, mesh):
        defs = model.param_defs()
        p_specs = param_specs(defs)
        p_shapes = _shape_of(defs)
        cache_defs = model.cache_defs(shape.global_batch, shape.seq_len)
        cache_specs = param_specs(cache_defs)
        io = input_specs(arch, shape, rules)

    return StepBundle(
        fn=prefill_step,
        in_shardings=(_sharding_tree(p_specs, mesh),
                      _sharding_tree(io["specs"], mesh)),
        out_shardings=(None, _sharding_tree(cache_specs, mesh)),
        abstract_args=(p_shapes, io["batch"]),
    )


# ------------------------------------------------------------------- decode
def build_decode_bundle(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                        rules: ShardingRules) -> StepBundle:
    model = LMModel(arch)

    def serve_step(params, caches, batch):
        logits, new_caches = model.decode_step(
            params, batch["inputs"], batch["t"], caches)
        return logits, new_caches

    with use_rules(rules, mesh):
        defs = model.param_defs()
        p_specs = param_specs(defs)
        p_shapes = _shape_of(defs)
        cache_defs = model.cache_defs(shape.global_batch, shape.seq_len)
        cache_specs = param_specs(cache_defs)
        cache_shapes = _shape_of(cache_defs)
        io = input_specs(arch, shape, rules)

    cache_sh = _sharding_tree(cache_specs, mesh)
    return StepBundle(
        fn=serve_step,
        in_shardings=(_sharding_tree(p_specs, mesh), cache_sh,
                      _sharding_tree(io["specs"], mesh)),
        out_shardings=(None, cache_sh),
        abstract_args=(p_shapes, cache_shapes, io["batch"]),
        donate_argnums=(1,),
    )


def build_bundle(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                 rules: ShardingRules, **kw) -> StepBundle:
    if shape.kind == "train":
        return build_train_bundle(arch, shape, mesh, rules, **kw)
    if shape.kind == "prefill":
        return build_prefill_bundle(arch, shape, mesh, rules)
    return build_decode_bundle(arch, shape, mesh, rules)


def lower_bundle(bundle: StepBundle, mesh: Mesh, rules: ShardingRules):
    """jit + lower under the mesh/rules context (dry-run entry point)."""
    fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                 out_shardings=bundle.out_shardings,
                 donate_argnums=bundle.donate_argnums)
    with mesh, use_rules(rules, mesh):
        lowered = fn.lower(*bundle.abstract_args)
    return lowered
