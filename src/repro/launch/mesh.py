"""Production meshes.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state. Single pod: 16x16 = 256
chips ("data", "model"); multi-pod: 2x16x16 = 512 chips ("pod", "data",
"model") — the pod axis is pure DP and only gradient all-reduce (optionally
int8-compressed, training/grad.py) crosses the slow inter-pod links.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1) -> Mesh:
    """Mesh over whatever devices exist (tests / quickstart)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def describe(mesh: Mesh) -> str:
    return f"mesh{dict(zip(mesh.axis_names, mesh.devices.shape))}"
