"""Render dryrun_results.json into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import argparse
import json


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def render(results, mesh="pod"):
    rows = []
    header = ("| arch | shape | status | mem/dev GiB | t_comp ms | t_mem ms "
              "| t_coll ms | bottleneck | useful |")
    sep = "|" + "---|" * 9
    rows.append(header)
    rows.append(sep)
    for r in results:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP (full attn) "
                        "| - | - | - | - | - | - |")
            continue
        if r["status"] == "fail":
            rows.append(f"| {r['arch']} | {r['shape']} | FAIL | - | - | - "
                        f"| - | - | - |")
            continue
        rf = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {fmt_bytes(r['bytes_per_device'])} "
            f"| {rf['t_compute']*1e3:.1f} | {rf['t_memory']*1e3:.1f} "
            f"| {rf['t_collective']*1e3:.1f} | {rf['bottleneck']} "
            f"| {r['useful_flops_ratio']:.2f} |")
    return "\n".join(rows)


def summarize(results):
    ok = [r for r in results if r["status"] == "ok"]
    fail = [r for r in results if r["status"] == "fail"]
    skip = [r for r in results if r["status"] == "skipped"]
    lines = [f"{len(ok)} ok / {len(skip)} skipped / {len(fail)} failed"]
    for r in fail:
        lines.append(f"  FAIL {r['arch']} x {r['shape']} x {r['mesh']}: "
                     f"{r.get('error', '')[:200]}")
    return "\n".join(lines)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("results")
    ap.add_argument("--mesh", default="pod")
    args = ap.parse_args()
    with open(args.results) as f:
        results = json.load(f)
    print(summarize(results))
    print()
    print(render(results, args.mesh))
