"""Logical->mesh sharding rules per (arch x shape-kind x mesh).

Scheme (DESIGN.md §5):
  * train   — DP over ("pod","data"), FSDP(ZeRO-3) weight sharding over
    "data", megatron TP over "model"; MoE expert-parallel over "data".
  * prefill — batch over "data", TP over "model"; weights replicated over
    "data" (except experts) for latency; seq-parallel attention (shard_map)
    for archs whose head count doesn't divide the model axis.
  * decode  — batch over "data"; KV caches SEQUENCE-sharded over "model"
    (flash-decode combine); long_500k shards KV seq over ("data","model").
"""
from __future__ import annotations

from typing import Optional

from jax.sharding import Mesh

from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed import ShardingRules, mesh_axis_size


def heads_divisible(arch: ArchConfig, mesh: Optional[Mesh]) -> bool:
    tp = mesh_axis_size(mesh, "model") if mesh else 1
    return arch.num_heads % tp == 0


def make_rules(arch: ArchConfig, shape: ShapeConfig,
               mesh: Optional[Mesh]) -> ShardingRules:
    if mesh is None:
        return ShardingRules()
    multi_pod = "pod" in mesh.axis_names
    dp = ("pod", "data") if multi_pod else ("data",)
    head_mode = heads_divisible(arch, mesh)

    rules = ShardingRules({
        # Weights.
        "ff": "model",
        "ff2": "model",
        "vocab": "model",
        "expert": "model",  # EP over the tensor axis (batch stays on data)
        "expert_in": "data",  # expert d_model dim FSDP-sharded
        "expert_ff": None,
        "kv_heads": None,  # kv heads replicated across TP (GQA < tp)
        "heads": "model" if head_mode else None,
        "heads_fused": "model",  # fused h*dh always divides the TP axis
        "kv_fused": "model",
        "head_dim": None,
        "layers": None,
        # Activations.
        "act_batch": dp,
        "act_embed": None,
        "act_seq": None,
        # KV cache.
        "kv_batch": "data",
        "kv_seq": "model",
    })

    if shape.kind == "train":
        rules["embed"] = "data"  # FSDP / ZeRO-3 over the data axis
        rules["batch"] = dp
        if not head_mode:
            # Sequence-parallel attention (shard_map over model).
            rules["attn_seq"] = "model"
    else:
        # Serving: replicate non-expert weights over data for latency
        # (experts stay EP over data — too large to replicate).
        rules["embed"] = None
        rules["batch"] = ("data",)
        if not head_mode and shape.kind == "prefill":
            rules["attn_seq"] = "model"

    if shape.kind == "decode":
        if shape.global_batch < mesh_axis_size(mesh, "data"):
            # long_500k: batch of 1 — shard the KV sequence over everything.
            rules["kv_batch"] = None
            rules["act_batch"] = None
            rules["batch"] = None
            rules["kv_seq"] = ("data", "model")
    return rules
