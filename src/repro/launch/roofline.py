"""Roofline-term extraction from compiled dry-run artifacts.

compute    = HLO_FLOPs / (chips x 197 TFLOP/s)
memory     = HLO_bytes / (chips x 819 GB/s)
collective = collective_bytes / (chips x 50 GB/s)   [spec formula]

collective_bytes is parsed from HLO text: the summed operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
A refined per-op ring estimate (bytes x (k-1)/k with k = replica-group size)
is reported alongside.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional

from repro.core.estimator import TPU_HBM_BW, TPU_ICI_BW, TPU_PEAK_FLOPS

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _parse_type_bytes(type_str: str) -> float:
    """'f32[16,128]' or tuple '(f32[2], s32[4])' -> total bytes."""
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    op_bytes: Dict[str, float]
    op_counts: Dict[str, int]
    total_bytes: float
    ring_bytes: float  # refined: x (k-1)/k per op

    def to_dict(self):
        return dataclasses.asdict(self)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    # Pass 1: map %name -> output type string (first token after '=').
    def_types: Dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        rhs = m.group(2)
        tm = _SHAPE_RE.search(rhs.split(" ")[0]) or _SHAPE_RE.search(rhs)
        if tm:
            # capture full leading type expression (may be a tuple)
            paren = rhs.split("=")[0]
            def_types[m.group(1)] = rhs.split(") ")[0] if rhs.startswith("(") \
                else rhs.split(" ")[0]

    op_bytes: Dict[str, float] = {k: 0.0 for k in COLLECTIVE_KINDS}
    op_counts: Dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    ring_bytes = 0.0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _DEF_RE.match(stripped)
        if not m:
            continue
        rhs = m.group(2)
        kind = None
        for k in COLLECTIVE_KINDS:
            if re.search(rf"\b{k}(-start|-done)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        if re.search(rf"\b{kind}-done\(", rhs):
            continue  # avoid double counting start/done pairs
        # Operand bytes: resolve %operand names to their defined types.
        args_m = re.search(rf"{kind}(?:-start)?\(([^)]*)\)", rhs)
        operand_bytes = 0.0
        if args_m:
            for arg in args_m.group(1).split(","):
                arg = arg.strip().lstrip("%")
                if arg in def_types:
                    operand_bytes += _parse_type_bytes(def_types[arg])
        if operand_bytes == 0.0:
            # Fallback: use this op's own output type.
            operand_bytes = _parse_type_bytes(rhs.split(" ")[0])
        # Group size from replica_groups (k devices participating).
        k_size = _group_size(rhs)
        op_bytes[kind] += operand_bytes
        op_counts[kind] += 1
        factor = 2.0 if kind == "all-reduce" else 1.0
        if kind == "all-gather":
            operand_ring = operand_bytes * max(k_size - 1, 1)
        else:
            operand_ring = operand_bytes * factor * (k_size - 1) / max(k_size, 1)
        ring_bytes += operand_ring
    total = sum(op_bytes.values())
    return CollectiveStats(op_bytes, op_counts, total, ring_bytes)


def _group_size(rhs: str) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", rhs)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rhs)
    if m:  # iota format [num_groups, group_size]
        return int(m.group(2))
    return 2


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    ring_bytes: float
    chips: int
    peak_flops: float = TPU_PEAK_FLOPS
    hbm_bw: float = TPU_HBM_BW
    ici_bw: float = TPU_ICI_BW
    collective_detail: Optional[dict] = None

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * self.peak_flops)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * self.hbm_bw)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.chips * self.ici_bw)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_total(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def to_dict(self):
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "ring_bytes": self.ring_bytes, "chips": self.chips,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "collective_detail": self.collective_detail,
        }


def analyze(compiled, hlo_text: str, chips: int) -> Roofline:
    """Roofline terms from the structural analyzer (hlo_analysis): the
    compiled HLO is the per-device (post-SPMD) program, so flops/bytes are
    per-chip directly and the 'chips x' denominators below see chips=1.
    XLA's own cost_analysis is NOT used — it counts while bodies once
    (~500x undercount with scan-over-layers)."""
    from repro.launch import hlo_analysis

    cost = hlo_analysis.analyze_hlo_text(hlo_text)
    rf = Roofline(
        flops=cost.flops, hbm_bytes=cost.traffic_bytes_fused,
        collective_bytes=cost.collective_bytes,
        ring_bytes=cost.collective_ring_bytes,
        chips=1)
    rf.collective_detail = {
        "by_kind": cost.collective_by_kind,
        "counts": cost.collective_counts,
        "hbm_bytes_unfused": cost.traffic_bytes,
    }
    return rf
