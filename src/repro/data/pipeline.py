"""FramePipeline — the session's data plane, with speculative prefetch.

The engine (core/session.py) cannot prefetch its frame windows the way the
dispatch bench does, because window bounds depend on the virtual clock: the
span a score or labeling window covers is only known once the phase's kernel
costs have been charged. This module closes that gap with *speculation*: the
pipeline records the frame requests of each phase as offsets from the phase
start, and when the next phase opens it replays that trace — last phase's
layout as the predicted next-window bounds — synthesizing the predicted
windows on a background thread while the device executes. At each request
the pipeline *reconciles*: a prediction that matches serves its prefetched
frames (a **speculation hit** — host synthesis overlapped device dispatch);
anything else is synthesized inline exactly as before and recorded as a
**speculation miss**. Mispredictions can therefore never change results,
only forfeit overlap.

Bit-identity of hits is structural, not probabilistic: a frame of
:class:`~repro.data.stream.DriftStream` depends on its timestamp only
through ``round(t, 4)`` (the per-frame hash input) and its segment index, so
a predicted window is declared a hit **only if** every predicted timestamp
agrees with the requested one on both — in which case the prefetched arrays
are bit-identical to what inline slicing would synthesize. This also makes
the matcher robust to the float-accumulation jitter inherent in replaying
clock offsets from a different phase start (an ulp of drift almost never
moves the 4-decimal rounding, and when it does, the result is a miss, never
a wrong frame).

``FramePipeline`` is the only frame source the session loop touches; the
dispatch layer binds it into each :class:`~repro.core.dispatch.PhasePlan`
(``plan.fetch``) so concurrent dispatch issues device programs against
prefetched, host-ready windows.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.data.stream import DriftStream

# A window key: one (rounded-time, segment-index) pair per frame.
_WindowKey = Tuple[Tuple[str, int], ...]


def _window_key(stream: DriftStream, t0: float, t1: float,
                max_frames: int) -> _WindowKey:
    """Identity of the frames a request renders, without synthesizing them."""
    times = stream.frame_times(t0, t1, max_frames)
    return tuple((f"{float(t):.4f}", stream.segment_index(float(t)))
                 for t in times)


class _SpecWindow:
    """One predicted window: spec + synthesis rendezvous."""

    __slots__ = ("t0", "t1", "max_frames", "key", "ready", "x", "y",
                 "consumed")

    def __init__(self, t0: float, t1: float, max_frames: int,
                 key: _WindowKey):
        self.t0, self.t1, self.max_frames = t0, t1, max_frames
        self.key = key
        self.ready = threading.Event()
        self.x: Optional[np.ndarray] = None
        self.y: Optional[np.ndarray] = None
        self.consumed = False


class _SpecBatch:
    """The predictions for one phase, synthesized in request order."""

    __slots__ = ("windows", "index", "cancelled")

    def __init__(self, windows: List[_SpecWindow]):
        self.windows = windows
        self.index: Dict[_WindowKey, _SpecWindow] = {}
        for w in windows:
            self.index.setdefault(w.key, w)
        self.cancelled = False


@dataclasses.dataclass
class SpeculationStats:
    """Cumulative speculation counters (see ``FramePipeline.stats``)."""

    hits: int = 0
    misses: int = 0
    windows_speculated: int = 0
    windows_wasted: int = 0  # predicted but never consumed
    windows_hinted: int = 0  # pre-sized by a decision-aware label hint
    phases: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class FramePipeline:
    """Single data plane over a :class:`DriftStream` with speculative
    per-phase window prefetch.

    ``frames(t0, t1, max_frames)`` is a drop-in for ``stream.frames`` — same
    signature, bit-identical output — that additionally serves matching
    speculated windows from the background worker. ``begin_phase(start)``
    (called by the dispatch layer when a phase plan opens) rotates the
    request trace: the finished phase's trace, rebased onto the new phase
    start, becomes the speculation for the phase now beginning.

    With ``speculative=False`` the pipeline degenerates to transparent
    inline slicing (no worker thread, no counters) — the mode sequential
    sessions use, where the golden tests pin the seed numerics.
    """

    def __init__(self, stream: DriftStream, speculative: bool = True,
                 max_prefetch: int = 64, reconcile_timeout_s: float = 5.0):
        self.stream = stream
        self.speculative = speculative
        self.max_prefetch = max_prefetch
        # Anti-stall bound on waiting for a matched window still being
        # synthesized (the worker may be draining a cancelled batch's
        # in-flight window first). Orders of magnitude above any single
        # window's synthesis time, so it only fires pathologically; on
        # timeout the request degrades to an inline miss — never a stall,
        # never a wrong frame.
        self.reconcile_timeout_s = reconcile_timeout_s
        self.stats = SpeculationStats()
        # Request trace: (dt0, dt1, max_frames, tag) offsets from the phase
        # start; ``tag`` marks the window's role ("label" for the labeling
        # burst) so decision-aware hints can pre-size it on rotation.
        self._trace: List[Tuple[float, float, int, Optional[str]]] = []
        self._phase_start: Optional[float] = None
        self._batch: Optional[_SpecBatch] = None
        self._queue: "queue.Queue" = queue.Queue()
        self._stop = threading.Event()
        self._worker: Optional[threading.Thread] = None

    # ------------------------------------------------------------- proxies
    @property
    def duration(self) -> float:
        return self.stream.duration

    @property
    def fps(self) -> float:
        return self.stream.fps

    # ------------------------------------------------------------- stats
    @property
    def hits(self) -> int:
        return self.stats.hits

    @property
    def misses(self) -> int:
        return self.stats.misses

    @property
    def hit_rate(self) -> float:
        return self.stats.hit_rate

    # -------------------------------------------------------------- worker
    def _ensure_worker(self) -> None:
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._worker_loop,
                                            daemon=True)
            self._worker.start()

    def _worker_loop(self) -> None:
        while not self._stop.is_set():
            batch = self._queue.get()
            if batch is None:
                return
            try:
                for w in batch.windows:
                    if batch.cancelled or self._stop.is_set():
                        break
                    try:
                        x, y = self.stream.frames(w.t0, w.t1,
                                                  max_frames=w.max_frames)
                    except Exception:
                        break  # surviving windows stay unset -> misses
                    w.x, w.y = x, y
                    w.ready.set()
            finally:
                for w in batch.windows:
                    w.ready.set()  # unset windows reconcile as misses

    # -------------------------------------------------------------- phases
    def begin_phase(self, start: float,
                    label_hint: Optional[Tuple[int, float]] = None) -> None:
        """Open a phase at virtual time ``start``: retire the previous
        phase's speculation, and speculate this phase from its trace.

        ``label_hint`` is the decision-aware predictor (ROADMAP "smarter
        speculation"): at the phase barrier the session already knows the
        next decision's labeling budget, so a ``(n_samples, fps)`` hint
        pre-sizes every ``"label"``-tagged window of the replayed trace to
        the upcoming burst — on drift phases the N_ldd burst prefetches
        whole instead of replaying (and missing on) the last phase's small
        layout. Mis-sized hints behave like any misprediction: a reconcile
        miss, never a wrong frame."""
        prev_trace = self._trace
        self._trace = []
        self._phase_start = start
        if not self.speculative:
            return
        self.stats.phases += 1
        if self._batch is not None:
            self._batch.cancelled = True
            self.stats.windows_wasted += sum(
                1 for w in self._batch.windows if not w.consumed)
            self._batch = None
        if not prev_trace:
            return
        windows = []
        for dt0, dt1, mf, tag in prev_trace[:self.max_prefetch]:
            if (label_hint is not None and tag == "label"
                    and mf != label_hint[0]):
                n, fps = label_hint
                dt1, mf = dt0 + n / fps, int(n)
                self.stats.windows_hinted += 1
            windows.append(
                _SpecWindow(start + dt0, start + dt1, mf,
                            _window_key(self.stream, start + dt0,
                                        start + dt1, mf)))
        self._batch = _SpecBatch(windows)
        self.stats.windows_speculated += len(windows)
        self._ensure_worker()
        self._queue.put(self._batch)

    # -------------------------------------------------------------- frames
    def frames(self, t0: float, t1: float, max_frames: int = 0,
               tag: Optional[str] = None) -> Tuple[np.ndarray, np.ndarray]:
        """Frames in [t0, t1) — bit-identical to ``stream.frames``, served
        from the speculation when the prediction reconciles. ``tag`` names
        the window's role in the phase layout (``"label"`` enables
        decision-aware pre-sizing on the next rotation)."""
        if not self.speculative:
            return self.stream.frames(t0, t1, max_frames=max_frames)
        if self._phase_start is not None:
            self._trace.append((t0 - self._phase_start,
                                t1 - self._phase_start, max_frames, tag))
        batch = self._batch
        if batch is not None and not batch.cancelled:
            w = batch.index.get(_window_key(self.stream, t0, t1, max_frames))
            if w is not None and not w.consumed:
                # ready is set only after both arrays are stored, so it also
                # guards the timeout path against a torn read.
                if w.ready.wait(self.reconcile_timeout_s) and w.x is not None:
                    w.consumed = True
                    self.stats.hits += 1
                    return w.x, w.y
        self.stats.misses += 1
        return self.stream.frames(t0, t1, max_frames=max_frames)

    # --------------------------------------------------------------- close
    def close(self) -> None:
        """Stop the worker; the pipeline keeps serving frames inline."""
        self._stop.set()
        if self._batch is not None:
            self._batch.cancelled = True
            self.stats.windows_wasted += sum(
                1 for w in self._batch.windows if not w.consumed)
            self._batch = None
        self._queue.put(None)  # unblock the queue.get
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        self.speculative = False

    def __del__(self):
        self._stop.set()
        try:
            self._queue.put_nowait(None)
        except Exception:
            pass
