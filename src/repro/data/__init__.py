from repro.data.stream import DriftStream, SCENARIOS, Segment, scenario  # noqa: F401
from repro.data.tokens import TokenPipeline  # noqa: F401
