from repro.data.pipeline import FramePipeline, SpeculationStats  # noqa: F401
from repro.data.stream import (  # noqa: F401
    DriftStream,
    PrefetchingWindowIterator,
    SCENARIOS,
    Segment,
    scenario,
)
from repro.data.tokens import TokenPipeline  # noqa: F401
