"""Synthetic LM token pipeline: deterministic, host-sharded batches.

Tokens come from a fixed low-entropy bigram chain so cross-entropy has real
structure to learn (quickstart/train examples show loss decreasing). Batches
are generated per (step, host) so multihost data parallelism needs no
coordination — host h materializes only its slice of the global batch.
"""
from __future__ import annotations

import numpy as np


class TokenPipeline:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, branching: int = 4,
                 num_hosts: int = 1, host_index: int = 0):
        assert global_batch % num_hosts == 0
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.local_batch = global_batch // num_hosts
        self.host_index = host_index
        self.seed = seed
        rng = np.random.default_rng(seed)
        # Each token can be followed by `branching` successors, uniformly.
        self._succ = rng.integers(0, vocab_size,
                                  size=(vocab_size, branching))

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed, step, self.host_index, 7919))
        b, s = self.local_batch, self.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab_size, size=b)
        choices = rng.integers(0, self._succ.shape[1], size=(b, s))
        for t in range(s):
            toks[:, t + 1] = self._succ[toks[:, t], choices[:, t]]
        return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}
