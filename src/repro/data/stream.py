"""Synthetic drifting video-analytics streams (BDD100K stand-in).

Reproduces the paper's drift taxonomy (§VII-A) exactly — three single-drift
attributes plus weather for the extreme scenarios:

* Label Distribution: "traffic" (classes 0-4, skewed) vs "all" (0-7);
* Time of Day: daytime vs night (brightness/contrast/blue shift);
* Location: city (high-frequency clutter) vs highway (smooth gradients);
* Weather: clear / overcast / rainy / snowy (noise overlays).

Scenario tables S1-S6 / ES1-ES2 mirror Table II: 20-minute streams at 30 FPS
built from 60-second segments; each segment flips one (regular) or all four
(extreme) attributes. Frames are generated deterministically from (scenario
seed, time) so every system variant scores the identical stream.
"""
from __future__ import annotations

import dataclasses
import hashlib
import queue
import threading
from typing import Iterator, List, Sequence, Tuple

import numpy as np

N_CLASSES = 8
IMG = 32
TRAFFIC_CLASSES = (0, 1, 2, 3, 4)


@dataclasses.dataclass(frozen=True)
class Segment:
    duration_s: float = 60.0
    label_dist: str = "traffic"  # traffic | all
    time_of_day: str = "day"  # day | night
    location: str = "city"  # city | highway
    weather: str = "clear"  # clear | overcast | rainy | snowy


def _alternate(n: int, **flips) -> List[Segment]:
    """n segments flipping the given attributes every segment."""
    segs = []
    for i in range(n):
        kw = {}
        for attr, (a, b) in flips.items():
            kw[attr] = a if (i // _PERIOD.get(attr, 1)) % 2 == 0 else b
        segs.append(Segment(**kw))
    return segs


# Different flip periods per attribute so drifts don't always coincide.
_PERIOD = {"label_dist": 1, "time_of_day": 2, "location": 3, "weather": 4}

_N_SEG = 20  # 20 x 60 s = 20 minutes (paper §VII-A)

SCENARIOS = {
    # Regular: one drift type at a time (Table II).
    "S1": dict(weather="clear", flips=dict(label_dist=("traffic", "all"))),
    "S2": dict(weather="overcast", flips=dict(label_dist=("traffic", "all"))),
    "S3": dict(weather="clear", flips=dict(label_dist=("traffic", "all"),
                                           time_of_day=("day", "night"))),
    "S4": dict(weather="snowy", flips=dict(label_dist=("traffic", "all"),
                                           time_of_day=("day", "night"))),
    "S5": dict(weather="clear", flips=dict(label_dist=("traffic", "all"),
                                           time_of_day=("day", "night"),
                                           location=("city", "highway"))),
    "S6": dict(weather="rainy", flips=dict(label_dist=("traffic", "all"),
                                           time_of_day=("day", "night"),
                                           location=("city", "highway"))),
    # Extreme: all four drift axes at once.
    "ES1": dict(weather=None, flips=dict(label_dist=("traffic", "all"),
                                         time_of_day=("day", "night"),
                                         location=("city", "highway"),
                                         weather=("clear", "rainy"))),
    "ES2": dict(weather=None, flips=dict(label_dist=("traffic", "all"),
                                         time_of_day=("night", "day"),
                                         location=("highway", "city"),
                                         weather=("snowy", "overcast"))),
}


def scenario(name: str, n_segments: int = _N_SEG) -> List[Segment]:
    spec = SCENARIOS[name]
    segs = _alternate(n_segments, **spec["flips"])
    if spec["weather"] is not None:
        segs = [dataclasses.replace(s, weather=spec["weather"]) for s in segs]
    return segs


class DriftStream:
    """Deterministic frame stream over a scenario."""

    def __init__(self, segments: Sequence[Segment], fps: float = 30.0,
                 seed: int = 0, img: int = IMG, n_classes: int = N_CLASSES):
        self.segments = list(segments)
        self.fps = fps
        self.seed = seed
        self.img = img
        self.n_classes = n_classes
        self._bounds = np.cumsum([s.duration_s for s in self.segments])
        rng = np.random.default_rng(seed + 1234)
        # Smooth per-class base patterns (low-frequency random fields).
        k = img // 4
        low = rng.normal(size=(n_classes, k, k, 3))
        self._class_patterns = np.stack(
            [np.kron(low[c], np.ones((4, 4, 1))) for c in range(n_classes)])
        self._city_tex = rng.normal(size=(img, img, 3)) * 0.6
        gradient = np.linspace(-1, 1, img)[:, None, None]
        self._highway_tex = np.broadcast_to(gradient, (img, img, 3)) * 0.6

    @property
    def duration(self) -> float:
        return float(self._bounds[-1])

    def segment_index(self, t: float) -> int:
        idx = int(np.searchsorted(self._bounds, t, side="right"))
        return min(idx, len(self.segments) - 1)

    def segment_at(self, t: float) -> Segment:
        return self.segments[self.segment_index(t)]

    def frame_times(self, t0: float, t1: float,
                    max_frames: int = 0) -> np.ndarray:
        """The exact frame timestamps ``frames(t0, t1, max_frames)`` renders.

        Split out so consumers (data/pipeline.py) can decide whether two
        requests produce identical frames without synthesizing either: a
        frame depends on its time only through ``round(t, 4)`` (the hash
        input) and its segment index, so matching those per timestamp is a
        bit-identity guarantee."""
        n = max(1, int(round((t1 - t0) * self.fps)))
        if max_frames and n > max_frames:
            return np.linspace(t0, t1, max_frames, endpoint=False)
        return t0 + np.arange(n) / self.fps

    def _label_probs(self, seg: Segment) -> np.ndarray:
        p = np.zeros(self.n_classes)
        if seg.label_dist == "traffic":
            p[list(TRAFFIC_CLASSES)] = (0.35, 0.25, 0.2, 0.12, 0.08)
        else:
            p[:] = 1.0 / self.n_classes
        return p

    def frames(self, t0: float, t1: float,
               max_frames: int = 0) -> Tuple[np.ndarray, np.ndarray]:
        """Frames in [t0, t1); optionally uniformly subsampled."""
        times = self.frame_times(t0, t1, max_frames)
        xs, ys = [], []
        for t in times:
            x, y = self._frame(float(t))
            xs.append(x)
            ys.append(y)
        return np.stack(xs), np.asarray(ys, np.int32)

    def _frame(self, t: float) -> Tuple[np.ndarray, int]:
        seg = self.segment_at(t)
        # Deterministic per-frame RNG.
        h = hashlib.blake2b(f"{self.seed}:{t:.4f}".encode(),
                            digest_size=8).digest()
        rng = np.random.default_rng(int.from_bytes(h, "little"))
        y = int(rng.choice(self.n_classes, p=self._label_probs(seg)))
        x = self._class_patterns[y] * 0.55
        x = x + rng.normal(size=x.shape) * 1.0  # instance noise
        # Location background.
        x = x + (self._city_tex if seg.location == "city"
                 else self._highway_tex)
        # Time of day.
        if seg.time_of_day == "night":
            x = x * 0.35
            x[..., 2] += 0.5  # blue shift
        # Weather.
        if seg.weather == "overcast":
            x = x * 0.7 + 0.2
        elif seg.weather == "rainy":
            streaks = (rng.random(x.shape[:2]) < 0.06)[..., None] * 1.5
            x = x * 0.8 + streaks
        elif seg.weather == "snowy":
            flakes = (rng.random(x.shape[:2]) < 0.10)[..., None] * 2.0
            x = x * 0.9 + flakes
        return x.astype(np.float32), y

    def windows(self, t0: float, t1: float, window_s: float,
                max_frames: int = 0,
                prefetch: int = 2) -> "PrefetchingWindowIterator":
        """Iterate ``(t_start, t_end, x, y)`` frame windows of ``window_s``
        seconds over [t0, t1), generated ``prefetch`` windows ahead on a
        background thread — see :class:`PrefetchingWindowIterator`."""
        spans = []
        t = t0
        while t < t1 - 1e-9:
            spans.append((t, min(t + window_s, t1)))
            t += window_s
        return PrefetchingWindowIterator(self, spans, max_frames=max_frames,
                                         depth=prefetch)

    def sample_dataset(self, n: int, rng: np.random.Generator,
                       segments: Sequence[Segment] = None):
        """IID samples across given segments (for pretraining).

        Uses the SAME seed as this stream: the class patterns / textures
        must be the world the CL system is later scored on (the sampler
        only randomizes the timestamps)."""
        segs = list(segments) if segments is not None else self.segments
        xs, ys = [], []
        stream = DriftStream(segs, fps=self.fps, seed=self.seed,
                             img=self.img, n_classes=self.n_classes)
        times = rng.uniform(0, stream.duration, size=n)
        for t in times:
            x, y = stream._frame(float(t))
            xs.append(x)
            ys.append(y)
        return np.stack(xs), np.asarray(ys, np.int32)


class PrefetchingWindowIterator:
    """Frame windows generated ahead of consumption on a background thread.

    Host-side frame synthesis is a serial numpy loop; when the consumer
    dispatches async device work per window (core/dispatch.py), generating
    the *next* window on a worker thread overlaps CPU frame slicing with
    device execution instead of serializing the dispatch stream. Windows are
    yielded strictly in span order as ``(t_start, t_end, x, y)`` — the
    deterministic per-frame RNG makes the output identical to calling
    ``stream.frames`` per span inline.

    ``depth`` bounds how many undelivered windows may be in flight, so a
    slow consumer never accumulates unbounded frames in memory.
    """

    def __init__(self, stream: DriftStream,
                 spans: Sequence[Tuple[float, float]],
                 max_frames: int = 0, depth: int = 2):
        self.spans = list(spans)
        self._queue: "queue.Queue" = queue.Queue(maxsize=max(1, depth))
        self._error_box: list = []  # producer appends; consumer re-raises
        self._stop = threading.Event()
        self._closed = False

        # The producer closes over locals only — never ``self`` — so an
        # abandoned iterator can be garbage-collected, whose __del__ then
        # stops the thread via the shared event.
        spans_, q, stop, error_box = self.spans, self._queue, self._stop, \
            self._error_box

        def _put(item) -> bool:
            """Bounded put that gives up when the consumer went away."""
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.1)
                    return True
                except queue.Full:
                    continue
            return False

        def _producer():
            try:
                for t0, t1 in spans_:
                    if stop.is_set():
                        return
                    x, y = stream.frames(t0, t1, max_frames=max_frames)
                    if not _put((t0, t1, x, y)):
                        return
            except BaseException as exc:  # surfaced on the consumer side
                error_box.append(exc)
            finally:
                _put(None)  # sentinel: exhausted (or failed)

        self._thread = threading.Thread(target=_producer, daemon=True)
        self._thread.start()

    def __iter__(self) -> Iterator[Tuple[float, float, np.ndarray,
                                         np.ndarray]]:
        return self

    def __next__(self):
        if self._closed:
            raise StopIteration
        item = self._queue.get()
        if item is None:
            self._closed = True
            self._thread.join()
            if self._error_box:
                raise self._error_box[0]
            raise StopIteration
        return item

    def close(self) -> None:
        """Stop the producer early; subsequent ``next()`` raises
        StopIteration (the sentinel may be drained here, so ``__next__``
        must never block on the queue again)."""
        self._closed = True
        self._stop.set()
        while self._thread.is_alive():
            try:
                self._queue.get(timeout=0.05)  # unblock a full-queue put
            except queue.Empty:
                pass
        self._thread.join()

    def __del__(self):
        # Safety net for abandoned iterators: the producer's timeout-put
        # notices _stop and exits, so no thread or frame window leaks.
        self._stop.set()
