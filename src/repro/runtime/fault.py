"""Fault tolerance: heartbeats, straggler detection, preemption-safe loops.

On a real multi-pod deployment the Heartbeat is fed per-host via the
coordination service; here the same logic runs single-process and is
exercised by tests with a FailureInjector. ``resilient_loop`` is the
production training-loop wrapper: checkpoint every N steps, restore and
continue on failure, give up after max_restarts.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

import numpy as np


class Heartbeat:
    """Per-step wall-time tracker with quantile statistics."""

    def __init__(self, window: int = 100):
        self.window = window
        self.durations: List[float] = []
        self._last: Optional[float] = None

    def beat(self) -> float:
        now = time.monotonic()
        dur = 0.0
        if self._last is not None:
            dur = now - self._last
            self.durations.append(dur)
            if len(self.durations) > self.window:
                self.durations.pop(0)
        self._last = now
        return dur

    def median(self) -> float:
        return float(np.median(self.durations)) if self.durations else 0.0


class StragglerDetector:
    """Flags steps slower than ``factor`` x rolling median — the signal a
    pod-level scheduler uses to evict/replace a slow host. Mitigation hook is
    pluggable (default: record; production: trigger elastic re-mesh)."""

    def __init__(self, factor: float = 3.0, min_samples: int = 8):
        self.factor = factor
        self.min_samples = min_samples
        self.events: List[Dict] = []

    def observe(self, step: int, duration: float, median: float) -> bool:
        is_straggler = (median > 0 and duration > self.factor * median)
        if is_straggler:
            self.events.append(
                {"step": step, "duration": duration, "median": median})
        return is_straggler


class FailureInjector:
    """Deterministic failure injection for restart/recovery tests.

    ``fail_at_steps`` entries are either bare step numbers (fail whoever
    probes that step first — the ``resilient_loop`` contract) or
    ``(step, key)`` pairs targeting one probe site: the fleet manager
    probes with ``key=shard_index`` each round, so ``(3, 1)`` kills shard 1
    at round 3 and nobody else. Each entry fires exactly once — the
    check-then-mark is under a lock, so the exactly-once contract holds
    when shards probe concurrently from a worker pool
    (``FleetManager(parallel_shards=N)``); keyed ``(step, key)`` entries
    stay fully deterministic there, while bare-step entries fire on
    whichever probe wins the lock first."""

    def __init__(self, fail_at_steps=()):
        self.fail_at = set(fail_at_steps)
        self.failed = set()
        self._lock = threading.Lock()

    def maybe_fail(self, step: int, key=None) -> None:
        probe = step if key is None else (step, key)
        with self._lock:
            for entry in (step, probe) if key is not None else (step,):
                if entry in self.fail_at and entry not in self.failed:
                    self.failed.add(entry)
                    where = f" (key={key})" if key is not None else ""
                    raise RuntimeError(
                        f"injected node failure at step {step}{where}")


@dataclasses.dataclass
class LoopReport:
    final_step: int
    restarts: int
    straggler_events: int
    checkpointed_steps: List[int]


def resilient_loop(
    step_fn: Callable,  # (state, step) -> state
    state,
    num_steps: int,
    checkpoint_manager,
    checkpoint_every: int = 50,
    max_restarts: int = 3,
    failure_injector: Optional[FailureInjector] = None,
    straggler_detector: Optional[StragglerDetector] = None,
    state_like: Optional[object] = None,
) -> tuple:
    """Preemption-safe training loop: on failure, restore the last complete
    checkpoint and continue. Returns (state, LoopReport)."""
    hb = Heartbeat()
    sd = straggler_detector or StragglerDetector()
    restarts = 0
    saved_steps: List[int] = []
    step = 0
    # Resume if a checkpoint exists.
    latest = checkpoint_manager.latest_step()
    if latest is not None:
        state, manifest = checkpoint_manager.restore(
            latest, state_like if state_like is not None else state)
        step = int(manifest["step"])

    while step < num_steps:
        try:
            if failure_injector is not None:
                failure_injector.maybe_fail(step)
            state = step_fn(state, step)
            dur = hb.beat()
            sd.observe(step, dur, hb.median())
            step += 1
            if step % checkpoint_every == 0:
                checkpoint_manager.save(step, state, blocking=True)
                saved_steps.append(step)
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            latest = checkpoint_manager.latest_step()
            if latest is not None:
                state, manifest = checkpoint_manager.restore(
                    latest, state_like if state_like is not None else state)
                step = int(manifest["step"])
            else:
                step = 0
    return state, LoopReport(step, restarts, len(sd.events), saved_steps)
