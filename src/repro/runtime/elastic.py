"""Elastic scaling: reshard live state onto a different mesh.

``reshard_tree`` moves a (possibly sharded) pytree onto new shardings —
used when the pod scheduler grows/shrinks the data axis (node failure,
preemption backfill) without restarting from a checkpoint.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shardings_for(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def reshard_tree(tree, new_shardings):
    """device_put every leaf onto its new sharding (handles cross-mesh
    moves; on CPU this is a host-side reshuffle)."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), tree, new_shardings)


def rehome_tree(tree, mesh: Mesh = None, spec_tree=None):
    """Land a host-restored pytree on a (possibly shrunken) target mesh —
    the restore half of an elastic shrink: checkpointed lane state comes
    back as host numpy arrays and is device_put onto the surviving shard's
    devices. With no mesh (single-device shards, the default here) this is
    a plain device_put of every leaf, which normalizes numpy leaves to jax
    arrays so restored lanes compute exactly like live ones."""
    import jax.numpy as jnp

    if mesh is not None and spec_tree is not None:
        return reshard_tree(tree, shardings_for(mesh, spec_tree))
    return jax.tree_util.tree_map(jnp.asarray, tree)


def elastic_data_axis(mesh: Mesh, lost_rows: int) -> tuple:
    """Shrink the data axis by ``lost_rows`` (failed hosts) — returns the new
    mesh built from surviving devices, keeping the model axis intact."""
    import numpy as np

    ax = 0  # data-like axis is first by convention ("pod" or "data")
    dev = mesh.devices
    keep = dev.shape[ax] - lost_rows
    if keep <= 0:
        raise ValueError("no surviving rows")
    new_dev = np.take(dev, range(keep), axis=ax)
    return Mesh(new_dev, mesh.axis_names)
