from repro.runtime.fault import (  # noqa: F401
    FailureInjector,
    Heartbeat,
    StragglerDetector,
    resilient_loop,
)
from repro.runtime.elastic import reshard_tree  # noqa: F401
