"""The paper's own student/teacher model pairs (Table III).

| Type    | Name          | Parameters | GFLOPs |
|---------|---------------|------------|--------|
| Student | ResNet18      | 11.7M      | 1.82   |
| Student | ResNet34      | 21.8M      | 3.67   |
| Student | ViT-B/32      | 88.2M      | 4.37   |
| Teacher | WideResNet50  | 68.9M      | 11.43  |
| Teacher | ViT-B/16      | 86.6M      | 16.87  |
| Teacher | WideResNet101 | 126.9M     | 22.80  |
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class VisionConfig:
    name: str
    kind: str  # resnet | vit
    depth: int = 18  # resnet depth (18/34/50/101)
    width_mult: int = 1  # 2 for wide resnets
    patch: int = 16  # vit patch size
    d_model: int = 768
    num_heads: int = 12
    d_ff: int = 3072
    num_layers: int = 12
    img_size: int = 224
    num_classes: int = 1000
    base: int = 64  # resnet stem width

    def reduced(self, img_size: int = 24, num_classes: int = 8) -> "VisionConfig":
        """Small same-family twin for the CPU-side CL loop; teacher/student
        capacity gap preserved (wide resnets keep width_mult=2, ViT-B/16
        keeps its 4x patch count)."""
        if self.kind == "vit":
            return dataclasses.replace(
                self, name=self.name + "-reduced", img_size=img_size,
                num_classes=num_classes, d_model=64, num_heads=4, d_ff=128,
                num_layers=2, patch=max(4, self.patch // 4))
        return dataclasses.replace(
            self, name=self.name + "-reduced", img_size=img_size,
            num_classes=num_classes, depth=min(self.depth, 18),
            width_mult=self.width_mult, base=24)


RESNET18 = VisionConfig("resnet18", "resnet", depth=18)
RESNET34 = VisionConfig("resnet34", "resnet", depth=34)
WIDERESNET50 = VisionConfig("wideresnet50", "resnet", depth=50, width_mult=2)
WIDERESNET101 = VisionConfig("wideresnet101", "resnet", depth=101, width_mult=2)
VIT_B32 = VisionConfig("vit-b32", "vit", patch=32)
VIT_B16 = VisionConfig("vit-b16", "vit", patch=16)

VISION_MODELS = {
    m.name: m
    for m in (RESNET18, RESNET34, WIDERESNET50, WIDERESNET101, VIT_B32, VIT_B16)
}

# (student, teacher) pairs exactly as evaluated in the paper (§VII-A).
PAIRS: Tuple[Tuple[VisionConfig, VisionConfig], ...] = (
    (RESNET18, WIDERESNET50),
    (VIT_B32, VIT_B16),
    (RESNET34, WIDERESNET101),
)

# Table III reference numbers for validation benches.
TABLE_III = {
    "resnet18": (11.7e6, 1.82),
    "resnet34": (21.8e6, 3.67),
    "vit-b32": (88.2e6, 4.37),
    "wideresnet50": (68.9e6, 11.43),
    "vit-b16": (86.6e6, 16.87),
    "wideresnet101": (126.9e6, 22.80),
}
