"""granite-20b — dense llama-arch code model with MQA (kv=1).

[arXiv:2405.04324; hf] — gpt-bigcode lineage: multi-query attention,
LayerNorm + GELU MLP, learned absolute positions.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    norm="layernorm",
    mlp="gelu",
    pos="learned",
    max_position_embeddings=8192,
    source="arXiv:2405.04324; hf",
)
