"""musicgen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf] — MHA (kv=24), LayerNorm + GELU, sinusoidal positions,
4 parallel codebook output heads; the EnCodec frontend is a stub:
``input_specs()`` supplies precomputed frame embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    norm="layernorm",
    mlp="gelu",
    pos="sincos",
    input_mode="embeddings",
    num_output_heads=4,
    source="arXiv:2306.05284; hf",
)
