"""Architecture & shape configuration dataclasses.

Every assigned architecture gets one module defining an ``ArchConfig`` with the
exact published hyperparameters; ``reduced()`` derives a small same-family config
for CPU smoke tests. ``ShapeConfig`` describes the assigned input-shape cells.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

MIXER_ATTENTION = "attention"
MIXER_MAMBA = "mamba"
MIXER_MLSTM = "mlstm"
MIXER_SLSTM = "slstm"


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Declarative model description consumed by ``repro.models.transformer``."""

    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # Norm / MLP / positional choices.
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    mlp: str = "swiglu"  # swiglu | geglu | gelu | none
    pos: str = "rope"  # rope | learned | sincos | none
    rope_theta: float = 10_000.0
    max_position_embeddings: int = 1_048_576

    # Attention variants.
    sliding_window: Optional[int] = None  # SWA on every attention layer
    local_global_period: int = 0  # >0: alternate local(window)/global layers
    local_window: Optional[int] = None
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    query_scale: Optional[float] = None  # default 1/sqrt(head_dim)
    post_block_norm: bool = False  # gemma2-style post norms
    embed_scale: bool = False  # multiply embeddings by sqrt(d_model)

    # Layer pattern (which mixer at which depth).
    mixer_default: str = MIXER_ATTENTION
    attn_layer_period: int = 1  # attention every k-th layer when default!=attention
    attn_layer_offset: int = 0
    slstm_at: Tuple[int, ...] = ()

    # Mixture-of-Experts.
    num_experts: int = 0
    top_k: int = 0
    expert_layer_period: int = 1
    expert_layer_offset: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # Mamba (S6).
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # xLSTM.
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0

    # IO.
    input_mode: str = "tokens"  # tokens | embeddings (vlm/audio frontend stub)
    num_output_heads: int = 1  # musicgen: 4 codebook heads
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    source: str = ""  # provenance note from the assignment

    # ------------------------------------------------------------------ helpers
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def mixer_for_layer(self, i: int) -> str:
        if self.mixer_default == MIXER_ATTENTION:
            return MIXER_ATTENTION
        if self.mixer_default == MIXER_MAMBA:
            if i % self.attn_layer_period == self.attn_layer_offset:
                return MIXER_ATTENTION
            return MIXER_MAMBA
        if self.mixer_default == MIXER_MLSTM:
            return MIXER_SLSTM if i in self.slstm_at else MIXER_MLSTM
        raise ValueError(self.mixer_default)

    def is_moe_layer(self, i: int) -> bool:
        if self.num_experts <= 0:
            return False
        return i % self.expert_layer_period == self.expert_layer_offset

    def is_local_layer(self, i: int) -> bool:
        """gemma2-style alternation: even layers local, odd layers global."""
        if self.local_global_period <= 0:
            return False
        return i % self.local_global_period == 0

    def layer_signature(self, i: int) -> tuple:
        return (self.mixer_for_layer(i), self.is_moe_layer(i), self.is_local_layer(i))

    def pattern_period(self) -> int:
        """Smallest p dividing num_layers with a repeating layer signature."""
        for p in range(1, self.num_layers + 1):
            if self.num_layers % p:
                continue
            if all(
                self.layer_signature(i) == self.layer_signature(i % p)
                for i in range(self.num_layers)
            ):
                return p
        return self.num_layers

    # ------------------------------------------------------------ param counts
    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, h = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.num_output_heads * self.vocab_size * d
        if self.pos == "learned":
            total += self.max_position_embeddings * d
        for i in range(self.num_layers):
            mixer = self.mixer_for_layer(i)
            if mixer == MIXER_ATTENTION:
                total += d * h * (n_q + 2 * n_kv) + n_q * h * d
            elif mixer == MIXER_MAMBA:
                d_in = self.mamba_expand * d
                total += d * 2 * d_in  # in_proj
                total += d_in * self.mamba_d_conv  # conv
                total += d_in * (2 * self.mamba_d_state + 1)  # B,C,dt proj (x-dep)
                total += d_in * self.mamba_d_state  # A
                total += d_in * 2  # D, dt bias
                total += d_in * d  # out proj
            elif mixer == MIXER_MLSTM:
                d_in = int(self.mlstm_proj_factor * d)
                total += d * 2 * d_in + 3 * d_in * d_in + d_in * d + 4 * d_in
            elif mixer == MIXER_SLSTM:
                d_in = d
                total += 4 * d_in * d_in + 4 * d_in  # recurrent gates
                pf = self.slstm_proj_factor
                total += int(d_in * d_in * pf * 2)  # up/down proj
            if self.mlp != "none" and self.d_ff > 0:
                n_mat = 3 if self.mlp in ("swiglu", "geglu") else 2
                ff = n_mat * d * self.d_ff
                if self.is_moe_layer(i):
                    total += self.num_experts * ff + d * self.num_experts
                else:
                    total += ff
            total += 2 * d  # norms
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts)."""
        if self.num_experts <= 0:
            return self.param_count()
        total = self.param_count()
        n_mat = 3 if self.mlp in ("swiglu", "geglu") else 2
        ff = n_mat * self.d_model * self.d_ff
        n_moe = sum(self.is_moe_layer(i) for i in range(self.num_layers))
        total -= n_moe * (self.num_experts - self.top_k) * ff
        return total

    # ------------------------------------------------------------------ reduced
    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        period = self.pattern_period()
        n_layers = max(period, 2 if period == 1 else period)
        slstm_at = tuple(i for i in range(n_layers) if i in {x % period for x in self.slstm_at})
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=n_layers,
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            num_experts=min(self.num_experts, 4),
            top_k=min(self.top_k, 2),
            mamba_d_state=8,
            max_position_embeddings=512,
            slstm_at=slstm_at,
            dtype="float32",
        )


TRAIN = "train"
PREFILL = "prefill"
DECODE = "decode"


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


LM_SHAPES = (
    ShapeConfig("train_4k", 4_096, 256, TRAIN),
    ShapeConfig("prefill_32k", 32_768, 32, PREFILL),
    ShapeConfig("decode_32k", 32_768, 128, DECODE),
    ShapeConfig("long_500k", 524_288, 1, DECODE),
)
SHAPES_BY_NAME = {s.name: s for s in LM_SHAPES}


def supports_long_context(arch: ArchConfig) -> bool:
    """long_500k needs sub-quadratic attention (SWA/local/SSM/hybrid)."""
    if arch.mixer_default != MIXER_ATTENTION:
        return True  # ssm / hybrid / xlstm
    return arch.sliding_window is not None or arch.local_global_period > 0


def supports_shape(arch: ArchConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return supports_long_context(arch)
    return True


def flops_per_token(arch: ArchConfig, training: bool) -> float:
    """MODEL_FLOPS: 6·N·D rule (dense) / 6·N_active·D (MoE); 2·N for inference."""
    n = arch.active_param_count() - arch.vocab_size * arch.d_model  # non-embedding
    mult = 6.0 if training else 2.0
    return mult * n


def attention_flops(arch: ArchConfig, seq_len: int, training: bool) -> float:
    """Quadratic attention term per sequence (both QK^T and AV einsums)."""
    total = 0.0
    for i in range(arch.num_layers):
        if arch.mixer_for_layer(i) != MIXER_ATTENTION:
            continue
        window = None
        if arch.sliding_window is not None:
            window = arch.sliding_window
        if arch.local_global_period and arch.is_local_layer(i):
            window = arch.local_window
        eff = seq_len if window is None else min(window, seq_len)
        # causal: ~ S*eff/2 when eff==S else S*eff
        pairs = seq_len * eff / (2 if window is None else 1)
        flops = 2 * 2 * pairs * arch.num_heads * arch.resolved_head_dim
        total += flops * (3 if training else 1)
    return total
