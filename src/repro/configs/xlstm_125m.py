"""xlstm-125m — sLSTM + mLSTM blocks (xLSTM[~7:1] mix), d_ff=0.

[arXiv:2405.04517; unverified] — blocks carry their own projections
(mLSTM proj factor 2, sLSTM post-proj factor 4/3); no separate MLP.
sLSTM blocks at depths 3 and 9 (pattern period 6).
"""
from repro.configs.base import ArchConfig, MIXER_MLSTM

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    norm="layernorm",
    mlp="none",
    pos="none",
    mixer_default=MIXER_MLSTM,
    slstm_at=(3, 9),
    tie_embeddings=True,
    source="arXiv:2405.04517; unverified",
)
