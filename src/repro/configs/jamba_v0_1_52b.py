"""jamba-v0.1-52b — hybrid Mamba+attention (1:7 interleave), MoE 16 experts top-2.

[arXiv:2403.19887; hf] — attention every 8th layer (offset 4), MoE every 2nd
layer (offset 1), no positional encoding (Mamba carries position).
"""
from repro.configs.base import ArchConfig, MIXER_MAMBA

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    norm="rmsnorm",
    mlp="swiglu",
    pos="none",
    mixer_default=MIXER_MAMBA,
    attn_layer_period=8,
    attn_layer_offset=4,
    num_experts=16,
    top_k=2,
    expert_layer_period=2,
    expert_layer_offset=1,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    source="arXiv:2403.19887; hf",
)
