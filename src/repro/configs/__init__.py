"""Config registry: ``get_arch(name)`` / ``ARCHS`` / shape cells."""
from __future__ import annotations

from repro.configs import (
    gemma2_2b,
    granite_20b,
    jamba_v0_1_52b,
    llava_next_mistral_7b,
    mixtral_8x22b,
    mixtral_8x7b,
    musicgen_medium,
    xlstm_125m,
    yi_34b,
    yi_6b,
)
from repro.configs.base import (
    ArchConfig,
    LM_SHAPES,
    SHAPES_BY_NAME,
    ShapeConfig,
    supports_shape,
)

_MODULES = (
    llava_next_mistral_7b,
    mixtral_8x22b,
    mixtral_8x7b,
    jamba_v0_1_52b,
    yi_34b,
    granite_20b,
    gemma2_2b,
    yi_6b,
    musicgen_medium,
    xlstm_125m,
)

ARCHS = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES_BY_NAME:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES_BY_NAME)}")
    return SHAPES_BY_NAME[name]


def all_cells(include_skipped: bool = False):
    """Yield (arch, shape, supported) for the 40 assigned cells."""
    for arch in ARCHS.values():
        for shape in LM_SHAPES:
            ok = supports_shape(arch, shape)
            if ok or include_skipped:
                yield arch, shape, ok


__all__ = [
    "ARCHS",
    "ArchConfig",
    "LM_SHAPES",
    "ShapeConfig",
    "all_cells",
    "get_arch",
    "get_shape",
    "supports_shape",
]
