"""gemma2-2b — local/global alternating attention, logit softcapping.

[arXiv:2408.00118; hf] — head_dim 256, GeGLU, pre+post RMSNorm,
embedding scaling, attn softcap 50, final softcap 30, local window 4096.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    norm="rmsnorm",
    mlp="geglu",
    pos="rope",
    rope_theta=10_000.0,
    local_global_period=2,
    local_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_block_norm=True,
    embed_scale=True,
    tie_embeddings=True,
    query_scale=0.0625,  # 1/sqrt(256)
    source="arXiv:2408.00118; hf",
)
