"""llava-next-mistral-7b — Mistral-7B backbone, anyres vision frontend stubbed.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] — the vision tower/anyres
tiling is a frontend stub: ``input_specs()`` supplies precomputed patch
embeddings of width d_model.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    norm="rmsnorm",
    mlp="swiglu",
    pos="rope",
    rope_theta=1_000_000.0,
    input_mode="embeddings",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
