"""Fault-tolerant sharded checkpointing.

Design points for 1000+ node deployments:
  * per-host shard files — each host serializes only its addressable shards
    (single-process here, but the layout and manifest carry mesh metadata);
  * atomic commit — write to ``step_XXXX.tmp`` then rename; a crash mid-save
    never corrupts the latest checkpoint;
  * async save — serialization happens on a background thread off the
    training loop (device->host copy is synchronous, I/O is not);
  * elastic restore — arrays are loaded as full logical tensors and
    re-device_put with the *target* mesh's shardings, so a 512-chip
    checkpoint restores onto 256 chips (or 1 CPU) unchanged;
  * clean shutdown — the manager is a context manager; ``close()`` (or the
    ``with`` exit) joins the in-flight async save so a process exiting
    right after a non-blocking ``save()`` cannot silently drop it, and
    ``all_steps``/``latest_step`` ignore step directories without a
    committed ``manifest.json`` so a torn write never crashes ``restore``.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, max_to_keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.max_to_keep = max_to_keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, metadata: Optional[Dict] = None,
             blocking: bool = False) -> None:
        # Device->host copy happens NOW (consistent snapshot); I/O async.
        host_state = jax.tree_util.tree_map(np.asarray, state)
        self.wait()  # one in-flight save at a time

        def _do_save():
            final = os.path.join(self.directory, f"step_{step:010d}")
            tmp = final + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            arrays = _flatten_with_paths(host_state)
            np.savez(os.path.join(tmp, f"shard_{jax.process_index()}.npz"),
                     **arrays)
            manifest = {
                "step": step,
                "time": time.time(),
                "num_processes": jax.process_count(),
                "leaves": sorted(arrays.keys()),
                "metadata": metadata or {},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic commit
            self._gc()

        if self.async_save and not blocking:
            self._thread = threading.Thread(target=_do_save, daemon=True)
            self._thread.start()
        else:
            _do_save()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def close(self) -> None:
        """Flush the in-flight async save. Safe to call repeatedly; after
        close the manager can still be used (it is a flush, not a
        shutdown)."""
        self.wait()

    def __enter__(self) -> "CheckpointManager":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.max_to_keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        """Committed steps only: a step directory without a manifest.json
        (torn write, e.g. rename raced a crash) is invisible, so
        ``latest_step``/``restore`` never pick up a partial checkpoint."""
        steps = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.isfile(
                    os.path.join(self.directory, name, "manifest.json")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int], like, shardings=None):
        """Restore into the structure of ``like``; optionally device_put with
        target shardings (elastic re-mesh on load)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(
            path, f"shard_{jax.process_index()}.npz"))
        flat_like = _flatten_with_paths(like)
        restored = {}
        for key, leaf in flat_like.items():
            arr = data[key]
            restored[key] = arr
        leaves_sorted = [restored[k] for k in sorted(flat_like.keys())]
        # Rebuild tree in `like`'s structure (paths sort identically).
        treedef = jax.tree_util.tree_structure(like)
        order = sorted(flat_like.keys())
        flat_vals = {k: v for k, v in zip(order, leaves_sorted)}
        keyed, _ = jax.tree_util.tree_flatten_with_path(like)
        rebuilt = []
        for pth, _leaf in keyed:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in pth)
            rebuilt.append(flat_vals[key])
        tree = jax.tree_util.tree_unflatten(treedef, rebuilt)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, manifest
