"""Serve a small model with batched requests: prefill + autoregressive
decode with ring-buffer/sequence KV caches.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from repro.launch.serve import main  # noqa: E402

if __name__ == "__main__":
    argv = sys.argv[1:] or [
        "--arch", "mixtral-8x7b", "--reduced", "--batch", "4",
        "--prompt-len", "32", "--gen", "16",
    ]
    raise SystemExit(main(argv))
