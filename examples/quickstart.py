"""Quickstart: the three public layers of the framework in one script.

1. MX precision — quantize tensors / run an MX matmul (the paper's DPE).
2. Continuous learning — Algorithm 1 on a drifting stream (60 virtual s).
3. LM zoo — one train step + one decode step of an assigned architecture.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp
import numpy as np


def demo_mx():
    from repro.kernels import ops

    x = jax.random.normal(jax.random.PRNGKey(0), (64, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 64))
    exact = x @ w
    for prec in ("mx4", "mx6", "mx9"):
        out = ops.mx_matmul(x, w, prec, prec)
        rel = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
        print(f"  {prec}: matmul relative error {rel:.4f}")


def demo_continuous_learning():
    from repro.configs.dacapo_pairs import RESNET18, WIDERESNET50
    from repro.core import CLHyperParams, CLSystemSpec
    from repro.data.stream import DriftStream, scenario

    stream = DriftStream(scenario("S1", 3), seed=0, img=24)
    hp = CLHyperParams(n_t=48, n_l=24, c_b=192)
    # Declarative front door: describe the system, then build the session.
    session = CLSystemSpec(student=RESNET18, teacher=WIDERESNET50, hp=hp,
                           allocator="dacapo-spatiotemporal",
                           apply_mx=False, eval_fps=0.5).build()
    print(f"  spatial allocation: T-SA={session.r_tsa} rows, "
          f"B-SA={session.r_bsa} rows (30 FPS inference)")
    session.pretrain(stream, teacher_steps=30, student_steps=20, batch=32)
    result = session.run(stream, duration=60.0)
    print(f"  60s of S1: avg accuracy {result.avg_accuracy*100:.1f}%, "
          f"{result.drift_events} drift events, "
          f"retrain/label = {result.retrain_time:.1f}s/"
          f"{result.label_time:.1f}s")


def demo_lm():
    from repro import configs
    from repro.models.transformer import make_model

    cfg = configs.get_arch("gemma2-2b").reduced()
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 33), 0,
                              cfg.vocab_size)
    loss, metrics = model.loss(
        params, {"inputs": toks[:, :-1], "labels": toks[:, 1:]})
    print(f"  {cfg.name}: train loss {float(loss):.2f}")
    logits, caches = model.prefill(params, toks[:, :16], cache_capacity=33)
    logits, _ = model.decode_step(params, toks[:, 16:17], jnp.asarray(16),
                                  caches)
    print(f"  prefill(16) + decode(1): logits {logits.shape}")


if __name__ == "__main__":
    print("== MX block-floating-point (paper §V-B) ==")
    demo_mx()
    print("== LM architecture zoo (assigned archs) ==")
    demo_lm()
    print("== Continuous learning (Algorithm 1) ==")
    demo_continuous_learning()
    print("done.")
