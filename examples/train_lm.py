"""Train a ~100M-parameter LM (xlstm-125m, the full assigned config) for a
few hundred steps on the host mesh with the production substrate: sharded
params, checkpointing, fault-tolerant loop.

Run:  PYTHONPATH=src python examples/train_lm.py          (full xlstm-125m)
      PYTHONPATH=src python examples/train_lm.py --reduced --steps 50
"""
import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from repro.launch.train import main  # noqa: E402

if __name__ == "__main__":
    argv = sys.argv[1:] or [
        "--arch", "xlstm-125m", "--steps", "300", "--batch", "8",
        "--seq", "128", "--lr", "3e-3", "--log-every", "20",
        "--checkpoint-every", "100",
    ]
    raise SystemExit(main(argv))
