"""End-to-end driver: DaCapo continuous learning on a drifting drive.

Runs the full Algorithm 1 system against an extreme scenario (ES1 — all four
drift axes) and compares against the Ekya-like fixed-window baseline on
identical pretrained weights, printing the accuracy timeline.

``--dispatch concurrent`` executes through the async dispatch layer
(core/dispatch.py): a forced 2-row mesh is fissioned into T-SA/B-SA
sub-meshes, score windows are fused into batched inference, each phase
charges max(t_TSA, t_BSA) — the paper's Fig. 4 overlap — instead of the
serial chain, and frame windows flow through the speculative FramePipeline
(data/pipeline.py), whose reconcile hit rate is reported per system.

``--online`` swaps DaCapo-ST for DaCapo-ST-Online, the drift-reactive
spatial re-allocator: watch the tsa/bsa row split move in the phase log
when a drift fires, then return as validation accuracy recovers.

``--trace PATH`` turns on the trace spine (core/trace.py) for the DaCapo
system, dumps the full per-program execution trace as JSON to PATH for
offline analysis (:meth:`~repro.core.trace.SessionTrace.load` /
:class:`~repro.core.replay.TraceReplayer`), and prints the top-5 device
programs by measured host wall time and by virtual-clock cost.

Run:  PYTHONPATH=src python examples/continuous_learning_drive.py [--fast]
          [--dispatch sequential|concurrent] [--online] [--trace PATH]
"""
import argparse
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--scenario", default="ES1")
    ap.add_argument("--dispatch", default="sequential",
                    choices=("sequential", "concurrent"))
    ap.add_argument("--online", action="store_true",
                    help="use the drift-reactive online spatial "
                         "re-allocator (DC-ST-Online) instead of DC-ST")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record the DaCapo run's execution trace and "
                         "dump it as JSON to PATH")
    args = ap.parse_args()

    from repro.configs.dacapo_pairs import RESNET18, WIDERESNET50
    from repro.core import CLHyperParams, CLSystemSpec, pretrain_model
    from repro.core.partition import forced_row_mesh
    from repro.data.stream import DriftStream, scenario
    from repro.models.registry import make_vision_model

    mesh = None
    if args.dispatch == "concurrent":
        # Force a 2-row mesh so T-SA and B-SA are disjoint sub-meshes.
        mesh = forced_row_mesh(2)

    n_seg = 3 if args.fast else 5
    duration = 90.0 if args.fast else 240.0
    stream = DriftStream(scenario(args.scenario, n_seg), seed=11, img=24)
    hp = CLHyperParams(n_t=64 if args.fast else 96,
                       n_l=32 if args.fast else 48,
                       c_b=256)

    # One shared pretraining for fairness.
    rng = np.random.default_rng(0)
    steps = (30, 20) if args.fast else (100, 40)
    tp = pretrain_model(make_vision_model(WIDERESNET50.reduced()), stream,
                        steps[0], 48, rng)
    sp = pretrain_model(make_vision_model(RESNET18.reduced()), stream,
                        steps[1], 48, rng, segments=stream.segments[:1],
                        seed=8)

    dacapo = ("dacapo-spatiotemporal-online" if args.online
              else "dacapo-spatiotemporal")
    results = {}
    trace_rec = None
    for allocator in (dacapo, "ekya"):
        session = CLSystemSpec(
            student=RESNET18, teacher=WIDERESNET50, hp=hp,
            allocator=allocator, apply_mx=False, eval_fps=0.5,
            mesh=mesh, dispatch=args.dispatch,
            trace=bool(args.trace) and allocator == dacapo).build()
        if allocator == dacapo:
            trace_rec = session.dispatcher.recorder
        session.set_pretrained(tp, sp)
        # Observer hook: structured per-phase metrics as they happen.
        session.add_observer(lambda rec, name=allocator: print(
            f"  [{name}] phase {rec.index:2d} t={rec.t:6.1f}s "
            f"acc_v={rec.acc_valid:.2f} acc_l={rec.acc_label:.2f}"
            f" tsa/bsa={rec.t_tsa:.2f}/{rec.t_bsa:.2f}s"
            f" rows={rec.decision.rows_tsa}/{rec.decision.rows_bsa}"
            f"{' DRIFT' if rec.drift else ''}"))
        results[allocator] = session.run(stream, duration=duration)

    print(f"\nscenario {args.scenario}, {duration:.0f} virtual seconds")
    print(f"{'time':>6} | {'DaCapo':>10} | {'Ekya':>10}")
    dc = dict(results[dacapo].accuracy_timeline)
    ek = dict(results["ekya"].accuracy_timeline)
    for t in sorted(set(list(dc) + list(ek))):
        a = f"{dc[t]*100:9.1f}%" if t in dc else "         -"
        b = f"{ek[t]*100:9.1f}%" if t in ek else "         -"
        print(f"{t:6.0f} | {a} | {b}")
    for name, res in results.items():
        hits = sum(r.spec_hits for r in res.records)
        misses = sum(r.spec_misses for r in res.records)
        spec = (f" spec-hit-rate={hits / (hits + misses):.0%}"
                if hits + misses else "")
        print(f"{name}: avg={res.avg_accuracy*100:.1f}% "
              f"drifts={res.drift_events} "
              f"label/retrain={res.label_time:.0f}/{res.retrain_time:.0f}s"
              f"{spec}")

    if args.trace and trace_rec is not None:
        trace = trace_rec.trace
        trace.save(args.trace)
        programs = [(ph.index, e) for ph in trace.phases
                    for e in ph.events if e.kind == "program"]
        n_events = sum(len(ph.events) for ph in trace.phases)
        print(f"\ntrace: {len(trace.phases)} phases, {n_events} events "
              f"({len(programs)} programs) -> {args.trace}")
        for title, key in (("host wall time", lambda pe: pe[1].wall_s),
                           ("virtual cost", lambda pe: pe[1].cost_s)):
            print(f"top-5 programs by {title}:")
            for idx, e in sorted(programs, key=key, reverse=True)[:5]:
                path = f" path={e.path}" if e.path else ""
                print(f"  phase {idx:2d} {e.label:>9} [{e.role}] "
                      f"cost={e.cost_s:8.4f}s wall={e.wall_s:8.4f}s "
                      f"units={e.units:g}{path}")


if __name__ == "__main__":
    main()
