"""Multi-camera fleet driver: N streams, one accelerator, shared T-SA.

Builds a small heterogeneous fleet — one camera drifting through a paper
scenario, the rest parked in stable contexts — and runs it through
:class:`~repro.core.fleet.FleetSession`: every camera serves its own
inference timeline on the B-SA while a single shared T-SA labels and
retrains for the whole fleet, with the
:class:`~repro.core.allocation.FleetAllocator` proportioning the per-phase
budget across cameras (``--mode drift-weighted|uniform|round-robin|
isolated``) and a pluggable :class:`~repro.core.decision.FleetRowPolicy`
resolving the fleet's ONE spatial plane per phase (``--row-policy
resolve-max|drift-surge|weighted-vote``). The per-phase log shows each
stream's lane (``s0``, ``s1``, ...) and where the budget went; the summary
compares per-stream accuracy and plots the fleet T-SA rows over time (the
spatial plane in motion under drift-surge / weighted-vote).

With ``--shards N`` (N > 1) the same fleet runs under the sharded
:class:`~repro.core.manager.FleetManager` tier instead — N independent
FleetSessions, one per sub-accelerator, with headroom placement, live
lane migration and per-lane checkpointing — and ``--fail-at PHASE``
injects an accelerator loss on the last shard at that phase: the driver
prints the manager's re-homing/recovery timeline (admissions,
migrations, the failure, each lane's checkpoint restore) and the
conserved manager/shard virtual-clock ledgers.

``--parallel N`` steps the manager's shards on an N-worker pool each
round (overlapped stepping) — the printed results are bit-identical to
the serial run; only host scheduling changes.

Run:  PYTHONPATH=src python examples/fleet_drive.py [--fast] [--streams 3]
          [--mode drift-weighted] [--row-policy resolve-max]
          [--dispatch sequential|concurrent]
          [--shards 2] [--fail-at 4] [--parallel 2]
"""
import argparse
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--streams", type=int, default=3)
    ap.add_argument("--scenario", default="S3",
                    help="scenario of the drifting camera")
    ap.add_argument("--mode", default="drift-weighted",
                    choices=("drift-weighted", "uniform", "round-robin",
                             "isolated"))
    ap.add_argument("--row-policy", default="resolve-max",
                    choices=("resolve-max", "drift-surge", "weighted-vote"),
                    help="fleet spatial-plane policy (FleetRowPolicy)")
    ap.add_argument("--dispatch", default="sequential",
                    choices=("sequential", "concurrent"))
    ap.add_argument("--shards", type=int, default=1,
                    help="run under the FleetManager tier with N shards")
    ap.add_argument("--fail-at", type=int, default=None, metavar="PHASE",
                    help="kill the last shard's accelerator at this fleet "
                         "phase (implies the manager tier)")
    ap.add_argument("--parallel", type=int, default=0, metavar="N",
                    help="overlapped shard stepping: N pool workers step "
                         "the shards concurrently each round (0 = serial; "
                         "the ManagerResult is bit-identical either way)")
    args = ap.parse_args()
    if args.parallel > 1 and args.shards < 2:
        args.shards = 2  # overlap needs more than one shard to step
    if args.fail_at is not None and args.shards < 2:
        args.shards = 2  # a failure needs a survivor to recover onto

    import dataclasses

    from repro.configs.dacapo_pairs import RESNET18, WIDERESNET50
    from repro.core import CLHyperParams, FleetSpec, pretrain_model
    from repro.core.mx import PrecisionPolicy
    from repro.data.stream import DriftStream, Segment, scenario
    from repro.models.registry import make_vision_model

    seg_s = 20.0 if args.fast else 45.0
    n_seg = 4 if args.fast else 5
    duration = 60.0 if args.fast else 180.0
    drifting = [dataclasses.replace(s, duration_s=seg_s)
                for s in scenario(args.scenario, n_seg)]
    streams = [DriftStream(drifting, seed=11, img=24)]
    for i in range(args.streams - 1):
        streams.append(DriftStream([Segment(duration_s=seg_s)] * n_seg,
                                   seed=21 + i, img=24))
    # MX9 serving -> balanced (8, 8) split; v_thr widened for the scaled
    # per-lane label counts (same setup as benchmarks/bench_fleet.py).
    hp = CLHyperParams(n_t=48 if args.fast else 64,
                       n_l=24 if args.fast else 32, c_b=192, v_thr=-0.2)

    rng = np.random.default_rng(0)
    steps = (20, 12) if args.fast else (60, 30)
    tp = pretrain_model(make_vision_model(WIDERESNET50.reduced()),
                        streams[0], steps[0], 48, rng)
    sp = pretrain_model(make_vision_model(RESNET18.reduced()), streams[0],
                        steps[1], 48, rng,
                        segments=streams[0].segments[:1], seed=8)

    spec = FleetSpec(student=RESNET18, teacher=WIDERESNET50, hp=hp,
                     fleet_mode=args.mode, row_policy=args.row_policy,
                     apply_mx=False, eval_fps=0.5,
                     policy=PrecisionPolicy(inference="mx9"),
                     dispatch=args.dispatch)
    if args.shards > 1:
        run_manager(args, spec, streams, tp, sp, duration)
        return
    fleet = spec.build()
    fleet.set_pretrained(tp, sp)
    fleet.add_observer(lambda rec: print(
        f"  [s{rec.stream}] phase {rec.index:2d} t={rec.t:6.1f}s "
        f"acc_v={rec.acc_valid:.2f} acc_l={rec.acc_label:.2f} "
        f"budget={rec.decision.retrain_samples:3d}r/"
        f"{rec.decision.total_label_samples:3d}l "
        f"tsa={rec.t_tsa:5.2f}s"
        f"{' DRIFT' if rec.drift else ''}"))
    fres = fleet.run(streams, duration=duration)

    print(f"\nfleet mode={args.mode} row-policy={args.row_policy} "
          f"streams={args.streams} {duration:.0f} virtual seconds "
          f"({len(fres.fleet_phase_log)} fleet phases)")
    for i, lane in enumerate(fres.streams):
        kind = "drifting" if i == 0 else "stable"
        print(f"  s{i} ({kind:8s}): avg={lane.avg_accuracy * 100:5.1f}%  "
              f"drifts={lane.drift_events}  "
              f"label/retrain={lane.label_time:.0f}/"
              f"{lane.retrain_time:.0f}s")
    print(f"fleet mean accuracy: {fres.fleet_avg_accuracy * 100:.1f}%")
    if fres.fleet_phase_log:
        mean_tsa = float(np.mean([e["t_tsa"]
                                  for e in fres.fleet_phase_log]))
        print(f"shared T-SA per phase: {mean_tsa:.2f}s "
              f"(sum of per-stream shares — one array, not N)")
        # Fleet rows over time: the ONE spatial plane per phase.
        rows = [(e["t"], e["rows_tsa"], e["rows_bsa"])
                for e in fres.fleet_phase_log]
        print("fleet rows over time (t: T-SA/B-SA):")
        print("  " + "  ".join(f"{t:5.0f}s:{rt}/{rb}"
                               for t, rt, rb in rows))
        moves = sum(1 for a, b in zip(rows, rows[1:]) if a[1] != b[1])
        print(f"spatial re-allocations: {moves} "
              f"(row policy: {args.row_policy})")


def run_manager(args, spec, streams, tp, sp, duration):
    """The sharded tier: N FleetSessions under one FleetManager, with
    headroom placement, live migration, per-lane checkpoints and (with
    --fail-at) an injected accelerator loss + recovery."""
    import tempfile

    from repro.core.manager import FleetManager
    from repro.runtime.fault import FailureInjector

    victim = args.shards - 1
    injector = None
    if args.fail_at is not None:
        injector = FailureInjector(fail_at_steps=[(args.fail_at, victim)])
    with tempfile.TemporaryDirectory(prefix="fleet_drive_ckpt_") as ckpt:
        mgr = FleetManager(spec, n_shards=args.shards,
                           placement="headroom",
                           placement_kwargs={"min_gap": 1},
                           checkpoint_dir=ckpt, checkpoint_every=2,
                           migration=True, migration_cooldown=2,
                           failure_injector=injector, recovery_cost_s=2.0,
                           parallel_shards=args.parallel)
        mgr.set_pretrained(tp, sp)
        res = mgr.run(streams, duration=duration)

    stepping = (f"overlapped x{args.parallel} "
                f"({res.parallel_rounds}/{res.rounds} pooled rounds)"
                if args.parallel > 1 else "serial")
    print(f"\nmanager: {args.shards} shards, mode={args.mode}, "
          f"{duration:.0f} virtual seconds, {res.rounds} rounds, "
          f"stepping {stepping}"
          + (f", shard {victim} killed at phase {args.fail_at}"
             if args.fail_at is not None else ""))
    print("re-homing / recovery timeline:")
    shown = 0
    for e in res.events:
        if e.kind == "checkpoint":
            continue
        shown += 1
        where = (f"shard {e.shard}" if e.to_shard is None
                 else f"shard {e.shard} -> {e.to_shard}")
        lane = f" lane {e.key}" if e.key is not None else ""
        print(f"  t={e.t:6.1f}s round {e.round:2d} {e.kind:8s} "
              f"{where}{lane}  {e.detail}")
    if not shown:
        print("  (no admissions, migrations or failures)")
    ckpts = sum(1 for e in res.events if e.kind == "checkpoint")
    print(f"checkpoint sweeps: {ckpts} (every 2 rounds, per-lane)")
    print("per-lane results:")
    for key in sorted(res.lane_results, key=str):
        lane = res.lane_results[key]
        print(f"  {key}: avg={lane.avg_accuracy * 100:5.1f}%  "
              f"phases={len(lane.records)}  drifts={lane.drift_events}")
    print(f"fleet mean accuracy: {res.fleet_avg_accuracy * 100:.1f}%")
    dead = [i for i, r in enumerate(res.shard_results) if r is None]
    for i, led in enumerate(res.shard_ledgers):
        state = "DEAD" if i in dead else "alive"
        print(f"  shard {i} ({state}): t_tsa={led['t_tsa']:7.2f}s "
              f"t_bsa={led['t_bsa']:7.2f}s")
    print(f"manager ledger: t_tsa={res.ledger['t_tsa']:.2f}s "
          f"+ recovery={res.ledger['recovery_cost']:.2f}s "
          f"(conservation gap {res.conservation_gap():.2e})")


if __name__ == "__main__":
    main()
